"""Eq. 3 (CLR) and Eq. 4 (ILE) unit tests against hand-computed values."""
import numpy as np
import pytest

from repro.core.schedule import (EpochController, clr_lr, elr_lr,
                                 relative_change, round_lr)
from repro.configs.base import CoLearnConfig


def test_clr_eq3_values():
    # η_j^i = η^i · r^(j/T_i), r=1/4 (paper's setting)
    assert np.isclose(clr_lr(0.01, 0.25, 0, 8), 0.01)
    assert np.isclose(clr_lr(0.01, 0.25, 8, 8), 0.0025)
    assert np.isclose(clr_lr(0.01, 0.25, 4, 8), 0.01 * 0.25 ** 0.5)


def test_clr_restarts_each_round():
    cfg = CoLearnConfig(schedule="clr", T0=4)
    lr_round0_start = round_lr(cfg, 0, 0, 4, 0, 100)
    lr_round0_end = round_lr(cfg, 0, 3, 4, 3, 100)
    lr_round1_start = round_lr(cfg, 1, 0, 4, 4, 100)
    assert lr_round0_end < lr_round0_start
    assert np.isclose(lr_round1_start, lr_round0_start)  # the cycle restart


def test_elr_never_restarts():
    cfg = CoLearnConfig(schedule="elr", T0=4)
    lrs = [round_lr(cfg, i, j, 4, i * 4 + j, 16)
           for i in range(4) for j in range(4)]
    assert all(b < a for a, b in zip(lrs, lrs[1:]))  # strictly decreasing


def test_ile_eq4_doubles_only_below_epsilon():
    c = EpochController(T=5, epsilon=0.01, rule="ile")
    c = c.update(0.5)        # big change: keep T
    assert c.T == 5
    c = c.update(0.009)      # below eps: double
    assert c.T == 10
    c = c.update(0.0001)
    assert c.T == 20


def test_fle_never_doubles():
    c = EpochController(T=5, epsilon=0.01, rule="fle")
    for rel in (0.5, 0.001, 0.0):
        c = c.update(rel)
    assert c.T == 5


def test_relative_change():
    import jax.numpy as jnp
    a = {"w": jnp.ones((4,))}
    b = {"w": jnp.ones((4,)) * 2}
    # ||a - b|| / ||b|| = 2/4 = 0.5
    assert np.isclose(relative_change(a, b), 0.5)
    assert relative_change(a, a) == 0.0
