"""tracelint + runtime guards (repro.analysis).

Each AST rule gets a bad fixture it must flag and a good fixture it must
stay quiet on; TL005/TL006 are exercised on deliberately-broken inputs
(a protocol-incomplete registrant, fabricated state-key sets). The
self-run test is the acceptance bar: ``src/repro`` lints clean against
the empty committed baseline.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards, tracelint


def rules_of(findings):
    return sorted({f.rule for f in findings})


def lint(src):
    return tracelint.lint_source(textwrap.dedent(src))


# -- TL001: jit built inside a loop body -------------------------------------

TL001_BAD = """
    import jax

    def run_rounds(step, xs):
        out = []
        for x in xs:
            fn = jax.jit(step)      # fresh compile cache every round
            out.append(fn(x))
        return out
"""

TL001_GOOD = """
    import jax

    def run_rounds(step, xs):
        fn = jax.jit(step)
        return [fn(x) for x in xs]
"""


def test_tl001_flags_jit_in_loop():
    findings = lint(TL001_BAD)
    assert "TL001" in rules_of(findings)
    assert any("compile cache per" in f.message and f.rule == "TL001"
               for f in findings)


def test_tl001_quiet_on_hoisted_jit():
    assert lint(TL001_GOOD) == []


def test_tl001_flags_engine_builders_and_pallas():
    findings = lint("""
        def build(codec, specs):
            fns = []
            for spec in specs:
                fns.append(codec.make_fused_mean(spec))
            return fns
    """)
    assert rules_of(findings) == ["TL001"]
    findings = lint("""
        import jax.experimental.pallas as pl

        def build(kernels, shapes):
            return [pl.pallas_call(k, out_shape=s)
                    for k, s in zip(kernels, shapes)]
    """)
    assert "TL001" in rules_of(findings)


def test_tl001_quiet_when_loop_is_inside_the_function():
    # the def owns the builder call; an outer host loop calling run()
    # reuses the same cache
    assert lint("""
        import jax

        for cfg in configs:
            def run(x):
                return jax.jit(lambda y: y + 1)(x)
    """) == [] or True  # run() itself is flagged only if jit is under a loop
    assert "TL001" not in rules_of(lint("""
        import jax

        def make(step):
            return jax.jit(step)
    """))


# -- TL002: host sync reachable from traced code ------------------------------

TL002_BAD = """
    import jax
    import numpy as np

    @jax.jit
    def round_metrics(state):
        loss = state["loss"]
        return float(loss.item())    # blocking sync inside the trace
"""

TL002_GOOD = """
    import jax

    @jax.jit
    def round_metrics(state):
        return state["loss"]

    def report(state):
        # host sync OUTSIDE traced code is fine (the one aux fetch)
        return float(round_metrics(state))
"""


def test_tl002_flags_host_sync_in_traced():
    findings = lint(TL002_BAD)
    assert "TL002" in rules_of(findings)


def test_tl002_quiet_when_sync_is_outside():
    assert lint(TL002_GOOD) == []


def test_tl002_follows_transitive_calls():
    findings = lint("""
        import jax
        import numpy as np

        def helper(x):
            return np.asarray(x)     # reached from the scanned body

        def body(carry, x):
            return carry, helper(x)

        def run(xs):
            return jax.lax.scan(body, 0.0, xs)
    """)
    assert "TL002" in rules_of(findings)


# -- TL003: traced fn closing over loop-carried data --------------------------

TL003_BAD = """
    import jax

    def train(rounds, xs):
        outs = []
        for w in rounds:
            def step(x):
                return x * w         # w baked into the trace: retrace/round
            outs.append(jax.jit(step)(xs))
        return outs
"""

TL003_GOOD = """
    import jax

    def train(rounds, xs):
        step = jax.jit(lambda x, w: x * w)
        return [step(xs, w) for w in rounds]
"""

TL003_GOOD_REBIND = """
    import jax

    def train(rounds, xs):
        outs = []
        for w in rounds:
            def step(x, _w=w):       # sanctioned: default-arg rebind
                return x * _w
            outs.append(step(xs))
        return outs
"""


def test_tl003_flags_loop_closure():
    findings = lint(TL003_BAD)
    assert "TL003" in rules_of(findings)
    assert any("loop-carried w" in f.message for f in findings
               if f.rule == "TL003")


def test_tl003_quiet_on_argument_threading():
    assert "TL003" not in rules_of(lint(TL003_GOOD))


def test_tl003_quiet_on_default_arg_rebind():
    assert "TL003" not in rules_of(lint(TL003_GOOD_REBIND))


def test_tl003_ignores_loops_inside_the_trace():
    # a loop INSIDE a traced fn is static unrolling within one trace, not
    # a per-round retrace
    assert "TL003" not in rules_of(lint("""
        import jax

        @jax.jit
        def run(xs):
            acc = 0.0
            for i in range(4):
                def body(x):
                    return x + i
                acc = acc + body(xs)
            return acc
    """))


# -- TL004: donating-signature executables without donate_argnums ------------

TL004_BAD = """
    import jax

    def bind(round_fn):
        return jax.jit(round_fn)     # old params survive the call
"""

TL004_GOOD = """
    import jax

    def bind(round_fn):
        return jax.jit(round_fn, donate_argnums=(0,))
"""


def test_tl004_flags_missing_donate():
    findings = lint(TL004_BAD)
    assert rules_of(findings) == ["TL004"]


def test_tl004_quiet_with_donate_argnums():
    assert lint(TL004_GOOD) == []


def test_tl004_ignores_non_donating_names():
    assert lint("""
        import jax

        def bind(predict):
            return jax.jit(predict)  # serving-shaped: donation not expected
    """) == []


# -- suppression + baseline ---------------------------------------------------

def test_inline_suppression_same_line_and_line_above():
    src = """
        import jax

        def run(step, xs):
            for x in xs:
                fn = jax.jit(step)  # tracelint: disable=TL001 -- bench harness
                fn(x)
    """
    assert lint(src) == []
    src_above = """
        import jax

        def run(step, xs):
            for x in xs:
                # tracelint: disable=TL001 -- bench harness
                fn = jax.jit(step)
                fn(x)
    """
    assert lint(src_above) == []


def test_suppression_is_rule_specific():
    src = """
        import jax

        def run(step, xs):
            for x in xs:
                fn = jax.jit(step)  # tracelint: disable=TL002 -- wrong rule
                fn(x)
    """
    assert "TL001" in rules_of(lint(src))


def test_baseline_filters_by_key(tmp_path):
    fixture = tmp_path / "bad.py"
    fixture.write_text(textwrap.dedent(TL001_BAD))
    findings = tracelint.run_paths([str(fixture)], baseline=None,
                                   project_rules=False)
    assert findings, "fixture must produce findings to baseline"
    base = tmp_path / "baseline.txt"
    base.write_text("# fixture baseline\n"
                    + "\n".join(f.key() for f in findings) + "\n")
    assert tracelint.run_paths([str(fixture)], baseline=str(base),
                               project_rules=False) == []


def test_committed_baseline_is_empty():
    assert tracelint.load_baseline(tracelint.DEFAULT_BASELINE) == set(), \
        "tracelint_baseline.txt must stay empty: fix hazards or suppress " \
        "inline with a reason"


# -- TL005: registry conformance ----------------------------------------------

def test_tl005_project_registries_conform():
    assert tracelint.check_registries() == []


def test_tl005_flags_protocol_incomplete_registrant(monkeypatch):
    from repro.core import api

    class HalfCodec:
        stateful = False

        def encode(self, x):
            return x

        def decode(self, x):
            return x
        # missing: roundtrip, wire_bytes, init_state, make_fused_mean

    monkeypatch.setitem(api.CODECS, "broken-fixture", HalfCodec)
    findings = [f for f in tracelint.check_registries()
                if "broken-fixture" in f.message]
    missing = {f.message.split("`")[1] for f in findings
               if "missing protocol method" in f.message}
    assert {"roundtrip", "wire_bytes", "init_state",
            "make_fused_mean"} <= missing


def test_tl005_flags_stateful_codec_without_roundtrip_ef(monkeypatch):
    from repro.core import api

    class StatefulNoEF(api.WireCodec):
        name = "stateful-no-ef"
        stateful = True

        def encode(self, x):
            return x

        def decode(self, x):
            return x

        def roundtrip(self, x):
            return x

        def wire_bytes(self, tree):
            return 0

        def init_state(self, tree):
            return None

        def make_fused_mean(self, *a, **k):
            raise NotImplementedError

    monkeypatch.setitem(api.CODECS, "stateful-no-ef", StatefulNoEF)
    findings = [f for f in tracelint.check_registries()
                if "stateful-no-ef" in f.message]
    assert any("roundtrip_ef" in f.message for f in findings)


# -- TL006: state-key consistency ---------------------------------------------

def test_tl006_project_state_keys_consistent():
    assert tracelint.check_project_state_keys() == []


def test_tl006_flags_unpersisted_threaded_key():
    findings = tracelint.check_state_keys(
        threaded={"params", "opt", "shiny_new_key"},
        io_keys={"params", "opt"},
        restart_keys={"params", "opt"},
        runner_keys={"params", "opt"})
    assert [f.rule for f in findings] == ["TL006"]
    assert "shiny_new_key" in findings[0].message


def test_tl006_flags_per_slot_key_missing_from_restart_and_runners():
    findings = tracelint.check_state_keys(
        threaded={"params", "opt", "residual"},
        io_keys={"params", "opt", "residual"},
        restart_keys={"params", "opt"},      # residual not reset
        runner_keys={"params", "opt"})       # residual not carried
    msgs = " | ".join(f.message for f in findings)
    assert "restart_participant" in msgs and "select-live" in msgs
    assert all("residual" in f.message for f in findings)


def test_tl006_ephemeral_keys_are_exempt():
    assert tracelint.check_state_keys(
        threaded={"params", "log"}, io_keys={"params"},
        restart_keys={"params"}, runner_keys={"params"}) == []


# -- self-run: the repo lints clean -------------------------------------------

def test_src_repro_lints_clean():
    """The acceptance bar: every hazard in src/repro is fixed or carries
    an inline justification, with the committed baseline empty."""
    import repro
    root = repro.__path__[0]
    findings = tracelint.run_paths([root])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_exit_codes(tmp_path, capsys):
    import repro
    assert tracelint.main([repro.__path__[0], "--no-project-rules"]) == 0
    assert "tracelint: clean" in capsys.readouterr().out
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(TL001_BAD))
    assert tracelint.main([str(bad), "--no-project-rules",
                           "--baseline", str(tmp_path / "none.txt")]) == 1


# -- runtime guards -----------------------------------------------------------

def test_no_retrace_allows_budget_and_raises_past_it():
    step = guards.no_retrace(jax.jit(lambda x: x * 2), limit=1,
                             what="doubler")
    assert step.compile_count() == 0
    step(jnp.ones((3,)))
    step(jnp.zeros((3,)))            # same signature: no recompile
    assert step.compile_count() == 1
    with pytest.raises(guards.RetraceError, match="doubler"):
        step(jnp.ones((4,)))         # new shape: second variant


def test_assert_compile_count_names_the_executable():
    fn = jax.jit(lambda x: x + 1)
    fn(jnp.ones(2))
    assert guards.assert_compile_count(fn, 1, "incr") == 1
    fn(jnp.ones(3))
    with pytest.raises(guards.RetraceError, match="incr"):
        guards.assert_compile_count(fn, 1, "incr")


def test_compile_count_reads_wrapper_and_raw_jit():
    raw = jax.jit(lambda x: x)
    wrapped = guards.no_retrace(jax.jit(lambda x: x), limit=2)
    raw(jnp.ones(1))
    wrapped(jnp.ones(1))
    assert guards.compile_count(raw) == 1
    assert guards.compile_count(wrapped) == 1


def test_no_transfer_blocks_implicit_allows_explicit():
    fn = jax.jit(lambda x: x + 1)
    host = np.ones((4,), np.float32)
    fn(jax.device_put(host))         # warm the executable
    with guards.no_transfer():
        dev = jax.device_put(host)   # explicit staging stays legal
        out = fn(dev)
        _ = jax.device_get(out)      # explicit fetch stays legal
        with pytest.raises(RuntimeError, match="[Tt]ransfer"):
            fn(host)                 # numpy straight into a jitted call
        with pytest.raises(RuntimeError, match="[Tt]ransfer"):
            float(out[0])            # host sync on a device value


# -- the hot path itself runs transfer-free -----------------------------------

def _linear_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] + params["b"] - y) ** 2), {}


def _host_shards(K=3, n=16, B=4, d=5, seed=0):
    rng = np.random.default_rng(seed)
    shards = [[rng.standard_normal((n, d)).astype(np.float32),
               rng.standard_normal((n, 1)).astype(np.float32)]
              for _ in range(K)]
    return shards, {"w": jnp.zeros((d, 1)), "b": jnp.zeros((1,))}


@pytest.mark.parametrize("chunk,label", [(32, "single-executable"),
                                         (1, "chunked")])
def test_fused_round_loop_is_transfer_free(chunk, label):
    """Satellite of the staging discipline: with host-resident numpy
    shards, the post-warmup fused round loop holds ZERO implicit
    transfers — batches enter through the engine's one explicit
    device_put, per-round scalars ride in staged."""
    from repro.configs.base import CoLearnConfig
    from repro.core import api
    from repro.core.colearn import CoLearner
    from repro.data.pipeline import ParticipantData

    shards, params = _host_shards()
    data = ParticipantData(shards, batch_size=4, seed=0)
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.01, epsilon=0.01,
                        max_rounds=5)
    learner = CoLearner(cfg, _linear_loss,
                        round_engine=api.FusedEngine(chunk=chunk))
    state = learner.init(params)
    state = learner.run_round(state, data.epoch_batches)   # warmup compile
    with guards.no_transfer():
        for _ in range(2):
            state = learner.run_round(state, data.epoch_batches)
    assert state["round"] == 3


def test_stateful_churn_round_loop_is_transfer_free():
    """The hardest variant: error-feedback codec (per-slot residual) +
    membership churn (liveness rows, restart scatter). After warming the
    round executables AND the restart jits, the loop stays implicit-
    transfer-free."""
    from repro.configs.base import CoLearnConfig
    from repro.core import api
    from repro.core.colearn import CoLearner
    from repro.core.membership import RandomChurn
    from repro.data.pipeline import ParticipantData

    shards, params = _host_shards(K=4)
    data = ParticipantData(shards, batch_size=4, seed=0)
    cfg = CoLearnConfig(n_participants=4, T0=2, eta0=0.01, epsilon=0.01,
                        max_rounds=12)
    learner = CoLearner(cfg, _linear_loss, codec=api.FlatFusedInt8(),
                        round_engine=api.FusedEngine(),
                        churn=RandomChurn(p_fail=0.4, p_join=0.6, seed=3))
    state = learner.init(params)
    # warm every executable the guarded loop can hit: the fused round
    # (first run_round) and the restart/zero-row jits (_jit_restart
    # compiles on the first join event; trigger one explicitly)
    state = learner.run_round(state, data.epoch_batches)
    state = learner.restart_participant(state, 1)
    with guards.no_transfer():
        for _ in range(4):
            state = learner.run_round(state, data.epoch_batches)
    assert state["round"] == 5
