"""Fused round engine (repro.core.engine) vs the python reference loop.

The acceptance bar for the fused engine: the shared model after 3 rounds
matches the python-loop engine to <=1e-5 (we observe bitwise equality on
CPU), and the Eq. 4 ILE doubling / Eq. 3 CLR restart behaviour is
identical even though the fused path computes the schedule *traced*
inside the epoch scan.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards
from repro.configs.base import CoLearnConfig
from repro.core import engine as engine_mod
from repro.core.colearn import CoLearner
from repro.core.schedule import clr_lr


def tiny_loss(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, {"loss": loss}


def tiny_params(key=0, d=4):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}


def tiny_batches(K, n_batches, B, d=4, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (K, n_batches, B, d))
    w_true = jnp.arange(1.0, d + 1)[:, None]
    return (x, x @ w_true)


def run_both(cfg, loss_fn, params, batches_fn, rounds, **kw):
    out = {}
    for eng in ("python", "fused"):
        learner = CoLearner.from_flags(cfg, loss_fn, engine=eng, **kw)
        state = learner.init(params)
        for _ in range(rounds):
            state = learner.run_round(state, batches_fn)
        out[eng] = (learner.shared_model(state), state)
    return out


def max_abs_diff(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("schedule", ["clr", "elr"])
@pytest.mark.parametrize("rule", ["ile", "fle"])
def test_fused_matches_python_all_schedules(schedule, rule):
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=0.5,
                        schedule=schedule, epochs_rule=rule, max_rounds=3)
    b = tiny_batches(3, 4, 8)
    out = run_both(cfg, tiny_loss, tiny_params(), lambda i, j: b, rounds=3)
    (mp, sp_), (mf, sf) = out["python"], out["fused"]
    assert max_abs_diff(mp, mf) <= 1e-5
    # identical controller decisions and round bookkeeping
    assert [l.T for l in sp_["log"]] == [l.T for l in sf["log"]]
    assert sp_["ctrl"].T == sf["ctrl"].T
    assert sp_["global_epoch"] == sf["global_epoch"]
    for lp, lf in zip(sp_["log"], sf["log"]):
        np.testing.assert_allclose(lp.local_losses, lf.local_losses,
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose([lp.lr_first, lp.lr_last],
                                   [lf.lr_first, lf.lr_last], rtol=1e-6)
        assert lp.comm_bytes == lf.comm_bytes


@pytest.mark.parametrize("optimizer", ["momentum", "adamw"])
def test_fused_matches_python_stateful_optimizers(optimizer):
    """Opt state threads through the fused epoch scan exactly like the loop."""
    cfg = CoLearnConfig(n_participants=2, T0=3, eta0=0.01, epsilon=0.5,
                        max_rounds=2)
    b = tiny_batches(2, 3, 8)
    out = run_both(cfg, tiny_loss, tiny_params(), lambda i, j: b, rounds=2,
                   optimizer_name=optimizer)
    assert max_abs_diff(out["python"][0], out["fused"][0]) <= 1e-5


def test_fused_matches_python_with_compression():
    from repro.core.compression import make_compress_fn
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=2)
    b = tiny_batches(3, 2, 8)
    out = run_both(cfg, tiny_loss, tiny_params(), lambda i, j: b, rounds=2,
                   compress_fn=make_compress_fn())
    assert max_abs_diff(out["python"][0], out["fused"][0]) <= 1e-5


@pytest.mark.parametrize("compress", ["leafwise", "fused"])
def test_fused_matches_python_with_compress_modes(compress):
    """CoLearner(compress=...) routes both engines through the same wire
    path, so python-vs-fused equivalence must hold under either codec."""
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=2)
    b = tiny_batches(3, 2, 8, d=8)
    out = run_both(cfg, tiny_loss, tiny_params(d=8), lambda i, j: b,
                   rounds=2, compress=compress)
    assert max_abs_diff(out["python"][0], out["fused"][0]) <= 1e-5


def test_fused_compressed_average_matches_leafwise_finalize():
    """The ISSUE 2 acceptance bar: the flat-buffer finalize (one fused
    quant->avg->dequant pass, ``make_fused_compressed_average``) and the
    leafwise reference finalize (per-leaf roundtrip + separate mean) agree
    to <=1e-6 on block-aligned trees — identical block boundaries make the
    two wire paths produce the same int8 codes and scales."""
    from repro.core.compression import make_compress_fn
    from repro.optim.optimizers import get_optimizer
    opt = get_optimizer("sgd")
    K = 4
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    stacked = {"w": jax.random.normal(ks[0], (K, 3, 256)),
               "v": jax.random.normal(ks[1], (K, 512)),
               "u": jax.random.normal(ks[2], (K, 2, 2, 256))}
    old_avg = jax.tree.map(lambda t: t[0], stacked)
    fin_leaf = engine_mod.make_fused_finalize(
        opt, compress_fn=make_compress_fn(), donate=False)
    fin_flat = engine_mod.make_fused_finalize(
        opt, average_fn=engine_mod.make_fused_compressed_average(impl="ref"),
        donate=False)
    avg_l, _, rel_l, new_l = fin_leaf(stacked, old_avg)
    avg_f, _, rel_f, new_f = fin_flat(stacked, old_avg)
    assert max_abs_diff(avg_l, avg_f) <= 1e-6
    assert max_abs_diff(new_l, new_f) <= 1e-6
    np.testing.assert_allclose(float(rel_l), float(rel_f), rtol=1e-5)


def test_fused_matches_python_smoke_transformer():
    """The ISSUE acceptance bar: <=1e-5 over 3 rounds on the smoke config."""
    from repro.configs import get_smoke_config
    from repro.data.partition import partition_arrays
    from repro.data.pipeline import ParticipantData
    from repro.data.synthetic import lm_examples
    from repro.models import transformer as tr

    cfg = get_smoke_config("internlm2-1.8b").with_(
        n_layers=1, segments=((("gqa:dense",), 1),))
    K = 3
    x, y = lm_examples(0, 240, 32, cfg.vocab_size)
    data = ParticipantData(partition_arrays([x, y], K, 0), batch_size=8)

    def loss_fn(params, batch):
        bx, by = batch
        return tr.loss_fn(params, cfg, {"tokens": bx, "labels": by})

    def eb(i, j):
        return tuple(map(jnp.asarray, data.epoch_batches(i, j)))

    ccfg = CoLearnConfig(n_participants=K, T0=1, eta0=0.05, epsilon=1e-6,
                         max_rounds=3)
    params = tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    out = run_both(ccfg, loss_fn, params, eb, rounds=3)
    assert max_abs_diff(out["python"][0], out["fused"][0]) <= 1e-5
    # both engines actually trained
    lf = [np.mean(l.local_losses) for l in out["fused"][1]["log"]]
    assert lf[-1] < lf[0]


def test_ile_doubling_identical_under_traced_schedule():
    """Zero gradients => rel=0 => Eq. 4 doubles T the same in both engines."""
    def zero_loss(params, batch):
        return jnp.zeros(()), {}
    cfg = CoLearnConfig(n_participants=2, T0=1, epsilon=0.01,
                        epochs_rule="ile", max_rounds=3)
    b = tiny_batches(2, 1, 2)
    out = run_both(cfg, zero_loss, tiny_params(), lambda i, j: b, rounds=3)
    for eng in ("python", "fused"):
        state = out[eng][1]
        # round0: rel=inf (no prev) keep 1; round1: rel=0 -> 2; round2: -> 4
        assert [l.T for l in state["log"]] == [1, 1, 2], eng
        assert state["ctrl"].T == 4, eng
    hp = out["python"][1]["ctrl"].history
    hf = out["fused"][1]["ctrl"].history
    # history entries are (round, rel_change, next_T) triples
    assert [i for i, _, _ in hp] == list(range(len(hp)))
    assert [t for _, _, t in hp] == [t for _, _, t in hf]


def test_clr_restart_traced_in_scan():
    """The in-scan Eq. 3 schedule restarts at eta0 every round and decays
    to eta0 * r^((T-1)/T) within the round — same as the host loop."""
    cfg = CoLearnConfig(n_participants=2, T0=4, eta0=0.02, epsilon=0.0,
                        schedule="clr", epochs_rule="fle", max_rounds=3)
    b = tiny_batches(2, 2, 8)
    learner = CoLearner.from_flags(cfg, tiny_loss, engine="fused")
    state = learner.init(tiny_params())
    for _ in range(3):
        state = learner.run_round(state, lambda i, j: b)
    for log in state["log"]:
        np.testing.assert_allclose(log.lr_first, 0.02, rtol=1e-6)
        np.testing.assert_allclose(
            log.lr_last, clr_lr(0.02, cfg.decay_rate, 3, 4), rtol=1e-6)


def test_stack_epoch_batches_shape():
    per_epoch = [tiny_batches(2, 3, 4, seed=s) for s in range(5)]
    stacked = engine_mod.stack_epoch_batches(per_epoch)
    assert stacked[0].shape == (5, 2, 3, 4, 4)
    assert stacked[1].shape == (5, 2, 3, 4, 1)
    np.testing.assert_array_equal(stacked[0][2], per_epoch[2][0])


def test_fused_chunked_matches_python_and_single_shot():
    """T_i > fused_chunk switches to chained chunk executables + finalize;
    the trajectory must match both the python loop and the single-shot
    fused path (chunk sizes 2 and 5 cover remainder/no-remainder splits)."""
    cfg = CoLearnConfig(n_participants=2, T0=5, eta0=0.05, epsilon=0.5,
                        schedule="clr", epochs_rule="fle", max_rounds=2)
    b = tiny_batches(2, 3, 8)
    ref = None
    for eng, chunk in (("python", 32), ("fused", 32), ("fused", 2),
                       ("fused", 5)):
        learner = CoLearner.from_flags(cfg, tiny_loss, engine=eng,
                                        fused_chunk=chunk)
        state = learner.init(tiny_params())
        for _ in range(2):
            state = learner.run_round(state, lambda i, j: b)
        model = learner.shared_model(state)
        log = [(l.T, l.lr_first, l.lr_last) for l in state["log"]]
        if ref is None:
            ref = (model, log, state["global_epoch"])
        else:
            assert max_abs_diff(ref[0], model) <= 1e-5, (eng, chunk)
            np.testing.assert_allclose(
                np.array([x[1:] for x in log]),
                np.array([x[1:] for x in ref[1]]), rtol=1e-6)
            assert [x[0] for x in log] == [x[0] for x in ref[1]]
            assert state["global_epoch"] == ref[2]


def test_fused_chunk_executable_reused_across_T_doubling():
    """j0/T_i/ge0 are traced in the chunk executable: ILE doubling past the
    chunk size must NOT trigger recompiles for full-size chunks."""
    def zero_loss(params, batch):
        return jnp.zeros(()), {}
    cfg = CoLearnConfig(n_participants=2, T0=2, epsilon=0.01,
                        epochs_rule="ile", max_rounds=4)
    learner = CoLearner.from_flags(cfg, zero_loss, engine="fused",
                                    fused_chunk=2)
    state = learner.init(tiny_params())
    b = tiny_batches(2, 1, 2)
    for _ in range(4):
        state = learner.run_round(state, lambda i, j: b)
    # T trajectory 2,2,4,8: rounds 3-4 use the chunked path with C=2 only
    assert [l.T for l in state["log"]] == [2, 2, 4, 8]
    guards.assert_compile_count(learner._fused_epochs, 1,
                                "chunk executable")


def test_fused_single_round_recompiles_only_on_T_change():
    """The executable is cached per T_i: growing T (ILE doubling) recompiles,
    repeated rounds at the same T reuse the cache."""
    cfg = CoLearnConfig(n_participants=2, T0=2, eta0=0.01, epsilon=0.0,
                        max_rounds=4)
    learner = CoLearner.from_flags(cfg, tiny_loss, engine="fused")
    state = learner.init(tiny_params())
    b = tiny_batches(2, 2, 4)
    for _ in range(3):
        state = learner.run_round(state, lambda i, j: b)
    # T never doubled (epsilon=0) => one executable
    guards.assert_compile_count(learner._fused_round, 1,
                                "round executable")
