"""Flat-buffer wire codec (repro.core.flatbuf) + fused compressed average.

The codec's contract: one static layout per tree structure, bit-exact
flatten/unflatten inversion, no leaf exempt from the wire format, and exact
bytes-on-the-wire accounting. The fused compressed average built on it must
match the leafwise reference path exactly when the block boundaries align
and stay within the int8 error bound of the exact mean always.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import averaging, engine as engine_mod, flatbuf
from repro.core.compression import (compressed_bytes, flat_compressed_bytes,
                                    quantize_roundtrip)

KEY = jax.random.PRNGKey(3)


def mixed_tree(K=3):
    """Odd shapes, a scalar leaf, mixed float dtypes."""
    ks = jax.random.split(KEY, 5)
    return {
        "w": jax.random.normal(ks[0], (K, 7, 13)),
        "b": jax.random.normal(ks[1], (K, 5)).astype(jnp.bfloat16),
        "scale": jax.random.normal(ks[2], (K,)),                 # scalar leaf
        "h": (jax.random.normal(ks[3], (K, 300)).astype(jnp.float16),
              jax.random.normal(ks[4], (K, 2, 256))),
    }


def assert_bit_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype and x.shape == y.shape
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


def test_layout_static_and_padded():
    tree = mixed_tree()
    lo = flatbuf.make_layout(tree)
    sizes = [5, 300, 512, 1, 7 * 13]      # dict keys flatten sorted: b,h,scale,w
    assert list(lo.sizes) == sizes
    # every leaf starts on a block boundary (blocks never straddle leaves)
    assert list(lo.offsets) == [0, 256, 768, 1280, 1536]
    assert all(off % lo.block == 0 for off in lo.offsets)
    assert lo.n == 1792 and lo.k == 3     # block-aligned payload end
    assert lo.n_pad % (lo.rows * lo.block) == 0 and lo.n_pad >= lo.n
    # shapes-only: ShapeDtypeStructs produce the identical layout
    abstract = jax.tree.map(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), tree)
    lo2 = flatbuf.make_layout(abstract)
    assert (lo.offsets, lo.sizes, lo.shapes, lo.dtypes, lo.n_pad) == \
           (lo2.offsets, lo2.sizes, lo2.shapes, lo2.dtypes, lo2.n_pad)


def test_flatten_unflatten_bit_exact():
    tree = mixed_tree()
    lo = flatbuf.make_layout(tree)
    buf = flatbuf.flatten(tree, lo)
    assert buf.shape == (lo.k, lo.n_pad) and buf.dtype == jnp.float32
    assert_bit_equal(flatbuf.unflatten(buf, lo), tree)
    # tail pad is zero-filled whole blocks (never shares a scale with data)
    assert not np.asarray(buf[:, lo.n:]).any()


def test_layout_rejects_mismatched_participant_dim():
    with pytest.raises(ValueError):
        flatbuf.make_layout({"a": jnp.zeros((2, 3)), "b": jnp.zeros((4, 3))})
    with pytest.raises(ValueError):
        flatbuf.make_layout({"a": jnp.zeros(())})


def test_layout_rejects_dtypes_the_f32_container_corrupts():
    """int32 > 2^24 (or f64) would silently lose bits in the f32 wire
    buffer — the layout must refuse them instead. (f64 arrays can't exist
    without x64 mode, so it's exercised via ShapeDtypeStruct.)"""
    with pytest.raises(ValueError):
        flatbuf.make_layout({"a": jnp.zeros((2, 8), dtype=jnp.int32)})
    with pytest.raises(ValueError):
        flatbuf.make_layout(
            {"a": jax.ShapeDtypeStruct((2, 8), np.dtype("float64"))})


def test_wire_bytes_exact_no_leaf_escapes():
    """Every element of every leaf — including sub-block and scalar leaves
    the leafwise path exempts — is on the int8+scale wire format."""
    tree = mixed_tree()
    lo = flatbuf.make_layout(tree)
    wb = flatbuf.wire_bytes(lo)
    assert wb == lo.n_pad + 4 * (lo.n_pad // lo.block)
    assert flat_compressed_bytes(tree) == wb
    # leafwise accounting reports bypassed leaves at raw rates and bills
    # quantized leaves as whole padded blocks (packed payload + f32 scale)
    one = jax.tree.map(lambda t: t[0], tree)
    lb = compressed_bytes(one)
    expect = 0
    for t in jax.tree.leaves(one):
        if t.ndim == 0 or t.size < 256:
            expect += t.size * t.dtype.itemsize
        else:
            expect += (-(-t.size // 256)) * (256 + 4)
    assert lb == expect
    # sub-int8 payloads: bytes shrink with the bit width, scales don't
    for bits in (4, 1):
        wb_n = flatbuf.wire_bytes(lo, bits=bits)
        assert wb_n == (lo.n_pad * bits) // 8 + 4 * (lo.n_pad // lo.block)
        assert flat_compressed_bytes(tree, bits=bits) == wb_n
        assert wb_n < wb


def test_fused_average_within_quant_bound_and_broadcast():
    tree = mixed_tree()
    avg_fn = jax.jit(engine_mod.make_fused_compressed_average(impl="ref"))
    out = avg_fn(tree)
    exact = averaging.average_pjit(tree)
    for a, b, t in zip(jax.tree.leaves(out), jax.tree.leaves(exact),
                       jax.tree.leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape
        # the int8 step scales with the PARTICIPANT data's amax (the mean's
        # own amplitude cancels); add the storage-dtype casts of the mean
        amax = np.abs(np.asarray(t, np.float32)).max()
        err = np.abs(np.asarray(a, np.float32)
                     - np.asarray(b, np.float32)).max()
        bound = amax / 127.0 + 2 * float(jnp.finfo(a.dtype).eps) * amax + 1e-6
        assert err <= bound
        # all K slots hold the same mean (average_fn contract)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(a[1]))


def test_fused_average_pallas_matches_ref_impl():
    tree = mixed_tree()
    out_r = jax.jit(engine_mod.make_fused_compressed_average(impl="ref"))(tree)
    out_p = jax.jit(
        engine_mod.make_fused_compressed_average(impl="interpret"))(tree)
    for a, b in zip(jax.tree.leaves(out_r), jax.tree.leaves(out_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_fused_average_matches_leafwise_when_blocks_align():
    """With every per-participant leaf a whole number of f32 blocks, the
    flat buffer reproduces the leafwise block boundaries exactly — the two
    wire paths must then agree to well under 1e-6 (observed: bitwise)."""
    ks = jax.random.split(KEY, 3)
    K = 4
    tree = {"a": jax.random.normal(ks[0], (K, 2, 256)),
            "b": jax.random.normal(ks[1], (K, 512)),
            "c": jax.random.normal(ks[2], (K, 256))}
    out_f = jax.jit(engine_mod.make_fused_compressed_average(impl="ref"))(tree)
    out_l = jax.jit(lambda t: averaging.average_pjit(
        quantize_roundtrip(t)))(tree)
    for a, b in zip(jax.tree.leaves(out_f), jax.tree.leaves(out_l)):
        assert np.abs(np.asarray(a) - np.asarray(b)).max() <= 1e-6
