"""Elastic membership (ISSUE 6): liveness-masked round execution + churn.

Covers: Membership state/event-log semantics; ScriptedChurn / RandomChurn
schedule semantics (latest-event-wins, flaky slots, (seed, round)
determinism, the sole-survivor guarantee); the static-K reduction (all-live
runs are BIT-identical to the pre-membership path on both engines and both
wire codecs); cross-engine agreement under churn (identical membership
traces, matching round outputs); the dead-slot identity carry (params AND
optimizer state frozen through a churn round); live-renormalized mixing for
all three aggregators; the ``restart_participant`` sync-reference bugfix
(RingGossip rows are distinct, a quiet DivergenceTrigger round drifts slot
0 — both would hand the restarted peer the wrong model); membership-aware
sync policies (event rounds hold the ILE doubling and force a
DivergenceTrigger sync); checkpoint forward/backward compatibility and
resume parity under scripted churn; the K_max standby-slot pipeline
padding; and the train.py parse-time flag validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core import membership as M
from repro.core import schedule as sched_mod
from repro.core.colearn import CoLearner
from repro.data.pipeline import ParticipantData


def tiny_loss(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, {"loss": loss}


def tiny_params(key=0, d=4):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}


def tiny_batches(K, n_batches, B, d=4, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (K, n_batches, B, d))
    w_true = jnp.arange(1.0, d + 1)[:, None]
    return (x, x @ w_true)


def max_abs_diff(a, b):
    # default covers leafless pytrees (e.g. the SGD optimizer state)
    return max((float(jnp.abs(jnp.asarray(x, jnp.float32)
                              - jnp.asarray(y, jnp.float32)).max())
                for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))),
               default=0.0)


def run_rounds(rounds=4, K=4, engine="python", churn=None, **kw):
    cfg = CoLearnConfig(n_participants=K, T0=2, eta0=0.05, epsilon=1e-9,
                        max_rounds=rounds, **{k: v for k, v in kw.items()
                                              if k in ("epochs_rule",)})
    kw = {k: v for k, v in kw.items() if k != "epochs_rule"}
    learner = CoLearner(cfg, tiny_loss, round_engine=engine, churn=churn,
                        **kw)
    state = learner.init(tiny_params())
    batches = tiny_batches(K, 3, 2)
    for _ in range(rounds):
        state = learner.run_round(state, lambda i, j: batches)
    return learner, state


# ---------------------------------------------------------------------------
# Membership state
# ---------------------------------------------------------------------------
def test_membership_step_logs_flips():
    m = M.Membership.all_live(3)
    assert m.n_live == 3 and m.k_max == 3 and m.live_slots() == (0, 1, 2)
    m = m.step(1, [True, False, True])
    assert m.events == ((1, 1, "leave"),)
    m = m.step(2, [True, False, True])      # no change -> no event
    m = m.step(3, [True, True, False])
    assert m.round_events(3) == ((3, 1, "join"), (3, 2, "leave"))
    assert m.joined(3) == (1,)
    assert m.live == (True, True, False) and m.n_live == 2


def test_membership_step_validates_length():
    with pytest.raises(ValueError, match="K_max"):
        M.Membership.all_live(3).step(0, [True, True])


# ---------------------------------------------------------------------------
# Churn schedules
# ---------------------------------------------------------------------------
def test_scripted_churn_events_latest_wins_and_flaky():
    c = M.ScriptedChurn(events=(("crash", 1, 0), ("rejoin", 3, 0)),
                        flaky=((2, 3),))
    assert not c.is_static
    assert list(c.live_mask(0, 3)) == [True, True, True]
    assert list(c.live_mask(1, 3)) == [False, True, True]
    # flaky slot 2 is down on rounds r % 3 == 2
    assert list(c.live_mask(2, 3)) == [False, True, False]
    assert list(c.live_mask(3, 3)) == [True, True, True]
    assert list(c.live_mask(5, 3)) == [True, True, False]


def test_scripted_churn_initial_live_standby():
    c = M.ScriptedChurn(events=(("rejoin", 2, 3),), initial_live=3)
    assert list(c.live_mask(0, 4)) == [True, True, True, False]
    assert list(c.live_mask(2, 4)) == [True, True, True, True]


def test_scripted_churn_zero_live_raises():
    c = M.ScriptedChurn(events=(("crash", 1, 0), ("crash", 1, 1)))
    with pytest.raises(ValueError, match="zero live"):
        c.live_mask(1, 2)


def test_scripted_churn_rejects_bad_events():
    with pytest.raises(ValueError, match="event kind"):
        M.ScriptedChurn(events=(("explode", 1, 0),))
    with pytest.raises(ValueError, match="slot"):
        M.ScriptedChurn(events=(("crash", 1, 9),)).live_mask(0, 2)


def test_random_churn_deterministic_in_seed_round():
    c1 = M.RandomChurn(p_fail=0.4, p_join=0.5, seed=7)
    c2 = M.RandomChurn(p_fail=0.4, p_join=0.5, seed=7)
    traces = [[list(c.live_mask(r, 5)) for r in range(8)] for c in (c1, c2)]
    assert traces[0] == traces[1]
    assert any(sum(t) < 5 for t in traces[0])     # churn actually happened
    other = [list(M.RandomChurn(p_fail=0.4, seed=8).live_mask(r, 5))
             for r in range(8)]
    assert other != traces[0]


def test_random_churn_sole_survivor():
    c = M.RandomChurn(p_fail=1.0, p_join=0.0, seed=0)
    for r in range(4):
        assert int(c.live_mask(r, 4).sum()) == (4 if r == 0 else 1)


def test_static_schedules_and_registry():
    assert M.NoChurn().is_static
    assert M.ScriptedChurn().is_static
    assert M.RandomChurn(p_fail=0.0).is_static
    assert not M.RandomChurn(p_fail=0.0, initial_live=2).is_static
    assert isinstance(M.get_churn(None), M.NoChurn)
    assert isinstance(M.get_churn("random", p_fail=0.3), M.RandomChurn)
    with pytest.raises(KeyError, match="unknown churn"):
        M.get_churn("nope")


# ---------------------------------------------------------------------------
# Static-K reduction: all-live is bit-identical to the pre-membership path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["python", "fused"])
@pytest.mark.parametrize("codec", ["exact", "fused"])
def test_all_live_bit_identical_to_static(engine, codec):
    _, base = run_rounds(engine=engine, codec=codec, churn=None)
    for static in ("none", M.NoChurn(), M.ScriptedChurn()):
        _, st = run_rounds(engine=engine, codec=codec, churn=static)
        assert max_abs_diff(base["params"], st["params"]) == 0.0
        assert st["membership"].live == (True,) * 4


# ---------------------------------------------------------------------------
# Cross-engine agreement under churn
# ---------------------------------------------------------------------------
CHURN = M.ScriptedChurn(events=(("crash", 1, 1), ("rejoin", 3, 1),
                                ("crash", 2, 3)))


def test_engines_agree_under_churn():
    _, sp = run_rounds(engine="python", churn=CHURN)
    _, sf = run_rounds(engine="fused", churn=CHURN)
    assert sp["membership"].events == sf["membership"].events
    assert ([l.live for l in sp["log"]] == [l.live for l in sf["log"]]
            == [4, 3, 2, 3])
    assert max_abs_diff(sp["params"], sf["params"]) <= 1e-5
    for lp, lf in zip(sp["log"], sf["log"]):
        assert np.allclose(lp.local_losses, lf.local_losses, atol=1e-5)
        assert lp.comm_bytes == lf.comm_bytes


def test_dead_slot_is_identity_carry():
    # slot 1 dies at round 1 and stays dead: its params AND opt rows must
    # be frozen at their end-of-round-0 values through rounds 1 and 2
    # (momentum so the optimizer state is a non-empty pytree)
    churn = M.ScriptedChurn(events=(("crash", 1, 1),))
    for engine in ("python", "fused"):
        cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05,
                            epsilon=1e-9, max_rounds=3)
        learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                            churn=churn, optimizer_name="momentum")
        state = learner.init(tiny_params())
        batches = tiny_batches(3, 3, 2)
        state = learner.run_round(state, lambda i, j: batches)
        frozen_p = jax.tree.map(lambda t: np.asarray(t[1]), state["params"])
        frozen_o = jax.tree.map(lambda t: np.asarray(t[1]), state["opt"])
        for _ in range(2):
            state = learner.run_round(state, lambda i, j: batches)
            assert max_abs_diff(
                frozen_p, jax.tree.map(lambda t: t[1], state["params"])) == 0
            assert max_abs_diff(
                frozen_o, jax.tree.map(lambda t: t[1], state["opt"])) == 0
        # ...and the live slots kept training
        assert max_abs_diff(
            frozen_p, jax.tree.map(lambda t: t[0], state["params"])) > 0


def test_rejoin_warm_starts_from_synced_model():
    # the round a slot rejoins, run_round resets it from the last synced
    # shared model before training — not from its stale pre-crash row
    churn = M.ScriptedChurn(events=(("crash", 1, 1), ("rejoin", 2, 1)))
    cfg = CoLearnConfig(n_participants=3, T0=1, eta0=0.0, epsilon=1e-9,
                        max_rounds=3)
    learner = CoLearner(cfg, tiny_loss, round_engine="python", churn=churn)
    state = learner.init(tiny_params())
    batches = tiny_batches(3, 2, 2)
    for _ in range(2):
        state = learner.run_round(state, lambda i, j: batches)
    ref = jax.tree.map(np.asarray, state["prev_avg"])
    state = learner.run_round(state, lambda i, j: batches)
    # eta0=0 -> training is a no-op, so slot 1 now holds exactly the model
    # it was warm-started from, averaged over the (all-equal) live rows
    assert max_abs_diff(ref, jax.tree.map(lambda t: t[1],
                                          state["params"])) <= 1e-6


# ---------------------------------------------------------------------------
# Live-renormalized aggregation
# ---------------------------------------------------------------------------
def test_full_average_renormalizes_over_live():
    live = np.array([True, False, True, False])
    W = api.FullAverage().mixing_matrix(0, 4, live=live)
    expect = np.array([0.5, 0.0, 0.5, 0.0], np.float32)
    assert np.allclose(W, np.tile(expect, (4, 1)))
    # weighted: dead weights drop out, live weights renormalize
    Ww = api.FullAverage(weights=(1.0, 2.0, 3.0, 4.0)).mixing_matrix(
        0, 4, live=live)
    assert np.allclose(Ww[0], [0.25, 0.0, 0.75, 0.0])
    with pytest.raises(ValueError, match="live"):
        api.FullAverage().mixing_matrix(0, 4, live=np.zeros(4, bool))


def test_full_average_live_numeric():
    # a churn round's average is the mean over LIVE rows only
    churn = M.ScriptedChurn(events=(("crash", 1, 2),))
    cfg = CoLearnConfig(n_participants=3, T0=1, eta0=0.05, epsilon=1e-9,
                        max_rounds=2)
    learner = CoLearner(cfg, tiny_loss, round_engine="python", churn=churn)
    state = learner.init(tiny_params())
    batches = tiny_batches(3, 2, 2)
    state = learner.run_round(state, lambda i, j: batches)
    # round 1: slot 2 dead; live slots 0, 1 train then average
    state = learner.run_round(state, lambda i, j: batches)
    w = np.asarray(state["params"]["w"])
    assert np.allclose(w[0], w[1], atol=1e-6)      # live rows share the avg
    assert not np.allclose(w[0], w[2], atol=1e-6)  # dead row carried


def test_partial_participation_samples_only_live():
    agg = api.PartialParticipation(m=3, seed=0)
    live = np.array([True, False, True, False, True])
    for i in range(6):
        W = agg.mixing_matrix(i, 5, live=live)
        assert np.allclose(W[:, [1, 3]], 0.0)      # dead never sampled
        assert np.isclose(W[0].sum(), 1.0)
    # m_eff shrinks to the live count instead of erroring
    W = agg.mixing_matrix(0, 5, live=np.array([True] + [False] * 4))
    assert np.allclose(W[:, 0], 1.0)
    with pytest.raises(ValueError, match="zero live"):
        agg.mixing_matrix(0, 5, live=np.zeros(5, bool))


def test_ring_gossip_routes_around_dead():
    live = np.array([True, False, True, True])
    W = api.RingGossip().mixing_matrix(0, 4, live=live)
    assert np.allclose(W[1], [0, 1, 0, 0])         # dead row: identity
    assert np.allclose(W[0], [0.5, 0, 0, 0.5])     # pred 3 live
    assert np.allclose(W[2], [0.5, 0, 0.5, 0])     # pred 1 dead -> 0
    assert np.allclose(W[3], [0, 0, 0.5, 0.5])
    # sole survivor: nobody to gossip with
    W1 = api.RingGossip().mixing_matrix(0, 3,
                                        live=np.array([False, True, False]))
    assert np.allclose(W1, np.eye(3))


def test_comm_bytes_live_aware():
    stacked = {"w": jnp.zeros((4, 8))}
    codec = api.ExactF32()
    live2 = np.array([True, True, False, False])
    ring = api.RingGossip()
    assert ring.comm_bytes(codec, stacked, 0) == ring.comm_bytes(
        codec, stacked, 0, live=live2)
    assert ring.comm_bytes(
        codec, stacked, 0, live=np.array([True, False, False, False])) == 0
    part = api.PartialParticipation(m=3)
    # m_eff=2 of 2 live: every live row uploads; static bill amortizes 3/4
    assert (part.comm_bytes(codec, stacked, 0, live=live2)
            > part.comm_bytes(codec, stacked, 0))


def test_divergence_live_masked():
    stacked = {"w": jnp.stack([jnp.ones(3), 3 * jnp.ones(3),
                               99 * jnp.ones(3)])}
    ref = {"w": jnp.ones(3) * 2.0}
    full = sched_mod.divergence(stacked, ref)
    all_live = sched_mod.divergence(stacked, ref, live=np.ones(3, bool))
    assert np.isclose(full, all_live)
    # masking out the wild slot 2 removes its drift from the signal
    masked = sched_mod.divergence(stacked, ref,
                                  live=np.array([True, True, False]))
    assert masked < full
    expect = np.sqrt(3.0) / np.linalg.norm(np.asarray(ref["w"]))
    assert np.isclose(masked, expect, atol=1e-6)


# ---------------------------------------------------------------------------
# restart_participant resets from the SYNCED model (satellite bugfix)
# ---------------------------------------------------------------------------
def test_restart_resets_from_sync_ref_not_slot0_ring():
    # under RingGossip rows stay distinct, and once slot 0 is dead its row
    # is a STALE carry — the old slot-0 reset would hand the restarted
    # peer that stale pre-crash model instead of the sync reference
    churn = M.ScriptedChurn(events=(("crash", 1, 0),))
    learner, state = run_rounds(rounds=2, K=4, engine="python",
                                aggregator="ring", churn=churn)
    row0 = jax.tree.map(lambda t: np.asarray(t[0]), state["params"])
    ref = jax.tree.map(np.asarray, learner._sync_ref(state))
    assert max_abs_diff(row0, ref) > 0             # the bug was observable
    learner.restart_participant(state, 2)
    got = jax.tree.map(lambda t: np.asarray(t[2]), state["params"])
    assert max_abs_diff(got, ref) == 0.0
    fresh = learner.opt.init(learner._sync_ref(state))
    assert max_abs_diff(jax.tree.map(lambda t: t[2], state["opt"]),
                        fresh) == 0.0


def test_restart_resets_from_sync_ref_after_quiet_round():
    # a DivergenceTrigger quiet round leaves slot 0 locally drifted; the
    # restart must come from prev_avg (the last SYNCED model), not slot 0
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=1e-9,
                        max_rounds=4)
    learner = CoLearner(cfg, tiny_loss, round_engine="python",
                        sync_policy=api.DivergenceTrigger(delta=0.0))
    state = learner.init(tiny_params())
    batches = tiny_batches(3, 3, 2)
    state = learner.run_round(state, lambda i, j: batches)   # syncs
    assert state["log"][-1].synced
    learner.set_sync_policy(api.DivergenceTrigger(delta=1e9))
    state = learner.run_round(state, lambda i, j: batches)   # quiet
    assert not state["log"][-1].synced
    ref = jax.tree.map(np.asarray, state["prev_avg"])
    row0 = jax.tree.map(lambda t: np.asarray(t[0]), state["params"])
    assert max_abs_diff(row0, ref) > 0
    learner.restart_participant(state, 1)
    got = jax.tree.map(lambda t: np.asarray(t[1]), state["params"])
    assert max_abs_diff(got, ref) == 0.0


# ---------------------------------------------------------------------------
# Membership-aware sync policies
# ---------------------------------------------------------------------------
def test_ile_holds_doubling_on_membership_events():
    pol = api.ILE(epsilon=0.1)
    st = api.SyncState(T=2)
    held = pol.update(st, 0, 0.0, events=((0, 1, "leave"),))
    assert held.T == 2
    doubled = pol.update(st, 0, 0.0)
    assert doubled.T == 4
    # FLE ignores events entirely
    assert api.FLE().update(st, 0, 0.0, events=((0, 1, "leave"),)).T == 2


def test_divergence_trigger_forces_sync_on_membership_change():
    pol = api.DivergenceTrigger(delta=0.5)
    assert pol.round_delta(()) == 0.5
    assert pol.round_delta(((3, 1, "join"),)) == -1.0
    assert pol.should_sync(0.01, 3, delta=-1.0)    # any div > -1 syncs
    assert not pol.should_sync(0.01, 3, delta=0.5)
    # a learner under churn: the join round syncs even though models agree
    churn = M.ScriptedChurn(events=(("crash", 1, 1), ("rejoin", 2, 1)))
    cfg = CoLearnConfig(n_participants=3, T0=1, eta0=1e-6, epsilon=1e-9,
                        max_rounds=3)
    for engine in ("python", "fused"):
        learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                            churn=churn,
                            sync_policy=api.DivergenceTrigger(delta=1e9))
        state = learner.init(tiny_params())
        batches = tiny_batches(3, 2, 2)
        for _ in range(3):
            state = learner.run_round(state, lambda i, j: batches)
        assert [l.synced for l in state["log"]] == [False, True, True]


def test_round_log_live_counts():
    _, st = run_rounds(rounds=3, engine="fused")
    assert [l.live for l in st["log"]] == [4, 4, 4]
    _, st = run_rounds(rounds=3, engine="fused", churn=CHURN)
    assert [l.live for l in st["log"]] == [4, 3, 2]


# ---------------------------------------------------------------------------
# Checkpoint compatibility + resume parity (satellite)
# ---------------------------------------------------------------------------
def test_checkpoint_membership_roundtrip(tmp_path):
    from repro.checkpoint.io import restore_round_state, save_round_state
    learner, state = run_rounds(rounds=3, engine="python", churn=CHURN)
    path = str(tmp_path / "ck")
    save_round_state(path, state)
    fresh = learner.init(tiny_params(key=1))
    restored = restore_round_state(path, fresh)
    assert restored["membership"] == state["membership"]
    assert max_abs_diff(restored["params"], state["params"]) == 0.0


def test_pre_membership_checkpoint_restores_all_live(tmp_path):
    from repro.checkpoint.io import restore_round_state, save_round_state
    learner, state = run_rounds(rounds=2, engine="python")
    prev_avg = jax.tree.map(np.asarray, state["prev_avg"])
    ctrl = state["ctrl"]
    state.pop("membership")                 # simulate a pre-ISSUE-6 save
    path = str(tmp_path / "legacy")
    save_round_state(path, state)
    restored = restore_round_state(path, learner.init(tiny_params(key=1)))
    assert restored["membership"] == M.Membership.all_live(4)
    assert max_abs_diff(restored["prev_avg"], prev_avg) == 0.0
    assert restored["ctrl"] == ctrl


@pytest.mark.parametrize("engine", ["python", "fused"])
def test_resume_parity_under_scripted_churn(tmp_path, engine):
    from repro.checkpoint.io import restore_round_state, save_round_state
    churn = M.ScriptedChurn(events=(("crash", 1, 1), ("rejoin", 3, 1)))
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=1e-9,
                        max_rounds=4)
    batches = tiny_batches(3, 3, 2)

    def make():
        learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                            churn=churn)
        return learner, learner.init(tiny_params())

    learner, state = make()
    for _ in range(4):
        state = learner.run_round(state, lambda i, j: batches)

    learner2, st2 = make()
    for _ in range(2):
        st2 = learner2.run_round(st2, lambda i, j: batches)
    path = str(tmp_path / "mid")
    save_round_state(path, st2)
    learner3, st3 = make()
    st3 = restore_round_state(path, st3)
    for _ in range(2):
        st3 = learner3.run_round(st3, lambda i, j: batches)

    assert st3["membership"].events == state["membership"].events
    assert max_abs_diff(st3["params"], state["params"]) <= 1e-6


# ---------------------------------------------------------------------------
# K_max standby slots (pipeline padding)
# ---------------------------------------------------------------------------
def test_pipeline_k_max_pads_by_cycling_shards():
    rng = np.random.default_rng(0)
    shards = [[rng.normal(size=(6 + 2 * k, 3))] for k in range(2)]
    data = ParticipantData(shards, batch_size=2, k_max=5)
    assert data.K == 5 and data.n_shards == 2
    # slot K+i serves shards[i % K]
    assert data.shards[2] is shards[0]
    assert data.shards[3] is shards[1]
    assert data.shards[4] is shards[0]
    bx, = data.epoch_batches(0, 0)
    assert bx.shape[0] == 5
    # a padding slot trains on ITS shard's real examples (own shuffle)
    shard0_rows = {tuple(r) for r in shards[0][0]}
    assert {tuple(r) for r in bx[2].reshape(-1, 3)} <= shard0_rows
    # full() concatenates each REAL shard exactly once
    assert len(data.full()[0]) == 6 + 8
    with pytest.raises(ValueError, match="k_max"):
        ParticipantData(shards, batch_size=2, k_max=1)


def test_standby_slot_joins_with_real_data():
    # 2 real participants + 1 standby slot that joins at round 1
    churn = M.ScriptedChurn(events=(("rejoin", 1, 2),), initial_live=2)
    cfg = CoLearnConfig(n_participants=3, T0=1, eta0=0.05, epsilon=1e-9,
                        max_rounds=3)
    learner = CoLearner(cfg, tiny_loss, round_engine="fused", churn=churn)
    state = learner.init(tiny_params())
    batches = tiny_batches(3, 2, 2)
    assert state["membership"].live == (True, True, False)
    for _ in range(3):
        state = learner.run_round(state, lambda i, j: batches)
    assert [l.live for l in state["log"]] == [2, 3, 3]
    assert state["membership"].events == ((1, 2, "join"),)


# ---------------------------------------------------------------------------
# train.py flag surface (parse-time validation, satellite)
# ---------------------------------------------------------------------------
def _train_main(argv):
    from repro.launch.train import main
    return main(argv)


@pytest.mark.parametrize("argv, msg", [
    (["--aggregator", "partial", "--participants", "3", "--partial-m", "5"],
     "exceeds"),
    (["--aggregator", "partial", "--partial-m", "0"], ">= 1"),
    (["--churn-events", "crash:1:1"], "--churn scripted"),
    (["--churn-p", "0.5"], "--churn random"),
    (["--k-max", "8"], "--k-max requires --churn"),
    (["--churn", "random", "--k-max", "2", "--participants", "5"],
     "smaller than"),
    (["--churn", "scripted", "--churn-events", "crash:oops:1"],
     "kind:round:slot"),
])
def test_train_flag_validation_at_parse_time(argv, msg, capsys):
    with pytest.raises(SystemExit) as exc:
        _train_main(argv)
    assert exc.value.code == 2
    assert msg in capsys.readouterr().err


def test_churn_registry_spellings_match_train_choices():
    # the CLI choices and the registry must not drift apart
    assert set(M.CHURN_SCHEDULES) == {"none", "scripted", "random"}


def test_naive_membership_keeps_static_matrix():
    # the ablation arm: dead rows keep their 1/K weight in the average
    churn = M.ScriptedChurn(events=(("crash", 1, 2),))
    cfg = CoLearnConfig(n_participants=3, T0=1, eta0=0.05, epsilon=1e-9,
                        max_rounds=2)
    out = {}
    for aware in (True, False):
        learner = CoLearner(cfg, tiny_loss, round_engine="python",
                            churn=churn, liveness_aware=aware)
        state = learner.init(tiny_params())
        batches = tiny_batches(3, 2, 2)
        for _ in range(2):
            state = learner.run_round(state, lambda i, j: batches)
        out[aware] = state["params"]
    w_aware = np.asarray(out[True]["w"])
    w_naive = np.asarray(out[False]["w"])
    # aware: live rows hold the live-only mean; naive: the stale dead row
    # polluted the mean, so the live rows differ between the two runs
    assert not np.allclose(w_aware[0], w_naive[0], atol=1e-7)
    # both carry the dead row identically (engine-side identity carry is
    # independent of the mixing matrix)
    np.testing.assert_allclose(w_aware[2], w_naive[2], atol=1e-7)
