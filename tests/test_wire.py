"""The bit-width-general error-feedback wire (LeafwiseIntN / FlatFusedIntN).

Pins the tentpole contracts:
  * ``bits=8, error_feedback=False`` reduces bit-for-bit to the legacy
    LeafwiseInt8 / FlatFusedInt8 codecs — both engines;
  * error feedback threads the residual through init/run_round/checkpoint/
    restart consistently (resume == uninterrupted);
  * wire-byte accounting matches the actual encoded payload and int4 cuts
    the quantized payload ~2x vs int8;
  * EF at 4 bits converges comparably to the int8 wire on a small task.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.checkpoint import io as ckpt_io

K, D = 3, 48
CFG = CoLearnConfig(n_participants=K, T0=2, max_rounds=6)


def loss_fn(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - y) ** 2), {}


def init_params(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(D,)), jnp.float32),
            "b": jnp.float32(0.0)}


def make_batches(seed):
    """Deterministic (round, epoch) -> batch pytree (cached, replayable)."""
    cache = {}

    def fn(i, j):
        if (i, j) not in cache:
            r = np.random.default_rng((seed, i, j))
            x = jnp.asarray(r.normal(size=(K, 2, 8, D)), jnp.float32)
            w = np.arange(1.0, D + 1) / D
            y = jnp.asarray(x @ w + 0.01 * r.normal(size=(K, 2, 8)),
                            jnp.float32)
        else:
            return cache[(i, j)]
        cache[(i, j)] = (x, y)
        return cache[(i, j)]
    return fn


def run(codec, engine, rounds=3, seed=1, **kw):
    learner = CoLearner(CFG, loss_fn, codec=codec, round_engine=engine, **kw)
    state = learner.init(init_params())
    bf = make_batches(seed)
    for _ in range(rounds):
        state = learner.run_round(state, bf)
    return learner, state


ENGINES = ["python", api.FusedEngine(chunk=32), api.FusedEngine(chunk=1)]


# ---------------------------------------------------------------------------
# bits=8, error_feedback=False == the legacy int8 codecs, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("family,legacy", [
    (api.LeafwiseIntN, api.LeafwiseInt8),
    (api.FlatFusedIntN, api.FlatFusedInt8),
])
def test_bits8_no_ef_bit_identical_to_legacy(engine, family, legacy):
    _, s_new = run(family(bits=8), engine)
    _, s_old = run(legacy(), engine)
    for a, b in zip(jax.tree.leaves(s_new["params"]),
                    jax.tree.leaves(s_old["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_registry_returns_legacy_classes_at_bits8():
    """The registry factories collapse to the pinned Int8 classes at the
    legacy point, so isinstance pins (and their pod fast paths) hold."""
    assert isinstance(api.get_codec("leafwise"), api.LeafwiseInt8)
    assert isinstance(api.get_codec("fused"), api.FlatFusedInt8)
    c4 = api.get_codec("leafwise", bits=4)
    assert isinstance(c4, api.LeafwiseIntN)
    assert not isinstance(c4, api.LeafwiseInt8) and c4.bits == 4
    cef = api.get_codec("fused", bits=1, error_feedback=True)
    assert isinstance(cef, api.FlatFusedIntN) and cef.stateful
    assert cef.name == "fused-int1+ef"
    assert api.get_codec("leafwise", bits=4, error_feedback=True).name == \
        "leafwise-int4+ef"


# ---------------------------------------------------------------------------
# EF threading: engines agree; gated rounds / churn / restart semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", [api.LeafwiseIntN, api.FlatFusedIntN])
@pytest.mark.parametrize("bits", [4, 1])
def test_ef_python_and_fused_engines_agree(family, bits):
    codec = family(bits=bits, error_feedback=True)
    _, sp = run(codec, "python")
    _, sf = run(codec, api.FusedEngine(chunk=32))
    _, sc = run(codec, api.FusedEngine(chunk=1))       # chunked finalize
    for s_other in (sf, sc):
        for a, b in zip(jax.tree.leaves(sp["params"]),
                        jax.tree.leaves(s_other["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-6, atol=1e-6)
        for a, b in zip(jax.tree.leaves(sp["residual"]),
                        jax.tree.leaves(s_other["residual"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-6, atol=1e-6)


@pytest.mark.parametrize("engine", ENGINES)
def test_ef_quiet_round_leaves_residual_untouched(engine):
    """A divergence-gated quiet round quantizes nothing — the residual must
    carry through unchanged (zero, since no sync ever happened)."""
    learner = CoLearner(
        CFG, loss_fn, codec=api.LeafwiseIntN(bits=4, error_feedback=True),
        round_engine=engine, sync_policy=api.DivergenceTrigger(delta=1e9))
    state = learner.init(init_params())
    bf = make_batches(2)
    for _ in range(2):
        state = learner.run_round(state, bf)
    assert not state["log"][-1].synced
    for leaf in jax.tree.leaves(state["residual"]):
        assert np.allclose(np.asarray(leaf), 0.0)


def test_ef_restart_zeroes_participant_residual():
    learner, state = run(api.FlatFusedIntN(bits=4, error_feedback=True),
                         api.FusedEngine(chunk=32))
    assert not np.allclose(np.asarray(state["residual"]), 0.0)
    learner.restart_participant(state, 1)
    res = np.asarray(state["residual"])
    assert np.allclose(res[1], 0.0)
    assert not np.allclose(res[0], 0.0)    # other slots keep their memory


def test_ef_dead_slot_freezes_residual():
    from repro.core.membership import ScriptedChurn
    codec = api.FlatFusedIntN(bits=4, error_feedback=True)
    learner = CoLearner(CFG, loss_fn, codec=codec,
                        round_engine=api.FusedEngine(chunk=32),
                        churn=ScriptedChurn(events=(("crash", 1, 2),)))
    state = learner.init(init_params())
    bf = make_batches(3)
    state = learner.run_round(state, bf)              # round 0: all live
    frozen = np.asarray(state["residual"])[2].copy()
    assert not np.allclose(frozen, 0.0)
    for _ in range(2):                                # rounds 1-2: slot 2 dead
        state = learner.run_round(state, bf)
    np.testing.assert_array_equal(np.asarray(state["residual"])[2], frozen)


# ---------------------------------------------------------------------------
# checkpoint: resumed EF run == uninterrupted EF run
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["python", api.FusedEngine(chunk=32)])
@pytest.mark.parametrize("codec", [
    api.LeafwiseIntN(bits=4, error_feedback=True),
    api.FlatFusedIntN(bits=1, error_feedback=True),
])
def test_ef_resume_matches_uninterrupted(tmp_path, engine, codec):
    bf = make_batches(5)
    straight_learner = CoLearner(CFG, loss_fn, codec=codec,
                                 round_engine=engine)
    straight = straight_learner.init(init_params())
    for _ in range(4):
        straight = straight_learner.run_round(straight, bf)

    first = CoLearner(CFG, loss_fn, codec=codec, round_engine=engine)
    state = first.init(init_params())
    for _ in range(2):
        state = first.run_round(state, bf)
    path = os.path.join(tmp_path, "ck")
    ckpt_io.save_round_state(path, state)
    assert os.path.exists(path + ".residual.npz")

    resumed_learner = CoLearner(CFG, loss_fn, codec=codec,
                                round_engine=engine)
    resumed = resumed_learner.init(init_params())
    resumed = ckpt_io.restore_round_state(path, resumed)
    for _ in range(2):
        resumed = resumed_learner.run_round(resumed, bf)

    for a, b in zip(jax.tree.leaves(straight["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree.leaves(straight["residual"]),
                    jax.tree.leaves(resumed["residual"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_legacy_checkpoint_restores_zero_residual(tmp_path):
    """A checkpoint written without EF memory (pre-EF or stateless-codec
    run) restores into an EF learner with the documented zero residual."""
    codec = api.FlatFusedIntN(bits=4, error_feedback=True)
    learner, state = run(codec, "python", rounds=2)
    path = os.path.join(tmp_path, "ck")
    ckpt_io.save_round_state(path, state)
    os.remove(path + ".residual.npz")
    with open(path + ".meta.json") as f:
        meta = json.load(f)
    del meta["has_residual"]
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)
    fresh = learner.init(init_params())
    fresh = ckpt_io.restore_round_state(path, fresh)
    for leaf in jax.tree.leaves(fresh["residual"]):
        assert np.allclose(np.asarray(leaf), 0.0)


# ---------------------------------------------------------------------------
# byte accounting + convergence
# ---------------------------------------------------------------------------
def big_tree(K=3, seed=11):
    """Stacked tree dominated by quantizable leaves (realistic billing)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {"w": jax.random.normal(ks[0], (K, 8, 256)),
            "odd": jax.random.normal(ks[1], (K, 700)),
            "tiny": jax.random.normal(ks[2], (K, 5))}


@pytest.mark.parametrize("name", ["leafwise", "fused"])
def test_int4_wire_bytes_at_least_1p9x_smaller(name):
    tree = big_tree()
    b8 = api.get_codec(name).wire_bytes(tree)
    b4 = api.get_codec(name, bits=4).wire_bytes(tree)
    b1 = api.get_codec(name, bits=1).wire_bytes(tree)
    assert b8 / b4 >= 1.9
    assert b4 / b1 > 1.9           # 1-bit keeps shrinking (scales remain)
    # error feedback is device-side state — it never changes the wire
    assert api.get_codec(name, bits=4,
                         error_feedback=True).wire_bytes(tree) == b4


def test_ef_int4_converges_within_tolerance_of_int8():
    """On the quadratic task, the int4+EF wire's final round loss stays
    within 10% of the int8 wire's (1-bit+EF within 2x) — the residual
    memory is what makes the sub-int8 wire trainable."""
    losses = {}
    for label, codec in [
        ("int8", api.FlatFusedIntN(bits=8)),
        ("int4+ef", api.FlatFusedIntN(bits=4, error_feedback=True)),
        ("1bit+ef", api.FlatFusedIntN(bits=1, error_feedback=True)),
    ]:
        _, state = run(codec, api.FusedEngine(chunk=32), rounds=5, seed=9)
        losses[label] = float(np.mean(state["log"][-1].local_losses))
    assert losses["int4+ef"] <= losses["int8"] * 1.10
    assert losses["1bit+ef"] <= losses["int8"] * 2.0
