"""Communication-topology subsystem (repro.core.topology) and the
graph-structured gossip aggregators (api.GraphGossip / api.D2Gossip):
matrix invariants, connectivity guards, liveness routing, the legacy
RingGossip parity pins, and the D² round-state plumbing."""
import math
import os
import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core import topology as topo
from repro.core.colearn import CoLearner
from repro.core.membership import ScriptedChurn
from repro.checkpoint.io import restore_round_state, save_round_state


def tiny_loss(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, {"loss": loss}


def tiny_params(key=0, d=4):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}


def tiny_batches(K, seed=0, identical=False):
    k = jax.random.PRNGKey(seed)
    shape = (1 if identical else K, 3, 8, 4)
    x = jax.random.normal(k, shape)
    if identical:
        x = jnp.broadcast_to(x, (K,) + shape[1:])
    w_true = jnp.arange(1.0, 5.0)[:, None]
    return (x, x @ w_true)


def max_abs_diff(a, b):
    return max(float(jnp.abs(jnp.asarray(x, jnp.float32)
                             - jnp.asarray(y, jnp.float32)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def run_rounds(agg, K=4, rounds=3, engine="python", codec=None,
               batches=None, **kw):
    cfg = CoLearnConfig(n_participants=K, T0=1, eta0=0.05, epsilon=0.5,
                        max_rounds=rounds + 2)
    learner = CoLearner(cfg, tiny_loss, codec=codec, aggregator=agg,
                        round_engine=engine, **kw)
    state = learner.init(tiny_params())
    b = tiny_batches(K) if batches is None else batches
    for _ in range(rounds):
        state = learner.run_round(state, lambda i, j: b)
    return learner, state


# every registered topology with the Ks it is defined at (hypercube needs
# powers of two; default erdos_renyi draws are only guaranteed connected
# at the pinned (p, seed) choices below)
TOPO_CASES = [
    ("ring", topo.RingTopology(), (1, 2, 3, 4, 5, 8)),
    ("grid2d", topo.Grid2DTopology(), (1, 2, 3, 4, 6, 8, 9)),
    ("hypercube", topo.HypercubeTopology(), (1, 2, 4, 8)),
    ("exponential", topo.ExponentialTopology(), (1, 2, 3, 4, 5, 8)),
    ("erdos_renyi", topo.ErdosRenyiTopology(p=0.9, seed=2), (2, 4, 6)),
    ("complete", topo.CompleteTopology(), (1, 2, 3, 5, 8)),
]


# ---------------------------------------------------------------------------
# Topology invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,t,Ks", TOPO_CASES,
                         ids=[c[0] for c in TOPO_CASES])
def test_mixing_matrix_doubly_stochastic(name, t, Ks):
    """All-live mixing is nonnegative and doubly stochastic (rows AND
    columns sum to 1 +- 1e-6) at every round of the period; symmetric
    topologies yield symmetric matrices; spectral gap > 0."""
    for K in Ks:
        t.validate(K)
        for r in range(t.period(K)):
            W = t.mixing_matrix(r, K)
            assert W.shape == (K, K) and W.dtype == np.float32
            assert (W >= 0).all()
            np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)
            np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-6)
            if t.symmetric:
                np.testing.assert_allclose(W, W.T, atol=1e-7)
        assert t.spectral_gap(K) > 0.0


@pytest.mark.parametrize("name,t,Ks", TOPO_CASES,
                         ids=[c[0] for c in TOPO_CASES])
def test_edge_perms_cover_adjacency(name, t, Ks):
    """Where a permutation decomposition exists, each perm is a whole
    permutation of {0..K-1} and together they cover the directed edge
    set exactly once."""
    for K in Ks:
        for r in range(t.period(K)):
            perms = t.edge_perms(r, K)
            if perms is None:
                continue
            A = t.adjacency(r, K)
            covered = np.zeros((K, K), int)
            for perm in perms:
                assert len(perm) == K
                assert sorted(s for s, _ in perm) == list(range(K))
                assert sorted(d for _, d in perm) == list(range(K))
                for s, d in perm:
                    covered[d, s] += 1
            assert (covered[A] == 1).all(), (name, K, r)
            assert (covered[~A] == 0).all(), (name, K, r)


@pytest.mark.parametrize("name,t,Ks", TOPO_CASES,
                         ids=[c[0] for c in TOPO_CASES])
def test_live_masked_matrix_row_stochastic(name, t, Ks):
    """Liveness keeps every row stochastic, gives dead rows identity
    carries, and never mixes a live row with a dead column."""
    for K in [k for k in Ks if k >= 3]:
        live = np.ones(K, bool)
        live[1] = False
        for r in range(t.period(K)):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                W = t.mixing_matrix(r, K, live=live)
            assert (W >= 0).all()
            np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)
            assert W[1, 1] == 1.0 and np.count_nonzero(W[1]) == 1
            assert (W[live][:, ~live] == 0).all()
        # sole survivor: identity row
        alone = np.zeros(K, bool)
        alone[0] = True
        W = t.mixing_matrix(0, K, live=alone)
        assert W[0, 0] == 1.0 and np.count_nonzero(W[0]) == 1


def test_connectivity_guard_rejects_disconnected():
    with pytest.raises(ValueError, match="disconnected at K=4"):
        topo.ErdosRenyiTopology(p=0.05, seed=0).validate(4)
    # the error carries the reseed hint
    with pytest.raises(ValueError, match="different seed or a larger p"):
        topo.ErdosRenyiTopology(p=0.05, seed=0).validate(4)


def test_hypercube_rejects_non_power_of_two():
    with pytest.raises(ValueError, match="power of two"):
        topo.HypercubeTopology().validate(6)


def test_component_split_warns_and_mixes_blockwise():
    """2x2 torus with the diagonal pair {0, 3} live: no surviving edge
    connects them, so the live subgraph is split — mixing degrades to
    identity (component-wise) and a RuntimeWarning is logged."""
    t = topo.Grid2DTopology()
    live = np.array([True, False, False, True])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        W = t.mixing_matrix(0, 4, live=live)
    assert any("component-wise" in str(x.message) for x in w)
    np.testing.assert_array_equal(W, np.eye(4, dtype=np.float32))


def test_ring_matrix_pins_legacy_gossip():
    """RingTopology (and so RingGossip) reproduces the pre-topology
    hand-rolled matrices bit-for-bit: all-live 0.5/0.5 predecessor rows,
    and liveness routing to the nearest LIVE predecessor."""
    t = topo.RingTopology()
    for K in (1, 2, 3, 5, 8):
        W = t.mixing_matrix(0, K)
        ref = np.zeros((K, K), np.float32)
        for k in range(K):
            ref[k, k] += 0.5
            ref[k, (k - 1) % K] += 0.5
        np.testing.assert_array_equal(W, ref)
    # routed live case: 0 receives from 4 (skipping dead 1..2 is wrap),
    # 3 receives from 0, 4 receives from 3; dead rows identity
    W = t.mixing_matrix(0, 5, live=[1, 0, 0, 1, 1])
    assert W[0, 4] == 0.5 and W[3, 0] == 0.5 and W[4, 3] == 0.5
    assert W[1, 1] == 1.0 and W[2, 2] == 1.0
    with pytest.raises(ValueError, match="zero live participants"):
        t.mixing_matrix(3, 4, live=[0, 0, 0, 0])


def test_exponential_period_union_is_exponential_graph():
    t = topo.ExponentialTopology()
    K = 8
    assert t.period(K) == 3
    U = t.union_adjacency(K)
    k = np.arange(K)
    for d in (1, 2, 4):
        assert U[k, (k - d) % K].all()
    assert topo.is_connected(U)
    # a single round is one offset: degree 1, O(1) wire
    for r in range(t.period(K)):
        assert t.degree(r, K) == 1


def test_registry_and_get_topology():
    assert isinstance(topo.get_topology(None), topo.RingTopology)
    assert isinstance(topo.get_topology("torus"), topo.Grid2DTopology)
    er = topo.get_topology("erdos_renyi", p=0.9, seed=2)
    assert er.p == 0.9 and er.seed == 2
    inst = topo.HypercubeTopology()
    assert topo.get_topology(inst) is inst
    with pytest.raises(KeyError, match="unknown topology"):
        topo.get_topology("moebius")
    with pytest.raises(TypeError):
        topo.get_topology(3)


# ---------------------------------------------------------------------------
# GraphGossip / D2Gossip aggregators
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["python", "fused"])
@pytest.mark.parametrize("codec", ["exact", "fused"])
def test_ring_bit_identical_to_graph_ring(engine, codec):
    """The acceptance pin: all-live "ring" (the legacy aggregator, now a
    GraphGossip subclass) is bit-identical to GraphGossip(RingTopology())
    on both engines x exact/flat codecs."""
    _, s1 = run_rounds(api.RingGossip(), engine=engine, codec=codec)
    _, s2 = run_rounds(api.GraphGossip("ring"), engine=engine, codec=codec)
    assert max_abs_diff(s1["params"], s2["params"]) == 0.0
    assert ([l.comm_bytes for l in s1["log"]]
            == [l.comm_bytes for l in s2["log"]])


def test_ring_gossip_is_fixed_to_ring_topology():
    assert isinstance(api.RingGossip().topology, topo.RingTopology)
    with pytest.raises(ValueError, match="fixed to the ring"):
        api.RingGossip(topology="grid2d")


@pytest.mark.parametrize(
    "tname", ["ring", "grid2d", "hypercube", "complete", "exponential"])
def test_d2_matches_plain_gossip_on_identical_shards(tname):
    """With identical shards every local model stays identical, so the D²
    correction is (up to the f32 weight-row rounding) zero and D² IS
    plain gossip — exactly zero for the ring's dyadic weights."""
    b = tiny_batches(4, identical=True)
    _, sg = run_rounds(api.GraphGossip(tname), batches=b)
    _, sd = run_rounds(api.D2Gossip(tname), batches=b)
    tol = 0.0 if tname == "ring" else 1e-5
    assert max_abs_diff(sg["params"], sd["params"]) <= tol
    corr_max = max(float(jnp.abs(t).max())
                   for t in jax.tree.leaves(sd["residual"]))
    assert corr_max <= tol


def test_d2_matches_plain_gossip_on_iid_shards():
    """Satellite pin: on IID (statistically interchangeable) shards D²
    tracks plain gossip within tolerance — the variance it removes is the
    NON-IID drift. Compared on the consensus mean: doubly-stochastic
    mixing preserves it and the D² corrections sum to zero, so the two
    runs drive it to the same optimum."""
    b = tiny_batches(4, seed=3)          # same distribution per shard
    _, sg = run_rounds(api.GraphGossip("grid2d"), batches=b, rounds=8)
    _, sd = run_rounds(api.D2Gossip("grid2d"), batches=b, rounds=8)
    mg = jax.tree.map(lambda t: t.mean(0), sg["params"])
    md = jax.tree.map(lambda t: t.mean(0), sd["params"])
    scale = max(float(jnp.abs(t).max()) for t in jax.tree.leaves(mg))
    assert max_abs_diff(mg, md) <= 0.01 * max(scale, 1.0)
    for s in (sg, sd):                   # both converge on the tiny task
        assert float(np.mean(s["log"][-1].local_losses)) < 1e-4


@pytest.mark.parametrize("engine", ["python", "fused"])
def test_d2_checkpoint_resume_parity(engine):
    """Acceptance pin: resumed-vs-uninterrupted parity for the D² state
    across a checkpoint (the correction rides the PR-7 residual slot and
    must survive save/restore bit-for-bit)."""
    b = tiny_batches(4)

    def fresh():
        cfg = CoLearnConfig(n_participants=4, T0=1, eta0=0.05,
                            epsilon=0.5, max_rounds=6)
        learner = CoLearner(cfg, tiny_loss,
                            aggregator=api.D2Gossip("grid2d"),
                            round_engine=engine)
        return learner, learner.init(tiny_params())

    l1, s1 = fresh()
    for _ in range(4):
        s1 = l1.run_round(s1, lambda i, j: b)
    l2, s2 = fresh()
    for _ in range(2):
        s2 = l2.run_round(s2, lambda i, j: b)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck")
        save_round_state(path, s2)
        l3, s3 = fresh()
        s3 = restore_round_state(path, s3)
    for _ in range(2):
        s3 = l3.run_round(s3, lambda i, j: b)
    assert max_abs_diff(s1["params"], s3["params"]) == 0.0
    assert max_abs_diff(s1["residual"], s3["residual"]) == 0.0


def test_d2_with_error_feedback_codec_composes():
    """An EF codec and D² both carry round state: they ride together as
    {"corr", "res"} through the same slot, and restart_participant zeroes
    participant k's row of BOTH."""
    codec = api.LeafwiseIntN(bits=4, error_feedback=True)
    learner, state = run_rounds(api.D2Gossip("grid2d"), codec=codec,
                                engine="fused", rounds=2)
    assert set(state["residual"].keys()) == {"corr", "res"}
    state = learner.restart_participant(state, 2)
    assert max(float(jnp.abs(t[2]).max())
               for t in jax.tree.leaves(state["residual"])) == 0.0
    assert max(float(jnp.abs(t[0]).max())
               for t in jax.tree.leaves(state["residual"])) > 0.0


def test_d2_under_churn_freezes_dead_rows():
    """Elastic membership: a dead slot's correction rows are frozen (it
    neither uploads nor mixes) and thaw when the slot rejoins."""
    churn = ScriptedChurn(events=(("crash", 2, 1), ("rejoin", 4, 1)))
    cfg = CoLearnConfig(n_participants=4, T0=1, eta0=0.05, epsilon=0.5,
                        max_rounds=6)
    learner = CoLearner(cfg, tiny_loss, aggregator=api.D2Gossip("grid2d"),
                        round_engine="fused", churn=churn)
    state = learner.init(tiny_params())
    b = tiny_batches(4)
    frozen = None
    for i in range(5):
        state = learner.run_round(state, lambda i, j: b)
        row = jax.tree.map(lambda t: np.asarray(t[1]), state["residual"])
        if i == 2:
            frozen = row
        elif i == 3:
            assert max_abs_diff(frozen, row) == 0.0
    assert all(np.isfinite(l.local_losses).all() for l in state["log"])


def test_d2_quiet_divergence_trigger_rounds_carry_state():
    """A quiet DivergenceTrigger round skips the mix: the D² correction
    must pass through the skip branch unchanged."""
    learner, state = run_rounds(
        api.D2Gossip("grid2d"), engine="fused", rounds=1,
        sync_policy=api.DivergenceTrigger(delta=1e9))
    r0 = jax.tree.map(np.asarray, state["residual"])
    state = learner.run_round(state, lambda i, j: tiny_batches(4))
    assert max_abs_diff(r0, state["residual"]) == 0.0
    assert not any(l.synced for l in state["log"])


def test_comm_bytes_scale_with_degree_not_K():
    """Acceptance pin: graph gossip bills O(degree) encoded models per
    participant — the ring's bill is K-independent, the complete graph's
    is (K-1)-proportional, the hypercube's log2(K)-proportional."""
    codec = api.ExactF32()
    for K in (4, 8):
        stacked = {"w": jnp.zeros((K, 64))}
        wire = codec.wire_bytes(stacked)
        assert (api.GraphGossip("ring").comm_bytes(codec, stacked, 0)
                == 2 * wire)
        assert (api.GraphGossip("complete").comm_bytes(codec, stacked, 0)
                == 2 * (K - 1) * wire)
        assert (api.GraphGossip("hypercube").comm_bytes(codec, stacked, 0)
                == 2 * int(math.log2(K)) * wire)
    # sole survivor bills zero, like the legacy ring
    stacked = {"w": jnp.zeros((4, 64))}
    assert api.GraphGossip("grid2d").comm_bytes(
        codec, stacked, 0, live=[1, 0, 0, 0]) == 0


def test_mixing_matrix_cached_per_round_key():
    """Satellite pin: static graphs build their dense matrix once — the
    same (immutable) array comes back every round; a time-varying graph
    keys the cache by round-within-period."""
    g = api.GraphGossip("grid2d")
    W1, W2 = g.mixing_matrix(0, 6), g.mixing_matrix(5, 6)
    assert W1 is W2 and not W1.flags.writeable
    e = api.GraphGossip("exponential")
    assert e.mixing_matrix(0, 8) is e.mixing_matrix(3, 8)   # period 3
    assert e.mixing_matrix(0, 8) is not e.mixing_matrix(1, 8)
    # live sets key separately and do not clobber the all-live entry
    Wl = g.mixing_matrix(0, 6, live=[1, 1, 1, 1, 1, 0])
    assert Wl is not W1 and g.mixing_matrix(0, 6) is W1


def test_learner_construction_rejects_disconnected_topology():
    with pytest.raises(ValueError, match="disconnected"):
        run_rounds(api.GraphGossip(topo.ErdosRenyiTopology(p=0.05,
                                                           seed=0)),
                   rounds=0)


def test_graph_gossip_time_varying_runs_and_converges():
    """The exponential one-peer graph runs end-to-end on the fused engine
    (per-round matrix as traced data) with O(1) comm per round."""
    learner, state = run_rounds(api.GraphGossip("exponential"),
                                engine="fused", rounds=4)
    losses = [float(np.mean(l.local_losses)) for l in state["log"]]
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]
    wire = learner.codec.wire_bytes(state["params"])
    assert all(l.comm_bytes == 2 * wire for l in state["log"])
    assert not learner.aggregator.static_comm


def test_aggregator_registry_names():
    assert isinstance(api.get_aggregator("graph"), api.GraphGossip)
    assert isinstance(api.get_aggregator("d2"), api.D2Gossip)
    g = api.get_aggregator("graph", topology="hypercube")
    assert g.name == "graph[hypercube]"
    assert api.get_aggregator("d2", topology="grid2d").name == "d2[grid2d]"
    assert api.get_aggregator("ring").name == "ring"
