"""Distribution tests on a small forced-device mesh (subprocess: the main
pytest process must keep the plain 1-device backend)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_sim_mesh
from repro.sharding import compat, specs as sp
from repro.core import averaging
from repro.models import transformer as tr

mesh = make_sim_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_smoke_config("internlm2-1.8b")
out = {}

# 1) vanilla train step lowers+compiles and runs on the 3-axis mesh
params = tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
pspecs = sp.param_specs(params, cfg, mesh)
psh = sp.named(mesh, pspecs)
bsh = sp.named(mesh, sp.batch_specs(cfg, mesh, "train"))
step = steps_mod.make_train_step(cfg, lr=0.01)
batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
         "labels": jnp.ones((8, 16), jnp.int32)}
with compat.use_mesh(mesh):
    fn = jax.jit(step, in_shardings=(psh, bsh),
                 out_shardings=(psh, NamedSharding(mesh, P())))
    new_params, loss = fn(params, batch)
out["vanilla_loss_finite"] = bool(jnp.isfinite(loss))

# 2) colearn vmapped step: per-pod replicas stay DIFFERENT after local steps
K = 2
stacked = averaging.stack_participants(params, K)
stacked = jax.tree.map(
    lambda t: t.at[1].multiply(1.5) if t.ndim > 0 else t, stacked)
spshapes = jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), stacked)
spsh = sp.named(mesh, sp.param_specs(spshapes, cfg, mesh, participant=True))
cbsh = sp.named(mesh, sp.batch_specs(cfg, mesh, "train", participant=True))
cbatch = {"tokens": jnp.zeros((K, 4, 16), jnp.int32),
          "labels": jnp.ones((K, 4, 16), jnp.int32)}
cstep = steps_mod.make_colearn_train_step(cfg, lr=0.01)
with compat.use_mesh(mesh):
    cfn = jax.jit(cstep, in_shardings=(spsh, cbsh))
    new_stacked, losses = cfn(stacked, cbatch)
out["colearn_losses"] = [float(x) for x in losses]
d = jax.tree.leaves(jax.tree.map(
    lambda t: float(jnp.abs(t[0] - t[1]).max()), new_stacked))
out["replicas_differ"] = max(d) > 0

# 3) averaging: pjit mean == shard_map psum over 'pod'
avg_p = jax.jit(averaging.average_pjit)(new_stacked)
avg_sm_fn = averaging.make_average_shard_map(
    mesh, sp.param_specs(spshapes, cfg, mesh, participant=True))
avg_s = avg_sm_fn(new_stacked)
diffs = [float(jnp.abs(a - b).max()) for a, b in
         zip(jax.tree.leaves(avg_p), jax.tree.leaves(avg_s))]
out["avg_match"] = max(diffs) < 1e-4
out["avg_is_mean"] = bool(np.allclose(
    np.asarray(jax.tree.leaves(avg_p)[0][0]),
    np.asarray(jax.tree.leaves(new_stacked)[0].mean(0)), atol=1e-5))

# 4) fused round engine on the pod mesh: whole round (epoch scan + shard_map
#    Eq. 2 + on-device Eq. 4) as one program; slots converge to the mean
from repro.configs.base import CoLearnConfig
ccfg = CoLearnConfig(n_participants=K, T0=2, eta0=0.01, max_rounds=1)
round_fn = steps_mod.make_fused_round_step(
    cfg, ccfg, mesh=mesh,
    param_specs=sp.param_specs(spshapes, cfg, mesh, participant=True))
rbatch = {"tokens": jnp.zeros((2, K, 1, 4, 16), jnp.int32),
          "labels": jnp.ones((2, K, 1, 4, 16), jnp.int32)}
with compat.use_mesh(mesh):
    averaged, _, aux = round_fn(stacked, (), rbatch, jnp.int32(0))
out["fused_round_losses_finite"] = bool(jnp.isfinite(aux["losses"]).all())
out["fused_round_rel_finite"] = bool(jnp.isfinite(aux["rel"]))
out["fused_round_slots_equal"] = max(
    float(jnp.abs(t[0] - t[1]).max())
    for t in jax.tree.leaves(averaged)) < 1e-4

# 4b) FullAverage x FlatFusedInt8 on the pod mesh (via the round-strategy
#     API): each pod int8-roundtrips its own row, ONE psum over 'pod'
#     aggregates the payloads; result within the int8 error bound of the
#     exact mean, slots equal
from repro.core import api
flat_avg = api.FullAverage().make_aggregate_fn(
    api.FlatFusedInt8(impl="ref"), mesh=mesh)
with compat.use_mesh(mesh):
    favg = jax.jit(flat_avg)(new_stacked)
errs, bounds = [], []
for f, e, s in zip(jax.tree.leaves(favg), jax.tree.leaves(avg_p),
                   jax.tree.leaves(new_stacked)):
    errs.append(float(jnp.abs(f.astype(jnp.float32)
                              - e.astype(jnp.float32)).max()))
    bounds.append(float(jnp.abs(s.astype(jnp.float32)).max()) / 127.0 + 1e-6)
out["flat_avg_within_bound"] = all(e <= b for e, b in zip(errs, bounds))
out["flat_avg_slots_equal"] = max(
    float(jnp.abs(t[0] - t[1]).max()) for t in jax.tree.leaves(favg)) == 0.0

# 4c) FullAverage x LeafwiseInt8 on the pod mesh: per-leaf reference
#     roundtrip in front of the shard_map psum (the third codec of the
#     pod-path acceptance matrix; exact f32 is covered by 3/4 above)
leaf_avg = api.FullAverage().make_aggregate_fn(
    api.LeafwiseInt8(impl="ref"), mesh=mesh,
    param_specs=sp.param_specs(spshapes, cfg, mesh, participant=True))
with compat.use_mesh(mesh):
    lavg = jax.jit(leaf_avg)(new_stacked)
errs = [float(jnp.abs(f.astype(jnp.float32) - e.astype(jnp.float32)).max())
        for f, e in zip(jax.tree.leaves(lavg), jax.tree.leaves(avg_p))]
out["leafwise_avg_within_bound"] = all(
    e <= b for e, b in zip(errs, bounds))
out["leafwise_avg_slots_equal"] = max(
    float(jnp.abs(t[0] - t[1]).max()) for t in jax.tree.leaves(lavg)) == 0.0

# 4d) weighted aggregators on the pod mesh: the psum (partial) and
#     collective-permute (ring) specializations must match the host-side
#     dense-mixing reference — without the all-gather the fallback pays
pspecs_part = sp.param_specs(spshapes, cfg, mesh, participant=True)
for nm, agg in (("partial", api.PartialParticipation(m=2, seed=0)),
                ("ring", api.RingGossip())):
    W = jnp.asarray(agg.mixing_matrix(0, K))
    mesh_fn = agg.make_aggregate_fn(api.ExactF32(), mesh=mesh,
                                    param_specs=pspecs_part)
    host_fn = agg.make_aggregate_fn(api.ExactF32())
    with compat.use_mesh(mesh):
        got = jax.jit(mesh_fn)(new_stacked, W)
    want = host_fn(new_stacked, W)
    out[f"{nm}_mesh_matches_host"] = max(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want))) < 1e-5

# 4f) graph-structured gossip on the pod mesh: GraphGossip's sparse
#     per-permutation ppermute specialization and its D2 variant (the
#     correction tree rides the shard_map sharded like the params) must
#     match the host-side dense-mixing reference
for nm, agg in (("graph_hypercube", api.GraphGossip("hypercube")),
                ("graph_grid2d", api.GraphGossip("grid2d"))):
    W = jnp.asarray(agg.mixing_matrix(0, K))
    mesh_fn = agg._make_mesh_aggregate_fn(api.ExactF32(), mesh,
                                          pspecs_part, "pod")
    out[f"{nm}_sparse_path_engaged"] = mesh_fn is not None
    host_fn = agg._make_host_aggregate_fn(api.ExactF32())
    with compat.use_mesh(mesh):
        got = jax.jit(mesh_fn)(new_stacked, W)
    want = host_fn(new_stacked, W)
    out[f"{nm}_mesh_matches_host"] = max(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want))) < 1e-5

d2 = api.D2Gossip("hypercube")
W = jnp.asarray(d2.mixing_matrix(0, K))
corr0 = jax.tree.map(
    lambda t: 0.01 * jnp.arange(t.size, dtype=jnp.float32
                                ).reshape(t.shape), new_stacked)
d2_mesh = d2._make_mesh_aggregate_fn(api.ExactF32(), mesh,
                                     pspecs_part, "pod")
out["d2_sparse_path_engaged"] = d2_mesh is not None
d2_host = d2._make_host_aggregate_fn(api.ExactF32())
with compat.use_mesh(mesh):
    gmix, gcorr = jax.jit(d2_mesh)(new_stacked, W, corr0)
wmix, wcorr = d2_host(new_stacked, W, corr0)
out["d2_mesh_matches_host"] = max(
    float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    for a, b in zip(jax.tree.leaves((gmix, gcorr)),
                    jax.tree.leaves((wmix, wcorr)))) < 1e-5

# 4e) heterogeneity scenario on the pod mesh: example-count-weighted Eq. 2
#     rides the shared weighted-psum specialization (matches the host
#     dense-mixing reference), the flat codec keeps a weighted single-
#     buffer psum within the int8 bound, and the masked fused round
#     (ragged per-pod batch counts as traced data) runs end to end
wagg = api.FullAverage(weights=(3.0, 1.0))
W = jnp.asarray(wagg.mixing_matrix(0, K))
wmesh = wagg.make_aggregate_fn(api.ExactF32(), mesh=mesh,
                               param_specs=pspecs_part)
whost = wagg.make_aggregate_fn(api.ExactF32())
with compat.use_mesh(mesh):
    wgot = jax.jit(wmesh)(new_stacked, W)
wwant = whost(new_stacked, W)
out["weighted_full_mesh_matches_host"] = max(
    float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    for a, b in zip(jax.tree.leaves(wgot), jax.tree.leaves(wwant))) < 1e-5

wflat = api.FlatFusedInt8(impl="ref").make_fused_mean(mesh=mesh,
                                                      weighted=True)
with compat.use_mesh(mesh):
    wfgot = jax.jit(wflat)(new_stacked, W[0])
out["weighted_flat_mesh_within_bound"] = all(
    float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) <= bd
    for a, b, bd in zip(jax.tree.leaves(wfgot), jax.tree.leaves(wwant),
                        bounds))

round_fn_m = steps_mod.make_fused_round_step(
    cfg, ccfg, mesh=mesh, aggregator=wagg, masked=True,
    param_specs=pspecs_part)
rbatch_m = {"tokens": jnp.zeros((2, K, 2, 4, 16), jnp.int32),
            "labels": jnp.ones((2, K, 2, 4, 16), jnp.int32)}
bmask = jnp.asarray(np.array([[True, True], [True, False]]))
with compat.use_mesh(mesh):
    averaged_m, _, aux_m = round_fn_m(stacked, (), rbatch_m, bmask,
                                      jnp.int32(0), W)
out["masked_round_losses_finite"] = bool(jnp.isfinite(aux_m["losses"]).all())
out["masked_round_slots_equal"] = max(
    float(jnp.abs(t[0] - t[1]).max())
    for t in jax.tree.leaves(averaged_m)) < 1e-4

# 4f) sub-int8 wire on the pod mesh: the bit-width-general codecs reduce
#     bitwise to the legacy int8 classes at bits=8, and the error-feedback
#     (stateful) paths — psum aggregate and the fused round step — run on
#     the mesh with each pod's residual resident on that pod
gen8 = api.FullAverage().make_aggregate_fn(
    api.FlatFusedIntN(bits=8, impl="ref"), mesh=mesh)
with compat.use_mesh(mesh):
    favg_gen = jax.jit(gen8)(new_stacked)
out["intn_bits8_pod_bit_identical"] = max(
    float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    for a, b in zip(jax.tree.leaves(favg_gen), jax.tree.leaves(favg))) == 0.0
lgen8 = api.FullAverage().make_aggregate_fn(
    api.LeafwiseIntN(bits=8, impl="ref"), mesh=mesh,
    param_specs=pspecs_part)
with compat.use_mesh(mesh):
    lavg_gen = jax.jit(lgen8)(new_stacked)
out["leafwise_bits8_pod_bit_identical"] = max(
    float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    for a, b in zip(jax.tree.leaves(lavg_gen), jax.tree.leaves(lavg))) == 0.0

ef_codec = api.FlatFusedIntN(bits=4, error_feedback=True, impl="ref")
res0 = ef_codec.init_state(new_stacked)
ef_mesh = api.FullAverage().make_aggregate_fn(ef_codec, mesh=mesh)
ef_host = api.FullAverage().make_aggregate_fn(ef_codec)
with compat.use_mesh(mesh):
    mixed_m, res_m = jax.jit(ef_mesh)(new_stacked, None, res0)
mixed_h, res_h = ef_host(new_stacked, None, res0)
out["ef_int4_pod_matches_host"] = max(
    float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    for a, b in zip(jax.tree.leaves(mixed_m), jax.tree.leaves(mixed_h))) < 1e-5
out["ef_int4_pod_residual_matches_host"] = float(
    jnp.abs(res_m - res_h).max()) < 1e-5
out["ef_int4_pod_residual_nonzero"] = float(jnp.abs(res_m).max()) > 0.0

round_fn_ef = steps_mod.make_fused_round_step(
    cfg, ccfg, mesh=mesh, codec="fused", codec_bits=4, error_feedback=True,
    param_specs=pspecs_part)
with compat.use_mesh(mesh):
    averaged_ef, _, aux_ef = round_fn_ef(stacked, (), res0, rbatch,
                                         jnp.int32(0))
out["ef_round_losses_finite"] = bool(jnp.isfinite(aux_ef["losses"]).all())
out["ef_round_slots_equal"] = max(
    float(jnp.abs(t[0] - t[1]).max())
    for t in jax.tree.leaves(averaged_ef)) < 1e-4
out["ef_round_residual_nonzero"] = (
    float(jnp.abs(aux_ef["residual"]).max()) > 0.0)

# 5) decode step lowers on the mesh
cache = tr.init_cache(cfg, 8, 16, jnp.float32)
csh = sp.named(mesh, sp.cache_specs(
    jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), cache),
    mesh, 8))
with compat.use_mesh(mesh):
    sfn = jax.jit(steps_mod.make_serve_step(cfg),
                  in_shardings=(psh, csh, NamedSharding(mesh, P()),
                                NamedSharding(mesh, P())))
    logits, _ = sfn(new_params, cache, jnp.zeros((8, 1), jnp.int32),
                    jnp.int32(0))
out["decode_finite"] = bool(jnp.isfinite(logits).all())
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def mesh_results():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError("no RESULT line:\n" + proc.stdout[-2000:])


def test_vanilla_step_on_mesh(mesh_results):
    assert mesh_results["vanilla_loss_finite"]


def test_colearn_replicas_independent(mesh_results):
    assert mesh_results["replicas_differ"]
    assert all(np.isfinite(l) for l in mesh_results["colearn_losses"])


def test_average_pjit_matches_shard_map(mesh_results):
    assert mesh_results["avg_match"]
    assert mesh_results["avg_is_mean"]


def test_flat_compressed_average_on_pod_mesh(mesh_results):
    assert mesh_results["flat_avg_within_bound"]
    assert mesh_results["flat_avg_slots_equal"]


def test_leafwise_compressed_average_on_pod_mesh(mesh_results):
    assert mesh_results["leafwise_avg_within_bound"]
    assert mesh_results["leafwise_avg_slots_equal"]


def test_weighted_aggregators_on_pod_mesh(mesh_results):
    assert mesh_results["partial_mesh_matches_host"]
    assert mesh_results["ring_mesh_matches_host"]


def test_graph_gossip_on_pod_mesh(mesh_results):
    assert mesh_results["graph_hypercube_sparse_path_engaged"]
    assert mesh_results["graph_hypercube_mesh_matches_host"]
    assert mesh_results["graph_grid2d_sparse_path_engaged"]
    assert mesh_results["graph_grid2d_mesh_matches_host"]


def test_d2_gossip_on_pod_mesh(mesh_results):
    assert mesh_results["d2_sparse_path_engaged"]
    assert mesh_results["d2_mesh_matches_host"]


def test_heterogeneity_scenario_on_pod_mesh(mesh_results):
    assert mesh_results["weighted_full_mesh_matches_host"]
    assert mesh_results["weighted_flat_mesh_within_bound"]
    assert mesh_results["masked_round_losses_finite"]
    assert mesh_results["masked_round_slots_equal"]


def test_fused_round_on_pod_mesh(mesh_results):
    assert mesh_results["fused_round_losses_finite"]
    assert mesh_results["fused_round_rel_finite"]
    assert mesh_results["fused_round_slots_equal"]


def test_sub_int8_wire_on_pod_mesh(mesh_results):
    assert mesh_results["intn_bits8_pod_bit_identical"]
    assert mesh_results["leafwise_bits8_pod_bit_identical"]
    assert mesh_results["ef_int4_pod_matches_host"]
    assert mesh_results["ef_int4_pod_residual_matches_host"]
    assert mesh_results["ef_int4_pod_residual_nonzero"]
    assert mesh_results["ef_round_losses_finite"]
    assert mesh_results["ef_round_slots_equal"]
    assert mesh_results["ef_round_residual_nonzero"]


def test_decode_on_mesh(mesh_results):
    assert mesh_results["decode_finite"]


import numpy as np  # noqa: E402  (used in fixtures above)
