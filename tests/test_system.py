"""End-to-end behaviour tests: the paper's protocol on a real (tiny) model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.colearn import CoLearner
from repro.core.compression import make_compress_fn
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr


def setup(K=3, seq=32, n=240, arch="internlm2-1.8b", seed=0):
    cfg = get_smoke_config(arch).with_(n_layers=1, segments=((("gqa:dense",), 1),))
    x, y = lm_examples(seed, n, seq, cfg.vocab_size)
    shards = partition_arrays([x, y], K, seed)
    data = ParticipantData(shards, batch_size=8, seed=seed)

    def loss_fn(params, batch):
        bx, by = batch
        return tr.loss_fn(params, cfg, {"tokens": bx, "labels": by})

    params = tr.init_params(jax.random.PRNGKey(seed), cfg, jnp.float32)
    return cfg, data, loss_fn, params


def run_colearn(rounds=3, compress=None, **kw):
    cfg, data, loss_fn, params = setup(**kw)
    ccfg = CoLearnConfig(n_participants=3, T0=1, eta0=0.05, epsilon=1e-6,
                         max_rounds=rounds)
    learner = CoLearner.from_flags(ccfg, loss_fn, compress_fn=compress)
    state = learner.init(params)
    for _ in range(rounds):
        state = learner.run_round(
            state, lambda i, j: tuple(map(jnp.asarray,
                                          data.epoch_batches(i, j))))
    return learner, state


def test_colearn_trains_tiny_transformer():
    learner, state = run_colearn(rounds=3)
    losses = [np.mean(l.local_losses) for l in state["log"]]
    assert losses[-1] < losses[0] - 0.1, losses
    # Eq.2 bookkeeping: comm volume == 2 x model bytes each round
    one = learner.param_bytes(state)
    assert state["log"][0].comm_bytes == 2 * one


def test_colearn_participants_share_model_after_round():
    _, state = run_colearn(rounds=1)
    for t in jax.tree.leaves(state["params"]):
        np.testing.assert_allclose(t[0], t[-1], rtol=1e-6)


def test_compressed_averaging_close_to_exact():
    """Beyond-paper int8 upload: same trajectory within quantization noise."""
    _, s_exact = run_colearn(rounds=2)
    _, s_comp = run_colearn(rounds=2, compress=make_compress_fn())
    l_exact = np.mean(s_exact["log"][-1].local_losses)
    l_comp = np.mean(s_comp["log"][-1].local_losses)
    assert abs(l_exact - l_comp) < 0.1 * max(abs(l_exact), 1e-3) + 0.05


def test_train_driver_cli_runs():
    from repro.launch.train import main
    rc = main(["--arch", "internlm2-1.8b", "--participants", "2",
               "--rounds", "2", "--t0", "1", "--n-examples", "64",
               "--batch-size", "4", "--seq-len", "16",
               "--steps-per-epoch", "2"])
    assert rc == 0


def test_train_driver_cli_rejects_codec_plus_compress():
    from repro.launch.train import main
    with pytest.raises(SystemExit) as e:
        main(["--codec", "exact", "--compress", "fused"])
    assert e.value.code == 2


def test_serve_driver_cli_runs():
    from repro.launch.serve import main
    rc = main(["--arch", "xlstm-1.3b", "--batch", "2", "--prompt-len", "4",
               "--new-tokens", "4", "--max-seq", "16"])
    assert rc == 0
