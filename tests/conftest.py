import os
import sys

# tests run on the plain 1-device CPU backend (the dry-run forces 512
# devices in its own process only — never here)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
