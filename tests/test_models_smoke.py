"""Per-architecture smoke tests: reduced configs, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness (deliverable f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import transformer as tr


def make_batch(cfg, B=2, S=16, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "tokens+prefix":
        batch["prefix"] = jax.random.normal(
            k1, (B, cfg.prefix_len, cfg.d_model), jnp.float32)
        batch["labels"] = jnp.concatenate(
            [jnp.full((B, cfg.prefix_len), -1, jnp.int32), batch["labels"]], 1)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.citation
    assert sum(len(p) * r for p, r in cfg.segments) == cfg.n_layers


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_experts <= 4
    params = tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    batch = make_batch(cfg)
    S_total = 16 + (cfg.prefix_len if cfg.input_mode == "tokens+prefix" else 0)

    logits, aux = tr.forward(params, cfg, batch)
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    step = make_train_step(cfg, lr=0.01)
    new_params, loss = jax.jit(step)(params, batch)
    assert bool(jnp.isfinite(loss))
    # params actually changed
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(params), jax.tree.leaves(new_params))]
    assert max(diffs) > 0
    for t in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(t).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S_max = 2, 8
    cache = tr.init_cache(cfg, B, S_max, jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = tr.decode_step(params, cfg, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "xlstm-1.3b",
                                  "jamba-v0.1-52b", "deepseek-v3-671b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == full-sequence forward logits."""
    cfg = get_smoke_config(arch)
    if cfg.mtp_depth:
        cfg = cfg.with_(mtp_depth=0)
    if cfg.n_experts:
        # capacity dropping is batch-size dependent (forward sees B*S tokens,
        # decode sees B) — exact parity needs a drop-free capacity factor
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts))
    params = tr.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    full_logits, _ = tr.forward(params, cfg, {"tokens": toks},
                                lowering="unroll")

    cache = tr.init_cache(cfg, B, S, jnp.float32)
    dec = []
    for t in range(S):
        lg, cache = tr.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                   jnp.int32(t), lowering="unroll")
        dec.append(lg[:, 0])
    dec_logits = jnp.stack(dec, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_matches_forward():
    """SWA (the long_500k carve-in): windowed forward == ring-buffer decode."""
    cfg = get_smoke_config("internlm2-1.8b").with_(window=4)
    params = tr.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    full_logits, _ = tr.forward(params, cfg, {"tokens": toks},
                                lowering="unroll")
    cache = tr.init_cache(cfg, B, S, jnp.float32)   # ring buffer size = window
    assert cache[0]["p0"]["k"].shape[2] == 4        # (repeats,B,window,KV,hd)
    dec = []
    for t in range(S):
        lg, cache = tr.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                   jnp.int32(t), lowering="unroll")
        dec.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(dec, 1)),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)
