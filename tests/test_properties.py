"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency (declared in pyproject's
``test`` extra); environments without it skip this module instead of
hard-erroring the whole collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import averaging, flatbuf
from repro.core.schedule import EpochController, clr_lr, relative_change
from repro.data.partition import partition
from repro.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)

# float dtypes the f32 wire container holds losslessly
_WIRE_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)
# odd/irregular per-participant leaf shapes, () = scalar leaf
_LEAF_SHAPES = ((), (1,), (3,), (7, 13), (257,), (2, 256), (5, 5, 3))


@given(st.integers(1, 5),
       st.lists(st.tuples(st.integers(0, len(_LEAF_SHAPES) - 1),
                          st.integers(0, len(_WIRE_DTYPES) - 1)),
                min_size=1, max_size=6),
       st.integers(0, 99))
@settings(**SETTINGS)
def test_flatbuf_roundtrip_bit_exact(K, leaf_specs, seed):
    """unflatten(flatten(tree)) == tree BIT-exactly for any stacked tree of
    mixed float dtypes, odd shapes, and scalar leaves — no leaf escapes the
    flat-buffer wire layout."""
    rng = np.random.RandomState(seed)
    tree = {}
    for i, (si, di) in enumerate(leaf_specs):
        shape, dt = _LEAF_SHAPES[si], _WIRE_DTYPES[di]
        vals = rng.standard_normal((K, *shape)) * 10 ** rng.randint(-3, 4)
        tree[f"leaf{i}"] = jnp.asarray(vals, dtype=dt)
    layout = flatbuf.make_layout(tree)
    buf = flatbuf.flatten(tree, layout)
    assert buf.shape == (K, layout.n_pad)
    assert layout.n >= sum(int(np.prod(_LEAF_SHAPES[si], dtype=np.int64))
                           for si, _ in leaf_specs)
    assert all(off % layout.block == 0 for off in layout.offsets)
    back = flatbuf.unflatten(buf, layout)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@given(st.integers(10, 500), st.integers(1, 8), st.integers(0, 99))
@settings(**SETTINGS)
def test_partition_disjoint_and_covering(n, K, seed):
    """The random split: disjoint shards covering EVERY example (remainder
    round-robin, sizes within 1); drop_remainder=True restores the paper's
    exactly-equal shards as the explicit opt-in."""
    idx = partition(n, K, seed)
    assert len(idx) == K
    all_ids = np.concatenate(idx)
    assert len(all_ids) == n                             # nothing dropped
    assert len(set(all_ids.tolist())) == len(all_ids)    # disjoint
    sizes = [len(i) for i in idx]
    assert max(sizes) - min(sizes) <= 1
    eq = partition(n, K, seed, drop_remainder=True)
    assert {len(i) for i in eq} == {n // K}


@given(st.floats(1e-4, 1.0), st.floats(0.01, 0.99), st.integers(1, 64))
@settings(**SETTINGS)
def test_clr_monotone_within_round(eta, r, T):
    """Eq.3 is a monotone decay from η^i to η^i · r within a round."""
    lrs = [clr_lr(eta, r, j, T) for j in range(T + 1)]
    assert all(b <= a + 1e-12 for a, b in zip(lrs, lrs[1:]))
    assert np.isclose(lrs[0], eta)
    assert np.isclose(lrs[-1], eta * r)


@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=20),
       st.floats(0.001, 0.5))
@settings(**SETTINGS)
def test_ile_T_is_monotone_nondecreasing_and_powers(rels, eps):
    c = EpochController(T=5, epsilon=eps, rule="ile")
    prev = c.T
    for r in rels:
        c = c.update(r)
        assert c.T >= prev
        assert c.T % 5 == 0 and (c.T // 5) & (c.T // 5 - 1) == 0  # 5·2^k
        prev = c.T


@given(st.integers(1, 6), st.integers(0, 99))
@settings(**SETTINGS)
def test_averaging_linearity(K, seed):
    """avg(a + b) == avg(a) + avg(b) (Eq. 2 is linear)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = {"w": jax.random.normal(k1, (K, 3, 4))}
    b = {"w": jax.random.normal(k2, (K, 3, 4))}
    ab = jax.tree.map(jnp.add, a, b)
    lhs = averaging.average_mean(ab)["w"]
    rhs = averaging.average_mean(a)["w"] + averaging.average_mean(b)["w"]
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-6)


@given(st.integers(1, 6), st.integers(0, 99))
@settings(**SETTINGS)
def test_averaging_bounded_by_extremes(K, seed):
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(seed), (K, 5))}
    avg = averaging.average_mean(stacked)["w"]
    assert bool((avg <= stacked["w"].max(0) + 1e-6).all())
    assert bool((avg >= stacked["w"].min(0) - 1e-6).all())


@given(st.integers(0, 99))
@settings(**SETTINGS)
def test_relative_change_scale_invariant(seed):
    k = jax.random.PRNGKey(seed)
    a = {"w": jax.random.normal(k, (8,))}
    b = {"w": jax.random.normal(jax.random.PRNGKey(seed + 1), (8,))}
    r1 = relative_change(a, b)
    a2 = jax.tree.map(lambda t: t * 3.0, a)
    b2 = jax.tree.map(lambda t: t * 3.0, b)
    assert np.isclose(relative_change(a2, b2), r1, rtol=1e-5)


@given(st.integers(1, 400), st.integers(0, 99))
@settings(**SETTINGS)
def test_quantize_error_bound(n, seed):
    """int8 blockwise quantization: |x - dq(q(x))| <= blockmax/127."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 10
    q, s, shape = ref.quantize_blockwise_ref(x, block=64)
    back = ref.dequantize_blockwise_ref(q, s, shape)
    err = np.abs(np.asarray(x) - np.asarray(back))
    blocks = np.pad(np.asarray(x), (0, (-n) % 64)).reshape(-1, 64)
    bound = (np.abs(blocks).max(1) / 127.0 + 1e-7).repeat(64)[:n]
    # rounding error is at most half a step; allow a full step for safety
    assert (err <= bound).all()


@given(st.integers(2, 5), st.integers(4, 16), st.integers(0, 9))
@settings(max_examples=10, deadline=None)
def test_softmax_xent_ignores_masked_positions(B, S, seed):
    """Changing logits at ignored (-1) positions never changes the loss."""
    from repro.models.layers import softmax_xent
    k = jax.random.PRNGKey(seed)
    V = 11
    logits = jax.random.normal(k, (B, S, V))
    labels = jax.random.randint(k, (B, S), 0, V).at[:, 0].set(-1)
    l1 = softmax_xent(logits, labels)
    logits2 = logits.at[:, 0].add(100.0)
    l2 = softmax_xent(logits2, labels)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


# odd/block-unaligned per-participant leaf shapes for the wire-bytes law
_WIRE_SHAPES = ((1,), (5,), (256,), (300,), (2, 256), (7, 131), (1000, 3))


def _encoded_payload_bytes(codec, stacked, K):
    """Measure the ACTUAL per-participant bytes of ``codec.encode``."""
    from repro.core.api import ExactF32, FlatFusedIntN, LeafwiseIntN
    if isinstance(codec, FlatFusedIntN):
        _, q, scale, _ = codec.encode(stacked)
        return (q.nbytes + scale.nbytes) // K
    if isinstance(codec, LeafwiseIntN):
        # leafwise uploads are per participant — encode a K=1 stack
        one = jax.tree.map(lambda t: t[:1], stacked)
        _, enc = codec.encode(one)
        total = 0
        for kind, payload, _ in enc:
            if kind == "raw":
                total += payload.nbytes
            else:
                q, scale, _ = payload
                total += q.nbytes + scale.nbytes
        return total
    assert isinstance(codec, ExactF32)
    return sum(t.nbytes for t in jax.tree.leaves(codec.encode(stacked))) // K


@given(st.integers(1, 4),
       st.lists(st.integers(0, len(_WIRE_SHAPES) - 1), min_size=1,
                max_size=5),
       st.sampled_from(["exact", "leafwise", "fused"]),
       st.sampled_from([8, 4, 1]),
       st.booleans(),
       st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_wire_bytes_equals_encoded_payload(K, shape_idx, name, bits, ef,
                                           seed):
    """``codec.wire_bytes(stacked)`` == the byte size of the encoded
    payload that actually goes on the wire, for every registered codec x
    bit width x odd/block-unaligned shapes (error feedback never changes
    the wire). The ref impl emits exactly the canonical padded blocks the
    accounting bills (the pallas path only adds kernel-internal ROWS
    padding that never leaves the device)."""
    from repro.core import api
    rng = np.random.RandomState(seed)
    stacked = {
        f"leaf{i}": jnp.asarray(
            rng.randn(K, *_WIRE_SHAPES[si]).astype(np.float32))
        for i, si in enumerate(shape_idx)
    }
    codec = api.get_codec(name, bits=bits, error_feedback=ef, impl="ref")
    billed = codec.wire_bytes(stacked)
    actual = _encoded_payload_bytes(codec, stacked, K)
    if isinstance(codec, api.LeafwiseIntN):
        # the billing is per-participant by contract; the K=1 measurement
        # can only differ on leaves whose bypass threshold flips with K —
        # compare against the K=1 bill, which shares the measurement's view
        billed = codec.wire_bytes(jax.tree.map(lambda t: t[:1], stacked))
    assert billed == actual


# graph topologies x K: names valid at any K >= 2 (hypercube constrains
# K to powers of two and is covered with its own strategy below)
_TOPO_FACTORIES = (
    lambda: __import__("repro.core.topology",
                       fromlist=["RingTopology"]).RingTopology(),
    lambda: __import__("repro.core.topology",
                       fromlist=["Grid2DTopology"]).Grid2DTopology(),
    lambda: __import__("repro.core.topology",
                       fromlist=["ExponentialTopology"]
                       ).ExponentialTopology(),
    lambda: __import__("repro.core.topology",
                       fromlist=["CompleteTopology"]).CompleteTopology(),
)


@given(st.integers(0, len(_TOPO_FACTORIES)), st.integers(2, 12),
       st.integers(0, 5),
       st.lists(st.booleans(), min_size=2, max_size=12))
@settings(**SETTINGS)
def test_topology_mixing_matrix_invariants(ti, K, round_index, live_bits):
    """Every registered topology x K: the all-live mixing matrix is
    nonnegative, doubly stochastic (rows AND columns sum to 1 +- 1e-6),
    symmetric when the topology declares itself symmetric, and its
    spectral gap is > 0 (the graph is connected); any live-masked matrix
    stays row-stochastic with identity dead rows."""
    from repro.core import topology as topo
    if ti == len(_TOPO_FACTORIES):
        K = 1 << (K.bit_length() - 1)       # hypercube: snap K to 2^m
        t = topo.HypercubeTopology()
    else:
        t = _TOPO_FACTORIES[ti]()
    t.validate(K)
    W = t.mixing_matrix(round_index, K)
    assert W.shape == (K, K) and (W >= 0).all()
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-6)
    if t.symmetric:
        np.testing.assert_allclose(W, W.T, atol=1e-7)
    assert t.spectral_gap(K) > 0.0
    live = np.resize(np.asarray(live_bits, bool), K)
    if not live.any():
        live[0] = True
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore", RuntimeWarning)
        Wl = t.mixing_matrix(round_index, K, live=live)
    assert (Wl >= 0).all()
    np.testing.assert_allclose(Wl.sum(1), 1.0, atol=1e-6)
    for k in np.nonzero(~live)[0]:
        assert Wl[k, k] == 1.0 and np.count_nonzero(Wl[k]) == 1
    assert (Wl[live][:, ~live] == 0).all()
