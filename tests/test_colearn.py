"""Co-learning protocol invariants (Algorithm 1 / Eq. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoLearnConfig
from repro.core import averaging
from repro.core.colearn import CoLearner
from repro.core.ensemble import ensemble_logits


def tiny_loss(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, {"loss": loss}


def tiny_params(key=0, d=4):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}


def tiny_batches(K, n_batches, B, d=4, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (K, n_batches, B, d))
    w_true = jnp.arange(1.0, d + 1)[:, None]
    y = x @ w_true
    return (x, y)


def test_average_is_mean():
    p = tiny_params()
    stacked = averaging.stack_participants(p, 3)
    # perturb each copy differently
    stacked = jax.tree.map(
        lambda t: t + jnp.arange(3.0).reshape(3, *([1] * (t.ndim - 1))), stacked)
    avg = averaging.average_pjit(stacked)
    got = jax.tree.map(lambda t: t[0], avg)
    want = jax.tree.map(lambda t: t.mean(0), stacked)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    # all K slots identical after averaging (the broadcast back)
    for t in jax.tree.leaves(avg):
        np.testing.assert_allclose(t[0], t[-1])


def test_averaging_identical_models_is_identity():
    p = tiny_params()
    stacked = averaging.stack_participants(p, 5)
    avg = averaging.average_pjit(stacked)
    for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(stacked)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_k1_colearn_equals_plain_sgd():
    """K=1 co-learning round == T0 epochs of plain SGD."""
    cfg = CoLearnConfig(n_participants=1, T0=2, eta0=0.05, schedule="clr",
                        epochs_rule="fle", max_rounds=1)
    learner = CoLearner(cfg, tiny_loss)
    params = tiny_params()
    state = learner.init(params)
    batches = tiny_batches(1, 3, 8)
    state = learner.run_round(state, lambda i, j: batches)
    got = learner.shared_model(state)

    # manual: 2 epochs of SGD over the same batches with the CLR lrs
    from repro.core.schedule import clr_lr
    p = params
    for j in range(2):
        lr = clr_lr(0.05, 0.25, j, 2)
        for b in range(3):
            g = jax.grad(lambda q, _b=b: tiny_loss(
                q, (batches[0][0, _b], batches[1][0, _b]))[0])(p)
            p = jax.tree.map(lambda a, d: a - lr * d, p, g)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(p)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_colearn_reduces_loss_and_logs():
    cfg = CoLearnConfig(n_participants=4, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=3)
    learner = CoLearner(cfg, tiny_loss)
    state = learner.init(tiny_params())
    batches = tiny_batches(4, 4, 8)
    first = last = None
    for i in range(3):
        state = learner.run_round(state, lambda i_, j_: batches)
        log = state["log"][-1]
        if first is None:
            first = np.mean(log.local_losses)
        last = np.mean(log.local_losses)
    assert last < first
    assert state["round"] == 3
    assert state["log"][0].comm_bytes > 0


def test_ile_doubles_T_on_convergence():
    # zero gradients (loss already 0) => params never change => rel=0 => double
    def zero_loss(params, batch):
        return jnp.zeros(()), {}
    cfg = CoLearnConfig(n_participants=2, T0=1, epsilon=0.01,
                        epochs_rule="ile", max_rounds=3)
    learner = CoLearner(cfg, zero_loss)
    state = learner.init(tiny_params())
    b = tiny_batches(2, 1, 2)
    for _ in range(3):
        state = learner.run_round(state, lambda i, j: b)
    # round0: rel=inf (no prev) keep 1; round1: rel=0 -> 2; round2: -> 4
    assert [l.T for l in state["log"]] == [1, 1, 2]
    assert state["ctrl"].T == 4


def test_restart_participant_resets_to_shared():
    cfg = CoLearnConfig(n_participants=3, T0=1, max_rounds=1)
    learner = CoLearner(cfg, tiny_loss)
    state = learner.init(tiny_params())
    state["params"] = jax.tree.map(
        lambda t: t.at[1].add(100.0), state["params"])
    state = learner.restart_participant(state, 1)
    shared = learner.shared_model(state)
    for t, s in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(shared)):
        np.testing.assert_allclose(t[1], s)


def test_ensemble_baseline_averages_probs():
    K, B, C = 3, 4, 5
    key = jax.random.PRNGKey(0)
    stacked = {"w": jax.random.normal(key, (K, 7, C))}
    batch = jax.random.normal(jax.random.PRNGKey(1), (B, 7))
    lp = ensemble_logits(lambda p, b: b @ p["w"], stacked, batch)
    probs = jax.vmap(lambda p: jax.nn.softmax(batch @ p["w"], -1))(stacked)
    np.testing.assert_allclose(np.exp(lp), probs.mean(0), rtol=1e-5)
