"""Continuous-operation subsystem: ShardStream/DriftSchedule determinism
and invariants, ModelBank publication/staleness, ServeLoop hot-swap, and
the ensemble serving path (core.ensemble was previously untested)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.core.ensemble import ensemble_accuracy, ensemble_logits
from repro.checkpoint.io import (restore_pytree, restore_round_state,
                                 save_pytree, save_round_state)
from repro.data.pipeline import ParticipantData
from repro.data import partition as part_mod
from repro.data.stream import (AbruptDrift, CovariateDrift, DriftSchedule,
                               LabelShift, NoDrift, ShardStream, get_drift)
from repro.serving import ModelBank, ServeLoop


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def lin_params(key=0, d=4, C=3):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (d, C)), "b": jnp.zeros((C,))}


def lin_apply(params, x):
    return x @ params["w"] + params["b"]


def lin_loss(params, batch):
    x, y = batch
    logits = lin_apply(params, x)
    one_hot = jax.nn.one_hot(y, logits.shape[-1])
    loss = -jnp.mean(jnp.sum(one_hot * jax.nn.log_softmax(logits), -1))
    return loss, {"loss": loss}


def cls_data(n=48, d=4, C=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.integers(0, C, size=n).astype(np.int64)
    return x, y


def stacked(params_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def tiny_lm():
    from repro.configs import get_smoke_config
    return get_smoke_config("internlm2-1.8b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=64, segments=((("gqa:dense",), 1),))


# ---------------------------------------------------------------------------
# core/ensemble (paper Table 2 baseline) — previously untested
# ---------------------------------------------------------------------------
def test_ensemble_logits_prob_averaging():
    K, d, C = 3, 4, 5
    params = stacked([lin_params(k, d, C) for k in range(K)])
    x = jnp.asarray(np.random.default_rng(0).normal(size=(7, d)),
                    jnp.float32)
    out = ensemble_logits(lin_apply, params, x)
    # reference: average the per-member softmax PROBABILITIES, then log
    probs = np.stack([jax.nn.softmax(
        lin_apply(jax.tree.map(lambda t: t[k], params), x), -1)
        for k in range(K)])
    ref = np.log(np.maximum(probs.mean(0), 1e-9))
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-6)
    # prob-averaging is NOT logit-averaging: the naive mean differs
    naive = np.stack([np.asarray(lin_apply(
        jax.tree.map(lambda t: t[k], params), x)) for k in range(K)]).mean(0)
    assert not np.allclose(np.argsort(ref[0]), np.argsort(naive[0])) or \
        not np.allclose(ref, naive, atol=1e-3)


def test_ensemble_k1_reduces_to_single_model():
    params = stacked([lin_params(0)])
    x, y = cls_data(n=16)
    out = ensemble_logits(lin_apply, params, jnp.asarray(x))
    single = jax.nn.log_softmax(
        lin_apply(jax.tree.map(lambda t: t[0], params), jnp.asarray(x)), -1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(single),
                               atol=1e-6)
    acc = ensemble_accuracy(lin_apply, params, jnp.asarray(x),
                            jnp.asarray(y))
    pred = np.argmax(np.asarray(single), -1)
    assert float(acc) == pytest.approx((pred == y).mean())


# ---------------------------------------------------------------------------
# drift schedules: determinism + invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("drift", [
    CovariateDrift(rate=0.2), LabelShift(rate=0.25),
    AbruptDrift(at_round=2, severity=1.0)])
def test_drift_deterministic_in_seed_round(drift):
    x, y = cls_data(n=60)
    for r in (0, 1, 3):
        a = drift.transform(x, y, r, seed=5)
        b = drift.transform(x, y, r, seed=5)
        assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
        if drift.reassigns:
            ia = drift.assign(y, (30, 30), 2, r, seed=5)
            ib = drift.assign(y, (30, 30), 2, r, seed=5)
            assert all(np.array_equal(p, q) for p, q in zip(ia, ib))


def test_drift_actually_drifts():
    x, y = cls_data(n=60)
    cx, _ = CovariateDrift(rate=0.2).transform(x, y, 3, seed=0)
    assert not np.array_equal(cx, x)
    # int tokens drift by vocab-pair swap, preserving dtype
    xi = np.random.default_rng(0).integers(0, 32, (40, 8)).astype(np.int32)
    ci, _ = CovariateDrift(rate=0.5).transform(xi, y[:40], 4, seed=0)
    assert ci.dtype == xi.dtype and not np.array_equal(ci, xi)
    # abrupt: identity before at_round, full-cycle relabel after
    ad = AbruptDrift(at_round=2, severity=1.0)
    _, y0 = ad.transform(x, y, 1, seed=0)
    assert np.array_equal(y0, y)
    _, y2 = ad.transform(x, y, 2, seed=0)
    assert not np.any(y2 == y)          # a full cycle moves every label
    assert set(np.unique(y2)) == set(np.unique(y))
    # label shift: round 1 re-deal differs from the round-0 assignment
    ls = LabelShift(rate=0.25)
    i1 = ls.assign(y, (30, 30), 2, 1, seed=0)
    i0 = ls.assign(y, (30, 30), 2, 0, seed=0)
    assert not all(np.array_equal(a, b) for a, b in zip(i0, i1))


def test_get_drift_registry():
    assert isinstance(get_drift(None), NoDrift)
    assert isinstance(get_drift("covariate", rate=0.3), CovariateDrift)
    d = AbruptDrift(at_round=1)
    assert get_drift(d) is d
    with pytest.raises(ValueError):
        get_drift("nope")
    with pytest.raises(ValueError):
        get_drift(d, rate=0.5)


@pytest.mark.parametrize("drift", [
    NoDrift(), CovariateDrift(rate=0.2), LabelShift(rate=0.25),
    AbruptDrift(at_round=2)])
def test_stream_invariants_every_round(drift):
    x, y = cls_data(n=50)                # ragged: 50 over K=2, B=8
    stream = ShardStream([x, y], 2, 8, seed=3, drift=drift)
    mask0 = np.asarray(stream.batch_mask)
    for r in range(5):
        pd = stream.snapshot(r)
        # shapes are a round-0 invariant: sizes, batch counts, mask
        assert pd.sizes == stream.sizes
        assert pd.batch_counts == stream.batch_counts
        assert np.array_equal(np.asarray(pd.batch_mask), mask0)
        # exact coverage: the shards hold the whole (drifted) corpus
        dx, dy = drift.transform(x, y, r, stream.seed)
        got = np.sort(np.concatenate(
            [np.asarray(pd.full(k)[1]) for k in range(2)]))
        assert np.array_equal(got, np.sort(dy))
        assert sum(pd.sizes) == len(x)


def test_stream_shape_guard_raises():
    class BadDrift(DriftSchedule):
        name = "bad"
        reassigns = True

        def assign(self, labels, sizes, K, round_i, seed):
            # legal cover, WRONG per-shard sizes from round 1 on
            n = len(labels)
            cut = sizes[0] + (0 if round_i == 0 else 4)
            return [np.arange(cut), np.arange(cut, n)]

    x, y = cls_data(n=48)
    stream = ShardStream([x, y], 2, 8, drift=BadDrift())
    stream.snapshot(0)
    with pytest.raises(ValueError, match="changed shard shapes"):
        stream.snapshot(1)


def test_nodrift_bit_identical_to_static_pipeline():
    x, y = cls_data(n=48)
    stream = ShardStream([x, y], 2, 8, seed=1)
    idx = part_mod.scenario_indices(len(x), 2, 1, scenario="iid", labels=y,
                                    min_size=8)
    static = ParticipantData(part_mod.shard_by_indices([x, y], idx), 8, 1)
    assert stream.snapshot(0) is stream.snapshot(3)   # ONE snapshot, cached
    for r, e in [(0, 0), (1, 0), (2, 1)]:
        sb = stream.epoch_batches(r, e)
        pb = static.epoch_batches(r, e)
        assert all(np.array_equal(a, b) for a, b in zip(sb, pb))


@pytest.mark.parametrize("engine", ["python", "fused"])
def test_nodrift_training_bit_identical_both_engines(engine):
    """The all-static reduction: a NoDrift stream trains bit-for-bit like
    the frozen stack on both engines (the subsystem costs nothing)."""
    x, y = cls_data(n=48)
    cfg = CoLearnConfig(n_participants=2, T0=2, eta0=0.05, epsilon=0.02,
                        max_rounds=3)
    outs = []
    for data in (ShardStream([x, y], 2, 8, seed=1),
                 ParticipantData(part_mod.shard_by_indices(
                     [x, y], part_mod.scenario_indices(
                         len(x), 2, 1, scenario="iid", labels=y,
                         min_size=8)), 8, 1)):
        learner = CoLearner(cfg, lin_loss, round_engine=engine)
        state = learner.init(lin_params())
        for _ in range(3):
            state = learner.run_round(
                state, lambda i, j, d=data: tuple(
                    map(jnp.asarray, d.epoch_batches(i, j))))
        outs.append(state["params"])
    assert trees_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# ModelBank
# ---------------------------------------------------------------------------
class _FakeLearner:
    """learner stand-in for publish_from: shared model = slot 0."""

    def shared_model(self, state):
        return jax.tree.map(lambda t: t[0], state["params"])


class _Log:
    def __init__(self, synced):
        self.synced = synced


def _state(params_stack, round_i, synced):
    return {"params": params_stack, "round": round_i,
            "global_epoch": 2 * round_i, "log": [_Log(synced)]}


def test_bank_versioning_and_quiet_round_staleness():
    stack = stacked([lin_params(0), lin_params(1)])
    learner = _FakeLearner()
    bank = ModelBank(publish_on="synced")
    assert bank.version == 0 and bank.current() is None
    assert bank.staleness(3) >= 10 ** 6            # nothing published yet
    assert bank.publish_from(learner, _state(stack, 1, True)) is not None
    assert bank.version == 1 and bank.current().round == 1
    # quiet round: NO publish, the bank keeps the stale shared version
    assert bank.publish_from(learner, _state(stack, 2, False)) is None
    assert bank.version == 1
    assert bank.staleness(2) == 1 and bank.staleness(4) == 3
    assert bank.publish_from(learner, _state(stack, 3, True)).version == 2
    assert bank.staleness(3) == 0

    always = ModelBank(publish_on="always")
    assert always.publish_from(learner, _state(stack, 1, False)) is not None
    assert always.version == 1 and always.current().synced is False


def test_bank_swap_equals_offline_eval(tmp_path):
    """A mid-run hot-swap serves EXACTLY what an offline eval of the same
    persisted checkpoint computes."""
    p = lin_params(3)
    x, _ = cls_data(n=16)
    bank = ModelBank(dir=str(tmp_path))
    bank.publish(p, round_i=5, global_epoch=10)
    served = bank.predict_logits(lin_apply, jnp.asarray(x))
    # offline: restore the persisted npz and eval it directly
    restored = restore_pytree(os.path.join(str(tmp_path), "v1.npz"), p)
    offline = jax.nn.log_softmax(
        lin_apply(restored, jnp.asarray(x)).astype("float32"), -1)
    np.testing.assert_array_equal(np.asarray(served), np.asarray(offline))
    # and a fresh bank restored from disk serves the same thing
    bank2 = ModelBank.load(str(tmp_path), like=p)
    assert bank2.version == 1 and bank2.current().round == 5
    served2 = bank2.predict_logits(lin_apply, jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(served), np.asarray(served2))


def test_bank_ensemble_publication_mode():
    """The Table 2 ensemble baseline runs from the serving path."""
    stack = stacked([lin_params(k) for k in range(3)])
    x, y = cls_data(n=32)
    bank = ModelBank(mode="ensemble", publish_on="always")
    bank.publish(stack, round_i=1)
    lp = bank.predict_logits(lin_apply, jnp.asarray(x))
    ref = ensemble_logits(lin_apply, stack, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ref), atol=1e-6)
    acc = bank.accuracy(lin_apply, jnp.asarray(x), jnp.asarray(y))
    ref_acc = ensemble_accuracy(lin_apply, stack, jnp.asarray(x),
                                jnp.asarray(y))
    assert float(acc) == pytest.approx(float(ref_acc))


def test_bank_rejects_bad_modes():
    with pytest.raises(ValueError):
        ModelBank(mode="nope")
    with pytest.raises(ValueError):
        ModelBank(publish_on="sometimes")
    with pytest.raises(RuntimeError):
        ModelBank().predict_logits(lin_apply, jnp.zeros((1, 4)))


# ---------------------------------------------------------------------------
# ServeLoop: hot swap without recompiles, prefill through the jitted step
# ---------------------------------------------------------------------------
def test_serveloop_swap_no_recompile_and_prefill_correct():
    from repro.models import transformer as tr
    cfg = tiny_lm()
    p0 = tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    p1 = tr.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    loop = ServeLoop(cfg, p0, batch=2, max_seq=12)
    prompts = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 4)), jnp.int32)
    gen0, _ = loop.generate(prompts, 4)
    assert loop.compile_count() == 1

    # eager reference: token-by-token decode_step (the old serve.py path)
    def eager_generate(params):
        cache = tr.init_cache(cfg, 2, 12, jnp.float32)
        logits = None
        for t in range(prompts.shape[1]):
            logits, cache = tr.decode_step(params, cfg, cache,
                                           prompts[:, t:t + 1], jnp.int32(t))
        out, tok = [], jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(4):
            out.append(tok)
            logits, cache = tr.decode_step(params, cfg, cache, tok,
                                           jnp.int32(prompts.shape[1] + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return np.asarray(jnp.concatenate(out, axis=1))

    np.testing.assert_array_equal(np.asarray(gen0), eager_generate(p0))

    # hot swap: same shapes => no recompile, output = the new model's
    bank = ModelBank()
    bank.publish(p1, round_i=1)
    assert loop.poll(bank) is True and loop.version == 1
    gen1, stats = loop.generate(prompts, 4)
    assert loop.compile_count() == 1          # the swap reused the step
    assert stats["version"] == 1
    np.testing.assert_array_equal(np.asarray(gen1), eager_generate(p1))

    # a mismatched tree is rejected before it can poison the cache
    bad = dict(p1, extra=jnp.zeros((3,)))
    with pytest.raises(ValueError, match="treedef/shapes"):
        loop.swap(bad, 9)
    # an overlong decode is rejected before indexing past the cache
    with pytest.raises(ValueError, match="overruns"):
        loop.generate(prompts, 9)
    with pytest.raises(ValueError, match="batch"):
        loop.generate(jnp.zeros((3, 4), jnp.int32), 2)


def test_serve_cli_validates_max_seq():
    from repro.launch import serve
    with pytest.raises(SystemExit) as ei:
        serve.main(["--batch", "1", "--prompt-len", "16",
                    "--new-tokens", "16", "--max-seq", "24"])
    assert ei.value.code == 2                 # argparse parse-time error


def test_continuous_cli_validates_flags():
    from repro.launch import continuous
    with pytest.raises(SystemExit):
        continuous.main(["--max-seq", "8", "--prompt-len", "8",
                         "--new-tokens", "8"])
    with pytest.raises(SystemExit):
        continuous.main(["--drift", "none", "--drift-rate", "0.5"])


# ---------------------------------------------------------------------------
# resume under drift: the stream replays from (seed, round) purity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("drift", [CovariateDrift(rate=0.3),
                                   LabelShift(rate=0.25)])
def test_resume_from_checkpoint_under_drift(tmp_path, drift):
    x, y = cls_data(n=48)
    cfg = CoLearnConfig(n_participants=2, T0=2, eta0=0.05, epsilon=0.02,
                        max_rounds=4)

    def run(learner, state, stream, start, stop):
        for _ in range(start, stop):
            state = learner.run_round(
                state, lambda i, j: tuple(
                    map(jnp.asarray, stream.epoch_batches(i, j))))
        return state

    # uninterrupted: 4 rounds straight through
    stream = ShardStream([x, y], 2, 8, seed=2, drift=drift)
    learner = CoLearner(cfg, lin_loss, round_engine="fused")
    ref = run(learner, learner.init(lin_params()), stream, 0, 4)

    # interrupted: checkpoint after round 2, restore into a FRESH learner
    # and a FRESH stream built from the same arguments
    stream_a = ShardStream([x, y], 2, 8, seed=2, drift=drift)
    learner_a = CoLearner(cfg, lin_loss, round_engine="fused")
    state_a = run(learner_a, learner_a.init(lin_params()), stream_a, 0, 2)
    save_round_state(str(tmp_path / "ck"), state_a)

    stream_b = ShardStream([x, y], 2, 8, seed=2, drift=drift)
    learner_b = CoLearner(cfg, lin_loss, round_engine="fused")
    state_b = restore_round_state(str(tmp_path / "ck"),
                                  learner_b.init(lin_params()))
    state_b = run(learner_b, state_b, stream_b, 2, 4)
    assert trees_equal(ref["params"], state_b["params"])
    assert ref["round"] == state_b["round"]


def test_harness_drift_plumbing():
    """run_colearn(drift=...) stages the stream and scores the drifted
    test set; stream= passes a prebuilt one."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.harness import run_colearn
    x, y = cls_data(n=64)
    xt, yt = cls_data(n=32, seed=9)

    def init_fn(key):
        return lin_params()

    r = run_colearn(init_fn, lin_apply, (x, y), (xt, yt), K=2, rounds=2,
                    T0=1, batch_size=8, engine="fused",
                    drift=AbruptDrift(at_round=1))
    assert len(r["acc"]) == 2 and all(np.isfinite(a) for a in r["acc"])
    stream = ShardStream([x, y], 2, 8, seed=0, drift=CovariateDrift(0.2))
    r2 = run_colearn(init_fn, lin_apply, (x, y), (xt, yt), K=2, rounds=2,
                     T0=1, batch_size=8, engine="fused", stream=stream)
    assert len(r2["acc"]) == 2
    with pytest.raises(ValueError, match="not both"):
        run_colearn(init_fn, lin_apply, (x, y), (xt, yt), K=2, rounds=1,
                    drift=AbruptDrift(), stream=stream)
