"""Local-training policy API (ISSUE 4): LRSchedule / SyncPolicy protocols.

Covers: schedule semantics (CLR restarts at η^i every round boundary and
decays monotonically within a round; ELR never restarts; cosine restarts;
warmup ramps), the traced-vs-host agreement of every built-in, flag→object
parity over 3 rounds for all four legacy schedule×epochs_rule combinations,
the policy-aware ELR epoch budget (regression: one ILE doubling), and the
divergence-triggered sync policy (quiet rounds skip comm and bill 0 bytes;
fewer communicated rounds than always-sync at equal epoch budget on the
quickstart task).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards
from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.core.schedule import EpochController, divergence, switch_lr


def tiny_loss(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, {"loss": loss}


def tiny_params(key=0, d=4):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}


def tiny_batches(K, n_batches, B, d=4, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (K, n_batches, B, d))
    w_true = jnp.arange(1.0, d + 1)[:, None]
    return (x, x @ w_true)


def max_abs_diff(a, b):
    return max(float(jnp.abs(jnp.asarray(x, jnp.float32)
                             - jnp.asarray(y, jnp.float32)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


ALL_SCHEDULES = ["clr", "elr", "warmup_clr", "cosine"]


# ---------------------------------------------------------------------------
# LRSchedule semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("eta0", [0.1, 0.01, 0.003])
@pytest.mark.parametrize("decay", [0.25, 0.5, 0.9])
def test_clr_restarts_at_eta_every_round_and_decays_within(eta0, decay):
    sched = api.CLR(eta0=eta0, decay_rate=decay)
    for T in (1, 4, 7):
        for i in range(5):
            lrs = [float(sched.lr(i, j, T, i * T + j, 100))
                   for j in range(T)]
            assert np.isclose(lrs[0], eta0)        # restart at eta^i
            assert all(b < a for a, b in zip(lrs, lrs[1:]))  # monotone decay


def test_elr_never_restarts():
    sched = api.ELR(eta0=0.05, decay_rate=0.25)
    T, total = 4, 16
    lrs = [float(sched.lr(i, j, T, i * T + j, total))
           for i in range(4) for j in range(T)]
    assert all(b < a for a, b in zip(lrs, lrs[1:]))  # strictly decreasing
    assert np.isclose(lrs[-1], 0.05 * 0.25 ** (15 / 16))


def test_cosine_restarts_and_decays_to_eta_min():
    sched = api.CosineCyclical(eta0=0.1, eta_min=0.01)
    for i in range(3):
        lrs = [float(sched.lr(i, j, 5, i * 5 + j, 100)) for j in range(5)]
        assert np.isclose(lrs[0], 0.1)             # restart at eta^i
        assert all(b < a for a, b in zip(lrs, lrs[1:]))
        assert lrs[-1] > 0.01                      # floor reached at j=T only
    assert np.isclose(float(sched.lr(0, 5, 5, 5, 100)), 0.01)


def test_warmup_clr_ramps_eta_then_matches_clr():
    sched = api.WarmupCLR(eta0=0.08, decay_rate=0.25, warmup_rounds=4)
    etas = [float(sched.lr(i, 0, 3, 0, 100)) for i in range(6)]
    np.testing.assert_allclose(
        etas, [0.02, 0.04, 0.06, 0.08, 0.08, 0.08], rtol=1e-6)
    # past warmup the rates are exactly CLR's
    clr = api.CLR(eta0=0.08, decay_rate=0.25)
    for j in range(3):
        assert float(sched.lr(5, j, 3, 15 + j, 100)) == \
            float(clr.lr(5, j, 3, 15 + j, 100))


@pytest.mark.parametrize("name", ALL_SCHEDULES)
def test_traced_switch_matches_host_lr(name):
    """The shared lax.switch body (what the fused engine embeds) agrees
    with the host ``lr`` surface (what the python engine calls) for every
    built-in, across rounds/epochs/budgets."""
    sched = api.get_schedule(name, eta0=0.037, decay_rate=0.31)
    assert sched.traced_lr is switch_lr            # shared => swap-for-free
    for i in (0, 2, 5):
        sp = sched.device_round_params(i)
        assert sp["p"].shape == (4,) and sp["kind"].dtype == jnp.int32
        for (j, T, ge, total) in [(0, 1, 0, 10), (3, 8, 19, 40),
                                  (7, 8, 23, 24)]:
            got = float(switch_lr(sp, jnp.int32(j), jnp.int32(T),
                                  jnp.int32(ge), jnp.int32(total)))
            want = float(sched.lr(i, j, T, ge, total))
            np.testing.assert_allclose(got, want, rtol=1e-5)


def test_schedule_registry_resolution():
    cfg = CoLearnConfig(eta0=0.07, decay_rate=0.4, schedule="elr",
                        epochs_rule="fle", epsilon=0.02)
    # None -> the legacy cfg strings, parameterized from the cfg
    s = api.get_schedule(None, cfg)
    assert isinstance(s, api.ELR) and s.eta0 == 0.07 and s.decay_rate == 0.4
    p = api.get_sync_policy(None, cfg)
    assert isinstance(p, api.FLE)
    assert isinstance(api.get_sync_policy("ile", cfg), api.ILE)
    assert api.get_sync_policy("ile", cfg).epsilon == 0.02
    # names take cfg params; instances pass through untouched
    assert api.get_schedule("clr", cfg).eta0 == 0.07
    obj = api.WarmupCLR(eta0=0.5)
    assert api.get_schedule(obj, cfg) is obj
    trig = api.get_sync_policy("divtrigger", cfg, delta=0.125)
    assert isinstance(trig, api.DivergenceTrigger) and trig.delta == 0.125
    # the cfg's epsilon parameterizes ILE but does NOT leak into the
    # trigger's optional doubling; an EXPLICIT epsilon enables it
    assert trig.epsilon is None
    assert api.get_sync_policy("divtrigger", cfg, epsilon=0.3).epsilon == 0.3
    with pytest.raises(KeyError):
        api.get_schedule("nope")
    with pytest.raises(KeyError):
        api.get_sync_policy("nope")
    with pytest.raises(TypeError):
        api.get_schedule(42)


# ---------------------------------------------------------------------------
# flag -> object parity (the acceptance bar): all four legacy combos
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["python", "fused"])
@pytest.mark.parametrize("schedule", ["clr", "elr"])
@pytest.mark.parametrize("rule", ["ile", "fle"])
def test_string_flags_match_explicit_policy_objects(engine, schedule, rule):
    """CoLearner(schedule="clr", sync_policy="ile") and the old
    CoLearnConfig string flags must be bit-for-bit the same run."""
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=0.5,
                        schedule=schedule, epochs_rule=rule, max_rounds=3)
    b = tiny_batches(3, 2, 8)
    sched_obj = {"clr": api.CLR, "elr": api.ELR}[schedule](
        eta0=0.05, decay_rate=cfg.decay_rate)
    pol_obj = {"ile": api.ILE(epsilon=0.5), "fle": api.FLE()}[rule]
    out = {}
    for label, learner in (
            ("flags", CoLearner.from_flags(cfg, tiny_loss, engine=engine)),
            ("names", CoLearner(cfg, tiny_loss, round_engine=engine,
                                schedule=schedule, sync_policy=rule)),
            ("objects", CoLearner(cfg, tiny_loss, round_engine=engine,
                                  schedule=sched_obj, sync_policy=pol_obj))):
        state = learner.init(tiny_params())
        for _ in range(3):
            state = learner.run_round(state, lambda i, j: b)
        out[label] = (learner.shared_model(state), state)
    for label in ("names", "objects"):
        assert max_abs_diff(out["flags"][0], out[label][0]) <= 1e-6, label
        for lf, lo in zip(out["flags"][1]["log"], out[label][1]["log"]):
            assert (lf.T, lf.comm_bytes, lf.synced) == \
                (lo.T, lo.comm_bytes, lo.synced)
            np.testing.assert_allclose(
                [lf.lr_first, lf.lr_last], [lo.lr_first, lo.lr_last],
                rtol=1e-7)
        assert (out["flags"][1]["ctrl"].history
                == out[label][1]["ctrl"].history)


# ---------------------------------------------------------------------------
# policy-aware epoch budget (satellite: the ELR anneal denominator)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["python", "fused"])
def test_elr_budget_tracks_ile_doubling(engine):
    """Regression: with ILE doubling T_i the old static T0*max_rounds
    budget stranded the ELR anneal short. Zero gradients force the
    doubling: T = 1, 1, 2 over 3 rounds (4 actual epochs, static budget
    said 3). The round-2 budget must be 2 + 2*1 = 4, so its last epoch
    (ge=3) runs at eta * r^(3/4) — not the buggy r^(3/3)."""
    def zero_loss(params, batch):
        return jnp.zeros(()), {}
    cfg = CoLearnConfig(n_participants=2, T0=1, eta0=0.01, epsilon=0.01,
                        schedule="elr", epochs_rule="ile", max_rounds=3)
    learner = CoLearner(cfg, zero_loss, round_engine=engine)
    state = learner.init(tiny_params())
    b = tiny_batches(2, 1, 2)
    budgets = []
    for _ in range(3):
        budgets.append(learner.epochs_budget(state))
        state = learner.run_round(state, lambda i, j: b)
    assert [l.T for l in state["log"]] == [1, 1, 2]
    assert budgets == [3, 3, 4]
    np.testing.assert_allclose(state["log"][2].lr_last,
                               0.01 * 0.25 ** (3 / 4), rtol=1e-5)
    assert not np.isclose(state["log"][2].lr_last, 0.01 * 0.25 ** (3 / 3))


def test_fixed_T_budget_equals_legacy_static():
    cfg = CoLearnConfig(n_participants=2, T0=3, epochs_rule="fle",
                        max_rounds=5)
    learner = CoLearner(cfg, tiny_loss)
    state = learner.init(tiny_params())
    b = tiny_batches(2, 1, 2)
    for _ in range(3):
        assert learner.epochs_budget(state) == 15      # T0 * max_rounds
        state = learner.run_round(state, lambda i, j: b)


# ---------------------------------------------------------------------------
# SyncPolicy state (satellite: history triples) + DivergenceTrigger
# ---------------------------------------------------------------------------
def test_sync_state_history_stores_round_triples():
    pol = api.ILE(epsilon=0.01)
    st = pol.init_state(5)
    st = pol.update(st, 0, 0.5)
    st = pol.update(st, 1, 0.009)
    assert st.history == ((0, 0.5, 5), (1, 0.009, 10))
    assert st.T == 10 and st.skipped == ()


def test_epoch_controller_legacy_history_triples():
    """The legacy shim logs the same (round, rel, T) triples."""
    c = EpochController(T=5, epsilon=0.01, rule="ile")
    c = c.update(0.5)
    c = c.update(0.009)
    assert c.history == ((0, 0.5, 5), (1, 0.009, 10))


@pytest.mark.parametrize("engine", ["python", "fused"])
def test_divergence_trigger_skips_comm_on_quiet_round(engine):
    """Zero gradients => the locals never drift => after the (always
    divergent-looking) first round every round is quiet: no averaging, no
    opt reset, ZERO comm bytes billed."""
    def zero_loss(params, batch):
        return jnp.zeros(()), {}
    cfg = CoLearnConfig(n_participants=2, T0=1, eta0=0.01, max_rounds=4)
    learner = CoLearner(cfg, zero_loss, round_engine=engine,
                        sync_policy=api.DivergenceTrigger(delta=0.05))
    state = learner.init(tiny_params())
    b = tiny_batches(2, 1, 2)
    for _ in range(4):
        state = learner.run_round(state, lambda i, j: b)
    assert [l.synced for l in state["log"]] == [False] * 4
    assert [l.comm_bytes for l in state["log"]] == [0] * 4
    assert state["ctrl"].skipped == (0, 1, 2, 3)


@pytest.mark.parametrize("engine", ["python", "fused"])
def test_divergence_trigger_syncs_on_drift_and_engines_agree(engine):
    """On a real task the trigger syncs while training moves fast, then
    starts skipping as the locals stop drifting — and the fused engine
    takes the identical on-device decisions as the python loop."""
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=6)
    b = tiny_batches(3, 4, 8)
    learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                        sync_policy=api.DivergenceTrigger(delta=0.2))
    state = learner.init(tiny_params())
    for _ in range(6):
        state = learner.run_round(state, lambda i, j: b)
    synced = [l.synced for l in state["log"]]
    assert synced[0] is True                       # round 0 always drifts
    assert not all(synced)                         # ... but some rounds skip
    for log in state["log"]:
        assert log.comm_bytes == (0 if not log.synced else
                                  2 * learner.param_bytes(state))
    # decisions are engine-independent (asserted via a fixed expectation
    # rather than a cross-run compare so a single engine failure localizes)
    assert state["ctrl"].skipped == (3, 5), (engine, state["ctrl"].skipped)


def test_divergence_trigger_chunked_fused_matches_python():
    """T_i > chunk routes the gate through the chained-chunk finalize
    executable; decisions and trajectories must match the python loop."""
    cfg = CoLearnConfig(n_participants=3, T0=4, eta0=0.05, epsilon=0.5,
                        max_rounds=4)
    b = tiny_batches(3, 2, 8)
    out = {}
    for label, eng in (("python", api.PythonEngine()),
                       ("chunked", api.FusedEngine(chunk=2))):
        learner = CoLearner(cfg, tiny_loss, round_engine=eng,
                            sync_policy=api.DivergenceTrigger(delta=0.15))
        state = learner.init(tiny_params())
        for _ in range(4):
            state = learner.run_round(state, lambda i, j: b)
        out[label] = (learner.shared_model(state), state)
    assert max_abs_diff(out["python"][0], out["chunked"][0]) <= 1e-5
    sp, sc = out["python"][1], out["chunked"][1]
    assert [l.synced for l in sp["log"]] == [l.synced for l in sc["log"]]
    assert sp["ctrl"].skipped == sc["ctrl"].skipped
    assert any(not l.synced for l in sp["log"])    # the gate actually fired


def test_divergence_trigger_skip_preserves_local_state():
    """A quiet round must leave each participant's params AND optimizer
    state exactly as local training produced them (no averaging, no opt
    reset) — the Kamp continuation semantics."""
    cfg = CoLearnConfig(n_participants=2, T0=1, eta0=0.01, max_rounds=2)
    b = tiny_batches(2, 2, 4)
    ref = CoLearner(cfg, tiny_loss, optimizer_name="momentum")
    trig = CoLearner(cfg, tiny_loss, optimizer_name="momentum",
                     sync_policy=api.DivergenceTrigger(delta=1e9))
    s_ref = ref.init(tiny_params())
    s_trig = trig.init(tiny_params())
    # one epoch by hand = what round 0 runs before its aggregation step
    lr = float(ref.schedule.lr(0, 0, 1, 0, ref.epochs_budget(s_ref)))
    p_local, o_local, _ = ref._jit_epoch(s_ref["params"], s_ref["opt"], b,
                                         lr)
    s_trig = trig.run_round(s_trig, lambda i, j: b)
    assert not s_trig["log"][0].synced
    assert max_abs_diff(s_trig["params"], p_local) == 0.0
    assert max_abs_diff(s_trig["opt"], o_local) == 0.0


def test_divergence_metric_matches_manual():
    stacked = {"w": jnp.asarray([[3.0, 0.0], [0.0, 4.0]])}
    ref = {"w": jnp.zeros((2,))}
    # sqrt(mean(9, 16)) / max(||0||, eps) -> huge; use nonzero ref
    ref = {"w": jnp.asarray([1.0, 1.0])}
    want = np.sqrt(((2 ** 2 + 1) + (1 + 3 ** 2)) / 2) / np.sqrt(2)
    np.testing.assert_allclose(divergence(stacked, ref), want, rtol=1e-6)


# ---------------------------------------------------------------------------
# the acceptance bar: divergence-triggered co-learning on the quickstart
# task — converges with strictly fewer communicated rounds than
# FullAverage+ILE at equal epoch budget
# ---------------------------------------------------------------------------
def test_divergence_trigger_fewer_comm_rounds_equal_budget():
    from repro.configs import get_smoke_config
    from repro.data.partition import partition_arrays
    from repro.data.pipeline import ParticipantData
    from repro.data.synthetic import lm_examples
    from repro.models import transformer as tr

    cfg = get_smoke_config("internlm2-1.8b").with_(
        n_layers=1, segments=((("gqa:dense",), 1),))
    K, rounds = 3, 4
    x, y = lm_examples(0, 240, 32, cfg.vocab_size)
    data = ParticipantData(partition_arrays([x, y], K, 0), batch_size=8)

    def loss_fn(params, batch):
        bx, by = batch
        return tr.loss_fn(params, cfg, {"tokens": bx, "labels": by})

    def eb(i, j):
        return tuple(map(jnp.asarray, data.epoch_batches(i, j)))

    # epsilon=0 keeps T fixed for BOTH runs => equal epoch budget
    ccfg = CoLearnConfig(n_participants=K, T0=1, eta0=0.05, epsilon=0.0,
                         max_rounds=rounds)
    params = tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    out = {}
    for label, policy in (("ile", api.ILE(epsilon=0.0)),
                          ("trigger", api.DivergenceTrigger(delta=0.02))):
        learner = CoLearner(ccfg, loss_fn, round_engine="fused",
                            sync_policy=policy)
        state = learner.init(params)
        for _ in range(rounds):
            state = learner.run_round(state, eb)
        out[label] = state
    n_sync = {k: sum(1 for l in s["log"] if l.synced)
              for k, s in out.items()}
    assert n_sync["ile"] == rounds
    assert 0 < n_sync["trigger"] < rounds          # strictly fewer synced
    comm = {k: sum(l.comm_bytes for l in s["log"]) for k, s in out.items()}
    assert comm["trigger"] < comm["ile"]
    # and it still converges on the task
    for s in out.values():
        losses = [np.mean(l.local_losses) for l in s["log"]]
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0] - 0.1, losses


# ---------------------------------------------------------------------------
# schedule hot-swap (the retrace-free path asserted in
# benchmarks/round_latency.py --check-retrace)
# ---------------------------------------------------------------------------
def test_set_schedule_hot_swaps_without_retrace():
    cfg = CoLearnConfig(n_participants=2, T0=2, eta0=0.02, epsilon=0.0,
                        epochs_rule="fle", max_rounds=6)
    learner = CoLearner(cfg, tiny_loss, round_engine="fused")
    state = learner.init(tiny_params())
    b = tiny_batches(2, 2, 4)
    state = learner.run_round(state, lambda i, j: b)
    learner.set_schedule("cosine")
    state = learner.run_round(state, lambda i, j: b)
    learner.set_schedule(api.ELR(eta0=0.02))
    state = learner.run_round(state, lambda i, j: b)
    guards.assert_compile_count(learner._fused_round, 1,
                                "round executable")
    # the swaps took effect: cosine ends above CLR's r^((T-1)/T) tail, ELR
    # starts below eta0 (mid-anneal)
    lrs = [(l.lr_first, l.lr_last) for l in state["log"]]
    np.testing.assert_allclose(lrs[0][0], 0.02, rtol=1e-6)
    np.testing.assert_allclose(lrs[1][1], 0.01, rtol=1e-5)   # cos @ T/2
    assert lrs[2][0] < 0.02                                  # elr mid-anneal


def test_set_sync_policy_swaps_and_rebinds_the_gate():
    """Flipping the divergence gate mid-run rebinds the fused engine; a
    direct assignment that desyncs the gate fails loudly instead of
    silently ignoring the new policy."""
    cfg = CoLearnConfig(n_participants=2, T0=1, eta0=0.01, max_rounds=6)
    learner = CoLearner(cfg, tiny_loss, round_engine="fused")
    state = learner.init(tiny_params())
    b = tiny_batches(2, 2, 4)
    state = learner.run_round(state, lambda i, j: b)
    assert state["log"][-1].synced
    learner.sync_policy = api.DivergenceTrigger(delta=1e9)
    with pytest.raises(RuntimeError, match="set_sync_policy"):
        learner.run_round(state, lambda i, j: b)
    learner.set_sync_policy(api.DivergenceTrigger(delta=1e9))
    state = learner.run_round(state, lambda i, j: b)
    assert not state["log"][-1].synced and state["log"][-1].comm_bytes == 0
    learner.set_sync_policy("ile")
    state = learner.run_round(state, lambda i, j: b)
    assert state["log"][-1].synced


def test_restore_legacy_history_pairs_as_triples(tmp_path):
    """Pre-PR-4 checkpoints stored (rel, T) pairs; restore must pad them
    to the (round, rel, T) triples current consumers unpack."""
    import json

    from repro.checkpoint.io import restore_round_state, save_round_state
    cfg = CoLearnConfig(n_participants=2, T0=2, max_rounds=4)
    learner = CoLearner(cfg, tiny_loss)
    state = learner.init({"w": jnp.ones((2, 2))})
    path = str(tmp_path / "legacy")
    save_round_state(path, state)
    meta = {"round": 2, "global_epoch": 4, "T": 4, "epsilon": 0.01,
            "rule": "ile", "history": [[0.5, 2], [0.009, 4]]}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)
    restored = restore_round_state(path, learner.init({"w": jnp.ones((2, 2))}))
    assert restored["ctrl"].history == ((0, 0.5, 2), (1, 0.009, 4))
    assert [t for _, _, t in restored["ctrl"].history] == [2, 4]


def test_gated_checkpoint_roundtrips_sync_reference(tmp_path):
    """Under a divergence-gated policy the slots may hold divergent locals
    after a quiet round; the checkpoint must carry prev_avg (the last
    synced model) so a restored run gates against the right reference."""
    from repro.checkpoint.io import restore_round_state, save_round_state
    cfg = CoLearnConfig(n_participants=2, T0=1, eta0=0.05, max_rounds=6)
    b = tiny_batches(2, 2, 4)
    learner = CoLearner(cfg, tiny_loss,
                        sync_policy=api.DivergenceTrigger(delta=0.3))
    state = learner.init(tiny_params())
    for _ in range(4):                 # syncs 0-2, round 3 is quiet
        state = learner.run_round(state, lambda i, j: b)
    assert [l.synced for l in state["log"]] == [True, True, True, False]
    path = str(tmp_path / "gated")
    save_round_state(path, state)
    restored = restore_round_state(path, learner.init(tiny_params(key=9)))
    assert restored["prev_avg"] is not None
    assert max_abs_diff(restored["prev_avg"], state["prev_avg"]) == 0.0
    # after the quiet round the slots hold divergent locals — the restored
    # reference must be the last SYNCED model, not slot 0
    assert max_abs_diff(jax.tree.map(lambda t: t[0], restored["params"]),
                        restored["prev_avg"]) > 0
    assert max_abs_diff(learner._sync_ref(restored),
                        state["prev_avg"]) == 0.0
    assert restored["ctrl"].skipped == (3,)


def test_custom_gated_policy_gate_honored_by_both_engines():
    """A gated policy overriding should_sync/traced_should_sync (here an
    inverted gate: sync only while QUIET) must drive the fused engine's
    on-device decision too — and swapping to a different traced gate must
    go through set_sync_policy, not silent direct assignment."""
    import dataclasses as dc

    @dc.dataclass(frozen=True)
    class SyncWhileQuiet(api.DivergenceTrigger):
        name = "quietsync"

        def should_sync(self, div, round_i):
            return div <= self.delta

        def traced_should_sync(self, div, delta):
            return div <= delta

    def zero_loss(params, batch):
        return jnp.zeros(()), {}

    cfg = CoLearnConfig(n_participants=2, T0=1, eta0=0.01, max_rounds=3)
    b = tiny_batches(2, 1, 2)
    for eng in ("python", "fused"):
        learner = CoLearner(cfg, zero_loss, round_engine=eng,
                            sync_policy=SyncWhileQuiet(delta=0.5))
        state = learner.init(tiny_params())
        for _ in range(3):
            state = learner.run_round(state, lambda i, j: b)
        # zero gradients => div = 0 <= delta => the inverted gate SYNCS
        # every round (the default gate would skip every round)
        assert [l.synced for l in state["log"]] == [True] * 3, eng
    # swapping a gated policy for one with a DIFFERENT traced gate by
    # direct assignment desyncs the compiled executables -> loud error
    learner.sync_policy = api.DivergenceTrigger(delta=0.5)
    with pytest.raises(RuntimeError, match="set_sync_policy"):
        learner.run_round(state, lambda i, j: b)
    learner.set_sync_policy(api.DivergenceTrigger(delta=0.5))
    state = learner.run_round(state, lambda i, j: b)
    assert not state["log"][-1].synced        # default gate: quiet => skip


def test_restore_without_prev_avg_resets_stale_reference(tmp_path):
    """Restoring a checkpoint saved before any sync (prev_avg=None) into a
    mid-run state must clear the stale reference, not keep it."""
    from repro.checkpoint.io import restore_round_state, save_round_state
    cfg = CoLearnConfig(n_participants=2, T0=1, eta0=0.05, max_rounds=3)
    b = tiny_batches(2, 2, 4)
    learner = CoLearner(cfg, tiny_loss)
    fresh = learner.init(tiny_params())
    path = str(tmp_path / "round0")
    save_round_state(path, fresh)                  # prev_avg is None here
    used = learner.init(tiny_params())
    used = learner.run_round(used, lambda i, j: b)
    assert used["prev_avg"] is not None
    restored = restore_round_state(path, used)
    assert restored["prev_avg"] is None


def test_custom_plain_function_traced_lr_swaps_cleanly():
    """A subclass overriding ``traced_lr`` with a plain function (no
    staticmethod wrapper) binds as a method on attribute access; the
    engine must unwrap it — both for the hot-swap identity check and so
    the traced call doesn't receive the instance as its first argument."""
    def flat_lr(sp, epoch_j, T_i, global_epoch, total_epochs):
        return sp["p"][0] * jnp.ones(())

    class Flat(api.CLR):
        traced_lr = flat_lr
        name = "flat"

    cfg = CoLearnConfig(n_participants=2, T0=1, max_rounds=4)
    learner = CoLearner(cfg, tiny_loss, round_engine="fused")
    state = learner.init(tiny_params())
    b = tiny_batches(2, 1, 2)
    state = learner.run_round(state, lambda i, j: b)
    learner.set_schedule(Flat(eta0=0.123))
    state = learner.run_round(state, lambda i, j: b)   # must not raise
    state = learner.run_round(state, lambda i, j: b)   # nor on reuse
    np.testing.assert_allclose(state["log"][-1].lr_first, 0.123, rtol=1e-6)


def test_direct_schedule_assignment_with_custom_traced_lr_fails_loudly():
    """Bypassing set_schedule with a custom traced body must raise, not
    silently keep the old compiled schedule."""
    class Weird(api.CLR):
        traced_lr = staticmethod(lambda sp, j, T, ge, total: sp["p"][0])

    cfg = CoLearnConfig(n_participants=2, T0=1, max_rounds=2)
    learner = CoLearner(cfg, tiny_loss, round_engine="fused")
    state = learner.init(tiny_params())
    b = tiny_batches(2, 1, 2)
    state = learner.run_round(state, lambda i, j: b)
    learner.schedule = Weird()
    with pytest.raises(RuntimeError, match="set_schedule"):
        learner.run_round(state, lambda i, j: b)
    # set_schedule rebinds and runs fine
    learner.set_schedule(Weird())
    learner.run_round(state, lambda i, j: b)
