"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Every kernel is exercised over a grid of shapes and dtypes and must
allclose the ref.py oracle (deliverable c).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 32),      # MHA
    (2, 256, 8, 2, 64),      # GQA
    (1, 128, 4, 1, 32),      # MQA
    (2, 512, 4, 2, 128),     # longer, MXU-width head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    o_ref = ref.flash_attention_ref(q, k, v, n_kv_heads=KV)
    o_pal = ops.flash_attention(q, k, v, n_kv_heads=KV, impl="interpret",
                                block_q=64, block_k=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    B, S, H, KV, hd = 1, 256, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    o_ref = ref.flash_attention_ref(q, k, v, n_kv_heads=KV, window=window)
    o_pal = ops.flash_attention(q, k, v, n_kv_heads=KV, window=window,
                                impl="interpret", block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked_path():
    """The model's jnp chunked attention == the kernel (same contract)."""
    from repro.models.attention import chunked_attention
    B, S, H, KV, hd = 2, 256, 8, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    o_model = chunked_attention(q, k, v, n_kv_heads=KV, chunk_q=64,
                                chunk_kv=64)
    o_pal = ops.flash_attention(q, k, v, n_kv_heads=KV, impl="interpret",
                                block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_model),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,di,st,bd,ck", [
    (1, 64, 128, 8, 128, 32),
    (2, 128, 256, 16, 128, 64),
    (1, 256, 128, 4, 64, 256),
])
def test_selective_scan_sweep(B, S, di, st, bd, ck):
    ks = jax.random.split(KEY, 5)
    xc = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, st))
    Cm = jax.random.normal(ks[3], (B, S, st))
    A = -jnp.exp(jax.random.normal(ks[4], (di, st)) * 0.3)
    D = jnp.ones(di)
    y_ref, h_ref = ref.selective_scan_ref(xc, dt, Bm, Cm, A, D)
    y_pal, h_pal = ops.selective_scan(xc, dt, Bm, Cm, A, D, impl="interpret",
                                      block_d=bd, chunk=ck)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,hd,ck", [
    (1, 64, 2, 32, 32),
    (2, 128, 4, 64, 64),
])
def test_mlstm_sweep(B, S, H, hd, ck):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    h_ref, _ = ref.mlstm_ref(q, k, v, ig, fg)
    h_pal, _ = ops.mlstm(q, k, v, ig, fg, impl="interpret", chunk=ck)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1000, 37), (256,), (8, 8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_roundtrip_and_match(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 5).astype(dtype)
    q_p, s_p, shp = ops.quantize_blockwise(x, impl="interpret")
    q_r, s_r, _ = ref.quantize_blockwise_ref(x)
    # reduction-order ULP differences in the per-block scale may flip a
    # value sitting exactly on a quantization boundary by one step
    dq = np.abs(np.asarray(q_p[:q_r.shape[0]], np.int32)
                - np.asarray(q_r, np.int32))
    assert dq.max() <= 1 and (dq > 0).mean() < 1e-3
    x_back = ops.dequantize_blockwise(q_p, s_p, shp, impl="interpret")
    assert x_back.shape == shape
    scale = float(jnp.abs(x.astype(jnp.float32)).max())
    err = float(jnp.abs(x.astype(jnp.float32) - x_back).max())
    assert err <= scale / 127.0 + 1e-6   # int8 quantization bound
