"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Every kernel is exercised over a grid of shapes and dtypes and must
allclose the ref.py oracle (deliverable c).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 32),      # MHA
    (2, 256, 8, 2, 64),      # GQA
    (1, 128, 4, 1, 32),      # MQA
    (2, 512, 4, 2, 128),     # longer, MXU-width head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    o_ref = ref.flash_attention_ref(q, k, v, n_kv_heads=KV)
    o_pal = ops.flash_attention(q, k, v, n_kv_heads=KV, impl="interpret",
                                block_q=64, block_k=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    B, S, H, KV, hd = 1, 256, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    o_ref = ref.flash_attention_ref(q, k, v, n_kv_heads=KV, window=window)
    o_pal = ops.flash_attention(q, k, v, n_kv_heads=KV, window=window,
                                impl="interpret", block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked_path():
    """The model's jnp chunked attention == the kernel (same contract)."""
    from repro.models.attention import chunked_attention
    B, S, H, KV, hd = 2, 256, 8, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    o_model = chunked_attention(q, k, v, n_kv_heads=KV, chunk_q=64,
                                chunk_kv=64)
    o_pal = ops.flash_attention(q, k, v, n_kv_heads=KV, impl="interpret",
                                block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_model),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,di,st,bd,ck", [
    (1, 64, 128, 8, 128, 32),
    (2, 128, 256, 16, 128, 64),
    (1, 256, 128, 4, 64, 256),
])
def test_selective_scan_sweep(B, S, di, st, bd, ck):
    ks = jax.random.split(KEY, 5)
    xc = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, st))
    Cm = jax.random.normal(ks[3], (B, S, st))
    A = -jnp.exp(jax.random.normal(ks[4], (di, st)) * 0.3)
    D = jnp.ones(di)
    y_ref, h_ref = ref.selective_scan_ref(xc, dt, Bm, Cm, A, D)
    y_pal, h_pal = ops.selective_scan(xc, dt, Bm, Cm, A, D, impl="interpret",
                                      block_d=bd, chunk=ck)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,hd,ck", [
    (1, 64, 2, 32, 32),
    (2, 128, 4, 64, 64),
])
def test_mlstm_sweep(B, S, H, hd, ck):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    h_ref, _ = ref.mlstm_ref(q, k, v, ig, fg)
    h_pal, _ = ops.mlstm(q, k, v, ig, fg, impl="interpret", chunk=ck)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1000, 37), (256,), (8, 8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_roundtrip_and_match(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 5).astype(dtype)
    q_p, s_p, shp = ops.quantize_blockwise(x, impl="interpret")
    q_r, s_r, _ = ref.quantize_blockwise_ref(x)
    # reduction-order ULP differences in the per-block scale may flip a
    # value sitting exactly on a quantization boundary by one step
    dq = np.abs(np.asarray(q_p[:q_r.shape[0]], np.int32)
                - np.asarray(q_r, np.int32))
    assert dq.max() <= 1 and (dq > 0).mean() < 1e-3
    x_back = ops.dequantize_blockwise(q_p, s_p, shp, impl="interpret")
    assert x_back.shape == shape
    scale = float(jnp.abs(x.astype(jnp.float32)).max())
    err = float(jnp.abs(x.astype(jnp.float32) - x_back).max())
    assert err <= scale / 127.0 + 1e-6   # int8 quantization bound


def test_dequantize_handles_row_counts_not_multiple_of_rows():
    """Regression: the dequantizer grid used to silently drop trailing rows
    when nb % ROWS != 0 (the ref quantizer pads only to whole blocks)."""
    from repro.kernels.quantize import ROWS
    n = 3 * 256                                   # nb=3, not a ROWS multiple
    x = jax.random.normal(KEY, (n,)) * 4
    q_r, s_r, shp = ref.quantize_blockwise_ref(x)
    assert q_r.shape[0] % ROWS != 0
    x_ref = ref.dequantize_blockwise_ref(q_r, s_r, shp)
    x_pal = ops.dequantize_blockwise(q_r, s_r, shp, impl="interpret")
    np.testing.assert_array_equal(np.asarray(x_pal), np.asarray(x_ref))


def test_dequantize_rejects_inconsistent_payload():
    q = jnp.zeros((2, 256), jnp.int8)
    with pytest.raises(ValueError):
        ops.dequantize_blockwise(q, jnp.ones((3,)), (2, 256),
                                 impl="interpret")
    with pytest.raises(ValueError):
        ops.dequantize_blockwise(q, jnp.ones((2,)), (10, 256),
                                 impl="interpret")


# ---------------------------------------------------------------------------
# fused quantize->average->dequantize (Eq. 2 wire pass)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("K,n", [
    (1, 8 * 256),        # single participant, exactly one (ROWS, block) tile
    (3, 16 * 256),       # odd K, multiple tiles
    (5, 8 * 256 + 300),  # ragged n: kernel pads to whole tiles internally
])
def test_quant_avg_dequant_matches_ref(K, n):
    buf = jax.random.normal(KEY, (K, n)) * 3
    m_ref = ref.quant_avg_dequant_ref(buf)
    m_pal = ops.quant_avg_dequant(buf, impl="interpret")
    assert m_pal.shape == (n,)
    # one f32 ULP of slack: the cross-K accumulation order may differ
    np.testing.assert_allclose(np.asarray(m_pal), np.asarray(m_ref),
                               rtol=1e-7, atol=1e-6)


def test_quant_avg_dequant_is_quantized_mean():
    """The fused pass == mean of independently int8-roundtripped rows, and
    sits within the int8 error bound of the exact mean."""
    K, n = 4, 8 * 256
    buf = jax.random.normal(KEY, (K, n)) * 2
    rows = []
    for k in range(K):
        q, s, shp = ref.quantize_blockwise_ref(buf[k])
        rows.append(ref.dequantize_blockwise_ref(q, s, shp))
    expect = jnp.stack(rows).sum(0) / K
    got = ops.quant_avg_dequant(buf, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-7, atol=1e-6)
    exact = np.asarray(buf.mean(0))
    bound = np.abs(np.asarray(buf)).max() / 127.0 + 1e-6
    assert np.abs(np.asarray(got) - exact).max() <= bound


# ---------------------------------------------------------------------------
# sub-int8 bit widths (packed int4, 1-bit sign) + error feedback
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits,qmax", [(8, 127.0), (4, 7.0)])
@pytest.mark.parametrize("shape", [(1000, 37), (256,), (3 * 256 + 100,)])
def test_quantize_bits_roundtrip_bound(bits, qmax, shape):
    x = jax.random.normal(KEY, shape) * 5
    q_p, s_p, shp = ops.quantize_blockwise(x, bits=bits, impl="interpret")
    q_r, s_r, _ = ref.quantize_blockwise_ref(x, bits=bits)
    dq = np.abs(np.asarray(q_p[:q_r.shape[0]], np.int32)
                - np.asarray(q_r, np.int32))
    assert dq.max() <= 1 and (dq > 0).mean() < 1e-3
    x_back = ops.dequantize_blockwise(q_p, s_p, shp, bits=bits,
                                      impl="interpret")
    assert x_back.shape == shape
    scale = float(jnp.abs(x).max())
    err = float(jnp.abs(x - x_back).max())
    assert err <= scale / qmax + 1e-6


@pytest.mark.parametrize("shape", [(256,), (1000, 37)])
def test_quantize_1bit_semantics(shape):
    """1-bit codes are the sign; the per-block scale is mean(|x|)."""
    from repro.kernels.quantize import DEFAULT_BLOCK, unpack_codes
    x = jax.random.normal(KEY, shape) * 3
    q, s, shp = ref.quantize_blockwise_ref(x, bits=1)
    assert q.shape[-1] == DEFAULT_BLOCK // 8     # packed wire payload
    flat = np.asarray(x).reshape(-1)
    pad = -len(flat) % DEFAULT_BLOCK
    flat = np.pad(flat, (0, pad))
    blocks = flat.reshape(-1, DEFAULT_BLOCK)
    np.testing.assert_array_equal(np.asarray(unpack_codes(q, 1), np.int32),
                                  np.where(blocks > 0, 1, -1))
    np.testing.assert_allclose(np.asarray(s),
                               np.abs(blocks).mean(axis=1), rtol=1e-6)
    back = ref.dequantize_blockwise_ref(q, s, shp, bits=1)
    assert back.shape == shape
    # sign * mean|x| keeps every element within 2*mean|x| of the input
    err = np.abs(np.asarray(back) - np.asarray(x).reshape(back.shape))
    assert err.max() <= 2 * np.abs(np.asarray(x)).max()


@pytest.mark.parametrize("bits", [8, 4, 1])
def test_pack_unpack_codes_roundtrip(bits):
    from repro.kernels.quantize import pack_codes, unpack_codes
    lo, hi = (-1, 2) if bits == 1 else (-(2 ** (bits - 1) - 1),
                                        2 ** (bits - 1))
    q = jax.random.randint(KEY, (6, 256), lo, hi, jnp.int32)
    if bits == 1:
        q = jnp.where(q >= 0, 1, -1)       # valid 1-bit codes are +-1
    q = q.astype(jnp.int8)
    p = pack_codes(q, bits)
    assert p.dtype == jnp.int8 if bits == 8 else p.dtype == jnp.uint8
    assert p.shape[-1] == 256 * bits // 8
    back = unpack_codes(p, bits)
    np.testing.assert_array_equal(np.asarray(back, np.int32),
                                  np.asarray(q, np.int32))
    if bits == 8:
        assert p is q                      # identity, not a copy


@pytest.mark.parametrize("bits", [8, 4, 1])
@pytest.mark.parametrize("K,n", [(3, 16 * 256), (5, 8 * 256 + 300)])
def test_quant_avg_dequant_bits_matches_ref(bits, K, n):
    buf = jax.random.normal(KEY, (K, n)) * 3
    m_ref = ref.quant_avg_dequant_ref(buf, bits=bits)
    m_pal = ops.quant_avg_dequant(buf, bits=bits, impl="interpret")
    assert m_pal.shape == (n,)
    np.testing.assert_allclose(np.asarray(m_pal), np.asarray(m_ref),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("bits", [8, 4, 1])
def test_quant_avg_dequant_ef_oracle_and_kernel(bits):
    """EF fused pass: mean == plain pass on (buf + residual); new residual
    is exactly (buf + residual) - per-row dequant. Kernel == oracle."""
    K, n = 3, 8 * 256 + 300
    k1, k2 = jax.random.split(KEY)
    buf = jax.random.normal(k1, (K, n)) * 2
    res = jax.random.normal(k2, (K, n)) * 0.1
    m_ref, e_ref = ref.quant_avg_dequant_ef_ref(buf, res, bits=bits)
    m_pal, e_pal = ops.quant_avg_dequant_ef(buf, res, bits=bits,
                                            impl="interpret")
    np.testing.assert_allclose(np.asarray(m_pal), np.asarray(m_ref),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(np.asarray(e_pal), np.asarray(e_ref),
                               rtol=2e-6, atol=2e-6)
    # the mean is the plain fused pass over the compensated buffer
    m_plain = ref.quant_avg_dequant_ref(buf + res, bits=bits)
    np.testing.assert_allclose(np.asarray(m_ref), np.asarray(m_plain),
                               rtol=1e-6, atol=1e-6)
    # residual identity: y - dequant(quant(y)) row by row
    for k in range(K):
        q, s, shp = ref.quantize_blockwise_ref(buf[k] + res[k], bits=bits)
        dq = ref.dequantize_blockwise_ref(q, s, shp, bits=bits)
        np.testing.assert_allclose(np.asarray(e_ref[k]),
                                   np.asarray(buf[k] + res[k] - dq),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bits", [8, 4, 1])
def test_quant_avg_dequant_ef_zero_residual_is_plain(bits):
    K, n = 4, 8 * 256
    buf = jax.random.normal(KEY, (K, n)) * 2
    m_plain = ref.quant_avg_dequant_ref(buf, bits=bits)
    m_ef, e = ref.quant_avg_dequant_ef_ref(buf, jnp.zeros_like(buf),
                                           bits=bits)
    np.testing.assert_array_equal(np.asarray(m_ef), np.asarray(m_plain))
    # the residual is bounded by the quantization step of each block
    assert np.isfinite(np.asarray(e)).all()


def test_check_bits_rejects_unknown_widths():
    from repro.kernels.quantize import check_bits
    for bad in (2, 3, 16, 0):
        with pytest.raises(ValueError):
            check_bits(bad)
