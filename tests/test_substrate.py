"""Optimizers, data pipeline, checkpointing, compression substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (restore_pytree, restore_round_state,
                                 save_pytree, save_round_state)
from repro.core.compression import compressed_bytes, quantize_roundtrip
from repro.data.pipeline import ParticipantData
from repro.data.partition import partition_arrays
from repro.optim.optimizers import (SGD, AdamW, Momentum, apply_updates,
                                    clip_by_global_norm, get_optimizer,
                                    global_norm)


def test_sgd_analytic():
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    opt = SGD()
    upd, _ = opt.update(g, opt.init(p), p, lr=0.1)
    new = apply_updates(p, upd)
    np.testing.assert_allclose(new["w"], [0.95, 2.1], rtol=1e-6)


def test_momentum_accumulates():
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    opt = Momentum(beta=0.5)
    s = opt.init(p)
    u1, s = opt.update(g, s, p, 1.0)
    u2, s = opt.update(g, s, p, 1.0)
    np.testing.assert_allclose(u1["w"], [-1.0])
    np.testing.assert_allclose(u2["w"], [-1.5])   # 0.5*1 + 1


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([0.3])}
    opt = AdamW()
    u, _ = opt.update(g, opt.init(p), p, lr=0.01)
    np.testing.assert_allclose(u["w"], [-0.01], rtol=1e-4)


def test_adamw_converges_quadratic():
    opt = AdamW()
    p = {"w": jnp.array([5.0])}
    s = opt.init(p)
    for _ in range(300):
        g = jax.grad(lambda q: ((q["w"] - 2.0) ** 2).sum())(p)
        u, s = opt.update(g, s, p, 0.1)
        p = apply_updates(p, u)
    np.testing.assert_allclose(p["w"], [2.0], atol=1e-2)


def test_grad_clip():
    g = {"w": jnp.array([3.0, 4.0])}
    assert np.isclose(float(global_norm(g)), 5.0)
    c = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(global_norm(c)), 1.0)
    c2 = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(c2["w"], g["w"])


def test_get_optimizer_names():
    for n in ("sgd", "momentum", "adamw"):
        get_optimizer(n)
    with pytest.raises(KeyError):
        get_optimizer("nope")


# ---------------------------------------------------------------------------
def test_pipeline_epochs_deterministic_and_batched():
    x = np.arange(100, dtype=np.int32)
    y = x * 2
    shards = partition_arrays([x, y], 4, seed=1)
    pd = ParticipantData(shards, batch_size=5, seed=3)
    bx1, by1 = pd.epoch_batches(0, 0)
    bx2, by2 = pd.epoch_batches(0, 0)
    np.testing.assert_array_equal(bx1, bx2)          # deterministic
    assert bx1.shape == (4, 5, 5)
    np.testing.assert_array_equal(by1, bx1 * 2)      # pairing preserved
    bx3, _ = pd.epoch_batches(0, 1)
    assert not np.array_equal(bx1, bx3)              # reshuffled per epoch
    # participant k only ever sees its own shard
    for k in range(4):
        assert set(bx1[k].ravel().tolist()) <= set(shards[k][0].tolist())


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6.0).reshape(2, 3)},
            "c": [jnp.ones(4, jnp.int32), jnp.zeros((2, 2), jnp.bfloat16)]}
    path = str(tmp_path / "ck.npz")
    save_pytree(path, tree)
    like = jax.tree.map(lambda t: jnp.zeros_like(t), tree)
    back = restore_pytree(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_round_state_roundtrip(tmp_path):
    from repro.configs.base import CoLearnConfig
    from repro.core.colearn import CoLearner
    learner = CoLearner(CoLearnConfig(n_participants=2, T0=3),
                        lambda p, b: (jnp.zeros(()), {}))
    state = learner.init({"w": jnp.ones((2, 2))})
    state["round"] = 4
    state["global_epoch"] = 12
    # the sync policy (here the default ILE) owns the state transition
    state["ctrl"] = learner.sync_policy.update(state["ctrl"], 3, 0.001)
    path = str(tmp_path / "round")
    save_round_state(path, state)
    fresh = learner.init({"w": jnp.zeros((2, 2))})
    restored = restore_round_state(path, fresh)
    assert restored["round"] == 4
    assert restored["ctrl"].T == 6
    assert restored["ctrl"].history == ((3, 0.001, 6),)
    np.testing.assert_allclose(restored["params"]["w"], state["params"]["w"])


def test_round_state_persists_optimizer(tmp_path):
    """ISSUE 5 satellite: a checkpoint must carry state["opt"] — restoring
    mid-run and continuing must match the uninterrupted run exactly, even
    with a stateful optimizer (momentum would otherwise silently reset)."""
    from repro.configs.base import CoLearnConfig
    from repro.core import api
    from repro.core.colearn import CoLearner

    def loss(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2), {}

    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (4, 1))}
    x = jax.random.normal(k, (2, 3, 8, 4))
    batches = (x, x @ jnp.ones((4, 1)))
    # a gated policy with a huge delta never syncs, so the local momentum
    # is live across rounds — exactly the state a restore must not lose
    cfg = CoLearnConfig(n_participants=2, T0=2, eta0=0.05, max_rounds=6)

    def make():
        learner = CoLearner(cfg, loss, optimizer_name="momentum",
                            sync_policy=api.DivergenceTrigger(delta=1e9))
        return learner, learner.init(params)

    learner, state = make()
    for _ in range(2):
        state = learner.run_round(state, lambda i, j: batches)
    path = str(tmp_path / "mid")
    save_round_state(path, state)
    for _ in range(2):                               # uninterrupted arm
        state = learner.run_round(state, lambda i, j: batches)

    learner2, fresh = make()
    resumed = restore_round_state(path, fresh)
    for t, s in zip(jax.tree.leaves(resumed["opt"]),
                    jax.tree.leaves(state["opt"])):
        assert t.shape == s.shape
    for _ in range(2):                               # resumed arm
        resumed = learner2.run_round(resumed, lambda i, j: batches)
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree.leaves(state["opt"]),
                    jax.tree.leaves(resumed["opt"])):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_round_state_legacy_checkpoint_without_opt(tmp_path):
    """Pre-opt-persistence checkpoints (no has_opt / .opt.npz) restore with
    the caller's opt.init state — the documented legacy fallback."""
    from repro.configs.base import CoLearnConfig
    from repro.core.colearn import CoLearner
    learner = CoLearner(CoLearnConfig(n_participants=2, T0=1),
                        lambda p, b: (jnp.zeros(()), {}),
                        optimizer_name="momentum")
    state = learner.init({"w": jnp.ones((2, 2))})
    path = str(tmp_path / "legacy_opt")
    save_round_state(path, state)
    os.remove(path + ".opt.npz")
    import json
    with open(path + ".meta.json") as f:
        meta = json.load(f)
    del meta["has_opt"]
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)
    fresh = learner.init({"w": jnp.zeros((2, 2))})
    restored = restore_round_state(path, fresh)
    for t in jax.tree.leaves(restored["opt"]):
        np.testing.assert_allclose(t, 0.0)           # momentum re-zeroed


def test_compression_roundtrip_close_and_smaller():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,)),
            "tiny": jnp.ones(3)}
    back = quantize_roundtrip(tree, block=256)
    err = float(jnp.abs(tree["w"] - back["w"]).max())
    assert err < float(jnp.abs(tree["w"]).max()) / 100
    np.testing.assert_array_equal(back["tiny"], tree["tiny"])  # small skipped
    raw = sum(t.size * 4 for t in jax.tree.leaves(tree))
    assert compressed_bytes(tree) < raw / 3
