"""Heterogeneous-data scenario subsystem (ISSUE 5): partitioners cover
every example exactly once, the ragged pipeline masks instead of clamping,
and example-count-weighted averaging generalizes Eq. 2 without perturbing
the equal-shard paper path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.data.partition import (dirichlet_partition, partition,
                                  partition_arrays, quantity_skew,
                                  shard_by_indices)
from repro.data.pipeline import ParticipantData


def tiny_loss(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, {"loss": loss}


def tiny_params(key=0, d=4):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}


def tiny_batches(K, n_batches, B, d=4, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (K, n_batches, B, d))
    w_true = jnp.arange(1.0, d + 1)[:, None]
    return (x, x @ w_true)


def max_abs_diff(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def assert_exactly_once(idx, n):
    ids = np.concatenate([np.asarray(i) for i in idx])
    assert len(ids) == n
    assert np.array_equal(np.sort(ids), np.arange(n))


# ---------------------------------------------------------------------------
# Partitioners: every example in exactly one shard
# ---------------------------------------------------------------------------
def test_partition_remainder_round_robin():
    idx = partition(103, 5, seed=0)
    assert_exactly_once(idx, 103)
    assert sorted(len(i) for i in idx) == [20, 20, 21, 21, 21]


def test_partition_drop_remainder_is_explicit_optin():
    idx = partition(103, 5, seed=0, drop_remainder=True)
    assert all(len(i) == 20 for i in idx)                 # paper-equal
    ids = np.concatenate(idx)
    assert len(ids) == 100 and len(np.unique(ids)) == 100  # disjoint


def test_partition_arrays_covers_everything():
    x = np.arange(10)
    shards = partition_arrays([x], 3, seed=1)
    assert sorted(np.concatenate([s[0] for s in shards]).tolist()) \
        == list(range(10))


def test_dirichlet_partition_covers_and_respects_min_size():
    labels = np.random.default_rng(0).integers(0, 10, 997)
    for alpha in (0.1, 1.0, 100.0):
        idx = dirichlet_partition(labels, 5, alpha, seed=3, min_size=8)
        assert_exactly_once(idx, 997)
        assert min(len(i) for i in idx) >= 8


def test_dirichlet_alpha_controls_label_skew():
    """Small alpha concentrates each shard on few labels; large alpha
    approaches the IID mixture. Measured as the mean max-label fraction
    per shard (1.0 = single-label shard, 1/C = perfectly IID)."""
    labels = np.random.default_rng(1).integers(0, 10, 4000)

    def mean_max_frac(alpha):
        idx = dirichlet_partition(labels, 5, alpha, seed=5)
        fracs = []
        for i in idx:
            counts = np.bincount(labels[i], minlength=10)
            fracs.append(counts.max() / counts.sum())
        return np.mean(fracs)

    skewed, iid_ish = mean_max_frac(0.1), mean_max_frac(100.0)
    assert skewed > iid_ish + 0.1, (skewed, iid_ish)
    assert iid_ish < 0.2                  # ~1/10 with sampling noise


def test_quantity_skew_counts_and_fractions():
    idx = quantity_skew(100, [50, 30, 20], seed=0)
    assert [len(i) for i in idx] == [50, 30, 20]
    assert_exactly_once(idx, 100)
    # fractions: largest-remainder rounding still covers exactly n
    idx = quantity_skew(101, [0.5, 0.3, 0.2], seed=0)
    assert sum(len(i) for i in idx) == 101
    assert_exactly_once(idx, 101)


def test_quantity_skew_rejects_bad_sizes():
    with pytest.raises(ValueError):
        quantity_skew(100, [60, 30, 20], seed=0)      # sums to 110
    with pytest.raises(ValueError):
        quantity_skew(100, [100, 0], seed=0)          # empty shard
    with pytest.raises(ValueError):
        quantity_skew(100, [50.5, 49.5], seed=0)      # non-integer counts


# ---------------------------------------------------------------------------
# Hypothesis: the coverage property over every partitioner
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional test dep — skip, don't error
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=25, deadline=None)

    @given(st.integers(10, 400), st.integers(1, 8), st.integers(0, 99))
    @settings(**SETTINGS)
    def test_partition_covers_each_example_exactly_once(n, K, seed):
        assert_exactly_once(partition(n, K, seed), n)
        sizes = [len(i) for i in partition(n, K, seed)]
        assert max(sizes) - min(sizes) <= 1

    @given(st.integers(20, 300), st.integers(1, 5), st.integers(2, 8),
           st.sampled_from([0.1, 0.5, 2.0, 50.0]), st.integers(0, 99))
    @settings(**SETTINGS)
    def test_dirichlet_covers_each_example_exactly_once(n, K, n_classes,
                                                        alpha, seed):
        labels = np.random.default_rng(seed).integers(0, n_classes, n)
        assert_exactly_once(dirichlet_partition(labels, K, alpha, seed), n)

    @given(st.integers(2, 6), st.integers(0, 99), st.integers(50, 300))
    @settings(**SETTINGS)
    def test_quantity_skew_covers_each_example_exactly_once(K, seed, n):
        fracs = np.random.default_rng(seed).dirichlet(np.ones(K) * 2)
        # keep every shard non-empty for arbitrary fractions
        fracs = (fracs + 1.0 / n) / (fracs + 1.0 / n).sum()
        assert_exactly_once(quantity_skew(n, fracs, seed), n)


# ---------------------------------------------------------------------------
# Ragged pipeline: per-participant batch counts + validity mask
# ---------------------------------------------------------------------------
def _ragged_data(n=100, sizes=(50, 30, 20), B=10, seed=0):
    x = np.arange(n, dtype=np.float32)[:, None]
    y = x + 1000.0
    shards = shard_by_indices([x, y], quantity_skew(n, list(sizes), seed))
    return ParticipantData(shards, B, seed), shards


def test_ragged_pipeline_no_min_clamp():
    data, shards = _ragged_data()
    assert data.sizes == (50, 30, 20)
    assert data.batch_counts == (5, 3, 2)     # per-shard, NOT min-clamped
    assert data.n_batches == 5 and data.ragged
    mask = data.batch_mask
    assert mask.shape == (3, 5)
    np.testing.assert_array_equal(mask.sum(1), [5, 3, 2])
    bx, by = data.epoch_batches(0, 0)
    assert bx.shape == (3, 5, 10, 1)
    for k in range(3):
        own = set(np.asarray(shards[k][0]).ravel().tolist())
        # valid slots enumerate the shard's own examples...
        valid = bx[k][mask[k]].ravel()
        assert set(valid.tolist()) <= own
        # ...and within one epoch every example of a full-batch-multiple
        # shard appears exactly once in the valid slots
        assert len(np.unique(valid)) == data.batch_counts[k] * data.B
        # padding slots cycle the shard's OWN data (never another shard's,
        # never garbage) so mask-unaware consumers degrade gracefully
        assert set(bx[k].ravel().tolist()) <= own


def test_equal_shards_stay_bit_compatible():
    """The classic equal-IID pipeline is unchanged: not ragged, all-True
    mask, and epoch_batches identical to the pre-ragged formula."""
    data, shards = _ragged_data(n=90, sizes=(30, 30, 30), B=10)
    assert not data.ragged and data.batch_mask.all()
    bx, _ = data.epoch_batches(3, 1)
    for k, shard in enumerate(shards):
        rng = np.random.default_rng((data.seed, k, 3, 1, 0xC0))
        perm = rng.permutation(30)[:30]
        np.testing.assert_array_equal(bx[k], shard[0][perm].reshape(3, 10, 1))


def test_pipeline_still_rejects_subbatch_shard():
    x = np.arange(12, dtype=np.float32)[:, None]
    shards = shard_by_indices([x, x], quantity_skew(12, [8, 4], 0))
    with pytest.raises(ValueError, match="smaller than one batch"):
        ParticipantData(shards, batch_size=5)     # shard 1 < one batch


# ---------------------------------------------------------------------------
# Masked engines: ragged == per-shard exact; equal == unmasked bit path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["python", "fused"])
def test_masked_equals_unmasked_on_equal_shards(engine):
    """When shards happen to be equal, the masked-ragged path (all-True
    mask) must reproduce the truncated-equal (unmasked) trajectory."""
    K, nb = 3, 4
    b = tiny_batches(K, nb, 8)
    cfg = CoLearnConfig(n_participants=K, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=2)
    out = {}
    for mask in (None, np.ones((K, nb), bool)):
        learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                            batch_mask=mask)
        state = learner.init(tiny_params())
        for _ in range(2):
            state = learner.run_round(state, lambda i, j: b)
        out[mask is None] = (learner.shared_model(state), state)
    assert max_abs_diff(out[True][0], out[False][0]) <= 1e-6
    for lu, lm in zip(out[True][1]["log"], out[False][1]["log"]):
        np.testing.assert_allclose(lu.local_losses, lm.local_losses,
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("engine", ["python", "fused"])
def test_masked_step_is_identity_carry(engine):
    """A masked-out batch slot must not touch params, opt state, or the
    loss mean — participant k trains on exactly its batch_counts[k] slots."""
    K, nb = 2, 3
    b = tiny_batches(K, nb, 8)
    mask = np.array([[True, True, True], [True, False, False]])
    cfg = CoLearnConfig(n_participants=K, T0=1, eta0=0.05, epsilon=0.5,
                        max_rounds=1, epochs_rule="fle")
    learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                        optimizer_name="momentum", batch_mask=mask)
    # reference: participant 1 truncated to its single valid batch
    state = learner.init(tiny_params())
    # run ONE local epoch manually through the learner's epoch body, then
    # compare against per-participant plain SGD over only the valid slots
    from repro.core.schedule import clr_lr
    lr = clr_lr(0.05, 0.25, 0, 1)
    stacked, opt, loss = learner._jit_epoch(
        state["params"], state["opt"], b, lr, jnp.asarray(mask))
    for k, n_valid in ((0, 3), (1, 1)):
        p = tiny_params()
        m = jax.tree.map(lambda t: jnp.zeros_like(t), p)
        for s in range(n_valid):
            g = jax.grad(lambda q, _k=k, _s=s: tiny_loss(
                q, (b[0][_k, _s], b[1][_k, _s]))[0])(p)
            m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
            p = jax.tree.map(lambda a, mm: a - lr * mm, p, m)
        got = jax.tree.map(lambda t, _k=k: t[_k], stacked)
        assert max_abs_diff(got, p) <= 1e-5, k
        got_m = jax.tree.map(lambda t, _k=k: t[_k], opt)
        assert max_abs_diff(got_m, m) <= 1e-5, k


def test_ragged_python_matches_fused():
    K, nb = 3, 4
    b = tiny_batches(K, nb, 8)
    mask = np.array([[True] * 4, [True] * 2 + [False] * 2,
                     [True] * 3 + [False]])
    cfg = CoLearnConfig(n_participants=K, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=3)
    out = {}
    for engine in ("python", "fused"):
        learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                            batch_mask=mask)
        state = learner.init(tiny_params())
        for _ in range(3):
            state = learner.run_round(state, lambda i, j: b)
        out[engine] = (learner.shared_model(state), state)
    assert max_abs_diff(out["python"][0], out["fused"][0]) <= 1e-5
    for lp, lf in zip(out["python"][1]["log"], out["fused"][1]["log"]):
        np.testing.assert_allclose(lp.local_losses, lf.local_losses,
                                   rtol=1e-5, atol=1e-7)


def test_ragged_chunked_fused_matches_single_shot():
    K, nb = 2, 2
    b = tiny_batches(K, nb, 8)
    mask = np.array([[True, True], [True, False]])
    cfg = CoLearnConfig(n_participants=K, T0=5, eta0=0.05, epsilon=0.5,
                        epochs_rule="fle", max_rounds=2)
    ref = None
    for chunk in (32, 2):
        learner = CoLearner(cfg, tiny_loss, batch_mask=mask,
                            round_engine=api.FusedEngine(chunk=chunk))
        state = learner.init(tiny_params())
        for _ in range(2):
            state = learner.run_round(state, lambda i, j: b)
        model = learner.shared_model(state)
        if ref is None:
            ref = model
        else:
            assert max_abs_diff(ref, model) <= 1e-5, chunk


def test_learner_rejects_bad_mask():
    cfg = CoLearnConfig(n_participants=2, T0=1, max_rounds=1)
    with pytest.raises(ValueError, match="batch_mask"):
        CoLearner(cfg, tiny_loss, batch_mask=np.ones((3, 2), bool))
    with pytest.raises(ValueError, match="zero valid"):
        CoLearner(cfg, tiny_loss,
                  batch_mask=np.array([[True, True], [False, False]]))


# ---------------------------------------------------------------------------
# Weighted Eq. 2 (FedAvg generalization)
# ---------------------------------------------------------------------------
def test_full_average_weighted_matrix():
    agg = api.FullAverage(weights=(1.0, 3.0))
    W = agg.mixing_matrix(0, 2)
    np.testing.assert_allclose(W, [[0.25, 0.75], [0.25, 0.75]], rtol=1e-6)
    assert agg.uses_weights
    assert not api.FullAverage().uses_weights
    with pytest.raises(ValueError):
        api.FullAverage(weights=(1.0,)).mixing_matrix(0, 2)
    with pytest.raises(ValueError):
        api.FullAverage(weights=(0.0, 0.0)).mixing_matrix(0, 2)


@pytest.mark.parametrize("engine", ["python", "fused"])
@pytest.mark.parametrize("codec", ["exact", "fused"])
def test_weighted_uniform_matches_unweighted_on_equal_shards(engine, codec):
    """Equal weights == the paper's uniform Eq. 2 (<=1e-6 across engines
    and codecs) — the weighted plumbing costs nothing on the paper path."""
    K = 3
    b = tiny_batches(K, 2, 8, d=8)
    cfg = CoLearnConfig(n_participants=K, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=2)
    out = {}
    for weights in (None, (5.0, 5.0, 5.0)):
        learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                            codec=codec,
                            aggregator=api.FullAverage(weights=weights))
        state = learner.init(tiny_params(d=8))
        for _ in range(2):
            state = learner.run_round(state, lambda i, j: b)
        out[weights is None] = learner.shared_model(state)
    assert max_abs_diff(out[True], out[False]) <= 1e-6


def test_weighted_average_is_weighted_mean():
    """The traced weighted aggregate == the literal Σ_k (n_k/n) w_k."""
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(2), (3, 7, 5))}
    agg = api.FullAverage(weights=(10.0, 30.0, 60.0))
    fn = agg.make_aggregate_fn(api.ExactF32())
    W = jnp.asarray(agg.mixing_matrix(0, 3))
    got = fn(stacked, W)
    want = jnp.einsum("k,k...->...", jnp.asarray([0.1, 0.3, 0.6]),
                      stacked["w"])
    np.testing.assert_allclose(got["w"][0], want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["w"][0], got["w"][-1], rtol=1e-6)


def test_weighted_flat_fused_mean_matches_exact_within_wire_noise():
    """The flat-buffer weighted fused mean == exact weighted mean up to
    the int8 wire error bound, and == the leafwise weighted path 1e-6 on
    block-aligned trees (same codes, same scales)."""
    K = 4
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    stacked = {"w": jax.random.normal(ks[0], (K, 3, 256)),
               "v": jax.random.normal(ks[1], (K, 512))}
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    flat = api.FlatFusedInt8().make_fused_mean(weighted=True)(stacked, w)
    leaf = api.mix_participants(
        api.LeafwiseInt8().roundtrip(stacked),
        jnp.broadcast_to(w, (K, K)))
    assert max_abs_diff(flat, leaf) <= 1e-6
    exact = api.mix_participants(stacked, jnp.broadcast_to(w, (K, K)))
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(exact)):
        bound = float(jnp.abs(b).max()) / 127.0 + 1e-6
        assert float(jnp.abs(a - b).max()) <= bound


def test_partial_participation_autowires_shard_sizes():
    """CoLearner(shard_sizes=...) upgrades a weight-less partial aggregator
    to FedAvg shard-size weighting (the docstring's promise made real);
    explicit weights are left alone."""
    cfg = CoLearnConfig(n_participants=3, T0=1, max_rounds=1)
    learner = CoLearner(cfg, tiny_loss,
                        aggregator=api.PartialParticipation(m=2),
                        shard_sizes=(10, 20, 30))
    assert learner.aggregator.weights == (10, 20, 30)
    learner2 = CoLearner(
        cfg, tiny_loss,
        aggregator=api.PartialParticipation(m=2, weights=(1.0, 1.0, 1.0)),
        shard_sizes=(10, 20, 30))
    assert learner2.aggregator.weights == (1.0, 1.0, 1.0)
    with pytest.raises(ValueError, match="shard_sizes"):
        CoLearner(cfg, tiny_loss, shard_sizes=(10, 20))


@pytest.mark.parametrize("engine", ["python", "fused"])
def test_heterogeneous_end_to_end(engine):
    """The full scenario: quantity-skewed shards + ragged mask + weighted
    Eq. 2 trains and logs coherently on both engines."""
    n, K, B = 120, 3, 8
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, 4)).astype(np.float32)
    y = (x @ np.arange(1.0, 5.0)[:, None]).astype(np.float32)
    data, _ = None, None
    shards = shard_by_indices([x, y], quantity_skew(n, [64, 32, 24], 0))
    data = ParticipantData(shards, B, 0)
    assert data.ragged
    cfg = CoLearnConfig(n_participants=K, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=2)
    learner = CoLearner(cfg, tiny_loss, round_engine=engine,
                        aggregator=api.FullAverage(weights=data.sizes),
                        shard_sizes=data.sizes, batch_mask=data.batch_mask)
    state = learner.init(tiny_params())
    for _ in range(2):
        state = learner.run_round(
            state, lambda i, j: tuple(map(jnp.asarray,
                                          data.epoch_batches(i, j))))
    losses = [float(np.mean(l.local_losses)) for l in state["log"]]
    assert losses[-1] < losses[0]
    assert state["log"][-1].comm_bytes > 0
