"""Paper-claims substrate: synthetic tasks + conv/recurrent testbeds.

Regression guard for the class-template bug (train/test splits must share
classes — caught when every image benchmark sat at chance accuracy).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import audio_like, image_like, lm_examples, text_like
from repro.models.convnets import AUDIO_MODELS, IMAGE_MODELS, TEXT_MODELS


def test_image_classes_consistent_across_seeds():
    """Class means from two different seeds must match (shared templates)."""
    x0, y0 = image_like(seed=0, n=2000)
    x1, y1 = image_like(seed=777, n=2000)
    m0 = np.stack([x0[y0 == c].mean(0) for c in range(10)])
    m1 = np.stack([x1[y1 == c].mean(0) for c in range(10)])
    # same-class means correlate far better than cross-class
    same = np.mean([np.corrcoef(m0[c].ravel(), m1[c].ravel())[0, 1]
                    for c in range(10)])
    cross = np.mean([np.corrcoef(m0[c].ravel(), m1[(c + 1) % 10].ravel())[0, 1]
                     for c in range(10)])
    assert same > 0.8 and same > cross + 0.5


def test_text_markers_class_consistent():
    x0, y0 = text_like(seed=0, n=500)
    x1, y1 = text_like(seed=9, n=500)
    # class-c examples contain class-c marker tokens (deterministic ids)
    for xs, ys in ((x0, y0), (x1, y1)):
        c = int(ys[0])
        assert set(range(c * 3, c * 3 + 3)) <= set(xs[0].tolist())


def test_lm_examples_next_token_pairs():
    x, y = lm_examples(seed=0, n=8, seq_len=16, vocab=64)
    assert x.shape == y.shape == (8, 16)
    assert (x[:, 1:] == y[:, :-1]).all()       # labels are shifted inputs
    assert x.max() < 64 and x.min() >= 0


@pytest.mark.parametrize("models,data", [
    (IMAGE_MODELS, image_like), (TEXT_MODELS, text_like),
    (AUDIO_MODELS, audio_like)])
def test_testbed_models_forward_and_grad(models, data):
    x, y = data(seed=0, n=8)
    xb, yb = jnp.asarray(x[:4]), jnp.asarray(y[:4])
    for name, (init_fn, apply_fn) in models.items():
        p = init_fn(jax.random.PRNGKey(0))
        logits = apply_fn(p, xb)
        assert logits.shape[0] == 4 and bool(jnp.isfinite(logits).all()), name
        # params must be a pure array pytree (strings break stacking)
        assert all(hasattr(t, "dtype") for t in jax.tree.leaves(p)), name
        g = jax.grad(lambda q, _f=apply_fn: _f(q, xb).sum())(p)
        assert all(bool(jnp.isfinite(t).all()) for t in jax.tree.leaves(g)), name


def test_image_task_linearly_learnable():
    """A linear probe must beat chance comfortably — guards task sanity."""
    x, y = image_like(seed=0, n=2000)
    xt, yt = image_like(seed=50, n=500)
    X = x.reshape(len(x), -1)
    # ridge closed-form on one-hot targets
    Y = np.eye(10)[y]
    W = np.linalg.solve(X.T @ X + 10 * np.eye(X.shape[1]), X.T @ Y)
    acc = (np.argmax(xt.reshape(len(xt), -1) @ W, -1) == yt).mean()
    assert acc > 0.5, acc
