"""Round-strategy API (repro.core.api): protocol conformance, flag/object
parity, the new aggregation scenarios, and the comm-byte accounting fix."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CoLearnConfig
from repro.core import api, averaging
from repro.core.colearn import CoLearner
from repro.core.compression import compressed_bytes, flat_compressed_bytes


def tiny_loss(params, batch):
    x, y = batch
    pred = x @ params["w"] + params["b"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, {"loss": loss}


def tiny_params(key=0, d=4):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}


def tiny_batches(K, n_batches, B, d=4, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (K, n_batches, B, d))
    w_true = jnp.arange(1.0, d + 1)[:, None]
    return (x, x @ w_true)


def mixed_tree(K=3, seed=7):
    """Stacked tree spanning block-aligned, odd-size, sub-block leaves."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {"w": jax.random.normal(ks[0], (K, 2, 256)),
            "odd": jax.random.normal(ks[1], (K, 300)),
            "tiny": jax.random.normal(ks[2], (K, 5)),
            "vec": jax.random.normal(ks[3], (K,))}


def max_abs_diff(a, b):
    return max(float(jnp.abs(jnp.asarray(x, jnp.float32)
                             - jnp.asarray(y, jnp.float32)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# WireCodec conformance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["exact", "leafwise", "fused"])
def test_codec_conformance(name):
    codec = api.get_codec(name)
    stacked = mixed_tree()
    rt = codec.roundtrip(stacked)
    # structure preserved
    assert jax.tree.structure(rt) == jax.tree.structure(stacked)
    for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(stacked)):
        assert a.shape == b.shape and a.dtype == b.dtype
        # int8 wire error bound (exact codec: identity)
        amax = float(jnp.abs(b).max())
        bound = 0.0 if name == "exact" else amax / 127.0 + 1e-6
        assert float(jnp.abs(a - b).max()) <= bound
    # encode/decode compose to the same wire emulation
    assert max_abs_diff(codec.decode(codec.encode(stacked)), rt) == 0.0
    if name == "leafwise":
        # pinned bitwise to the PR-2 reference path (same bypass threshold,
        # same kernels) so the two implementations can never drift
        from repro.core.compression import quantize_roundtrip
        assert max_abs_diff(rt, quantize_roundtrip(stacked)) == 0.0
    # exact per-participant byte accounting
    wb = codec.wire_bytes(stacked)
    assert isinstance(wb, int) and wb > 0
    one = jax.tree.map(lambda t: t[0], stacked)
    raw = sum(t.size * t.dtype.itemsize for t in jax.tree.leaves(one))
    if name == "exact":
        assert wb == raw
    elif name == "leafwise":
        assert wb == compressed_bytes(one)
    else:
        assert wb == flat_compressed_bytes(stacked)
        n = sum(t.size for t in jax.tree.leaves(one))
        assert wb >= n          # every element on the int8 + scale format


def test_codec_registry_rejects_unknown():
    with pytest.raises(KeyError):
        api.get_codec("nope")
    with pytest.raises(KeyError):
        api.get_aggregator("nope")
    assert api.get_engine("fused", chunk=7).chunk == 7
    # instances pass through untouched
    c = api.LeafwiseInt8(block=128)
    assert api.get_codec(c) is c
    # legacy registry aliases (CoLearnConfig.compress / old CLI spellings)
    assert isinstance(api.get_codec("none"), api.ExactF32)
    assert isinstance(api.get_codec("int8"), api.LeafwiseInt8)
    assert isinstance(api.get_codec("flat"), api.FlatFusedInt8)


# ---------------------------------------------------------------------------
# Aggregator conformance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["full", "partial", "ring"])
def test_aggregator_mixing_matrix_row_stochastic(name):
    agg = api.get_aggregator(name)
    for i in range(3):
        W = agg.mixing_matrix(i, 4)
        assert W.shape == (4, 4) and W.dtype == np.float32
        assert (W >= 0).all()
        np.testing.assert_allclose(W.sum(axis=1), 1.0, rtol=1e-6)


def test_full_average_matches_average_pjit():
    stacked = mixed_tree()
    fn = api.FullAverage().make_aggregate_fn(api.ExactF32())
    assert max_abs_diff(fn(stacked, None),
                        averaging.average_pjit(stacked)) == 0.0


def test_partial_participation_samples_m_and_weights():
    agg = api.PartialParticipation(m=2, weights=(1.0, 2.0, 3.0, 4.0), seed=3)
    W = agg.mixing_matrix(0, 4)
    sel = np.nonzero(W[0])[0]
    assert len(sel) == 2                      # exactly m active columns
    np.testing.assert_allclose(W, np.broadcast_to(W[0], (4, 4)))
    base = np.array([1.0, 2.0, 3.0, 4.0])
    np.testing.assert_allclose(W[0, sel], base[sel] / base[sel].sum(),
                               rtol=1e-6)
    # deterministic in (seed, round); varies across rounds
    np.testing.assert_array_equal(W, agg.mixing_matrix(0, 4))
    assert any(not np.array_equal(W, agg.mixing_matrix(i, 4))
               for i in range(1, 8))
    # the aggregate ignores unsampled participants entirely
    stacked = mixed_tree(K=4)
    out = api.PartialParticipation(m=2, seed=3).make_aggregate_fn(
        api.ExactF32())(stacked, jnp.asarray(W))
    unsampled = [k for k in range(4) if k not in sel]
    perturbed = jax.tree.map(lambda t: t.at[unsampled[0]].add(100.0), stacked)
    out2 = api.PartialParticipation(m=2, seed=3).make_aggregate_fn(
        api.ExactF32())(perturbed, jnp.asarray(W))
    assert max_abs_diff(out, out2) == 0.0
    with pytest.raises(ValueError):
        api.PartialParticipation(m=9).mixing_matrix(0, 4)


def test_partial_participation_never_samples_zero_weight():
    """Regression: a sample landing only on zero-weight participants used
    to normalize 0/0 into an all-NaN mixing matrix."""
    agg = api.PartialParticipation(m=1, weights=(0.0, 1.0, 1.0), seed=0)
    for i in range(16):
        W = agg.mixing_matrix(i, 3)
        assert np.isfinite(W).all()
        assert W[0, 0] == 0.0                 # weightless participant 0
        np.testing.assert_allclose(W.sum(axis=1), 1.0, rtol=1e-6)
    with pytest.raises(ValueError, match="positive weight"):
        api.PartialParticipation(m=2, weights=(0.0, 0.0, 1.0)).mixing_matrix(
            0, 3)
    with pytest.raises(ValueError, match="finite"):
        api.PartialParticipation(m=1, weights=(-1.0, 1.0, 1.0)).mixing_matrix(
            0, 3)


def test_ring_gossip_neighbor_average():
    K = 4
    stacked = mixed_tree(K=K)
    agg = api.RingGossip()
    W = agg.mixing_matrix(0, K)
    out = agg.make_aggregate_fn(api.ExactF32())(stacked, jnp.asarray(W))
    for got, t in zip(jax.tree.leaves(out), jax.tree.leaves(stacked)):
        want = 0.5 * (t + jnp.roll(t, 1, axis=0))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_ring_gossip_quantizes_only_the_received_leg():
    """A participant's own model never crosses the wire in gossip: under a
    lossy codec, only the neighbor's (received) half may carry int8 error —
    the local half must stay bit-exact."""
    K = 3
    codec = api.LeafwiseInt8()
    stacked = mixed_tree(K=K)
    agg = api.RingGossip()
    out = agg.make_aggregate_fn(codec)(
        stacked, jnp.asarray(agg.mixing_matrix(0, K)))
    rt = codec.roundtrip(stacked)
    for got, t, q in zip(jax.tree.leaves(out), jax.tree.leaves(stacked),
                         jax.tree.leaves(rt)):
        want = 0.5 * t + 0.5 * jnp.roll(q, 1, axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    # and the codec does perturb the received leg (the test has teeth)
    assert max_abs_diff(rt, stacked) > 0


# ---------------------------------------------------------------------------
# from_flags <-> explicit objects parity (the PR-2 surface, bit-for-bit)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["python", "fused"])
@pytest.mark.parametrize("compress", [None, "leafwise", "fused"])
def test_from_flags_matches_explicit_objects(engine, compress):
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=3)
    b = tiny_batches(3, 2, 8, d=8)
    codec = {None: api.ExactF32(), "leafwise": api.LeafwiseInt8(),
             "fused": api.FlatFusedInt8()}[compress]
    eng = (api.FusedEngine() if engine == "fused" else api.PythonEngine())
    out = {}
    for label, learner in (
            ("flags", CoLearner.from_flags(cfg, tiny_loss, engine=engine,
                                           compress=compress)),
            ("objects", CoLearner(cfg, tiny_loss, codec=codec,
                                  aggregator=api.FullAverage(),
                                  round_engine=eng))):
        state = learner.init(tiny_params(d=8))
        for _ in range(3):
            state = learner.run_round(state, lambda i, j: b)
        out[label] = (learner.shared_model(state), state)
    assert max_abs_diff(out["flags"][0], out["objects"][0]) <= 1e-6
    for lf, lo in zip(out["flags"][1]["log"], out["objects"][1]["log"]):
        assert (lf.T, lf.comm_bytes) == (lo.T, lo.comm_bytes)
        np.testing.assert_allclose(lf.local_losses, lo.local_losses,
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# New scenarios: convergence smoke + engine equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("aggregator", [api.PartialParticipation(m=2),
                                        api.RingGossip()])
def test_new_aggregators_converge_on_synthetic_task(aggregator):
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=5)
    b = tiny_batches(3, 4, 8)
    learner = CoLearner(cfg, tiny_loss, aggregator=aggregator)
    state = learner.init(tiny_params())
    for _ in range(5):
        state = learner.run_round(state, lambda i, j: b)
    losses = [np.mean(l.local_losses) for l in state["log"]]
    assert losses[-1] < 0.5 * losses[0], losses


@pytest.mark.parametrize("aggregator", [api.PartialParticipation(m=2),
                                        api.RingGossip()])
def test_new_aggregators_engine_equivalence(aggregator):
    """Python and fused engines see the identical (seed, round)-deterministic
    mixing matrix, so trajectories must agree like they do for Eq. 2."""
    cfg = CoLearnConfig(n_participants=3, T0=2, eta0=0.05, epsilon=0.5,
                        max_rounds=3)
    b = tiny_batches(3, 2, 8)
    out = {}
    for eng in ("python", "fused"):
        learner = CoLearner(cfg, tiny_loss, aggregator=aggregator,
                            round_engine=eng)
        state = learner.init(tiny_params())
        for _ in range(3):
            state = learner.run_round(state, lambda i, j: b)
        out[eng] = (learner.shared_model(state), state)
    assert max_abs_diff(out["python"][0], out["fused"][0]) <= 1e-5
    assert ([l.comm_bytes for l in out["python"][1]["log"]]
            == [l.comm_bytes for l in out["fused"][1]["log"]])


def test_mesh_specializations_reject_multiple_rows_per_pod():
    """The weighted pod paths permute/scale whole local blocks, so K must
    equal the pod count — a mismatch must fail loudly, not mix wrong rows."""
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("pod",))
    stacked = mixed_tree(K=3)
    specs = jax.tree.map(lambda t: P("pod"), stacked)
    for agg in (api.PartialParticipation(m=2), api.RingGossip()):
        fn = agg.make_aggregate_fn(api.ExactF32(), mesh=mesh,
                                   param_specs=specs)
        with pytest.raises(ValueError, match="one participant row per pod"):
            fn(stacked, jnp.asarray(agg.mixing_matrix(0, 3)))


def test_weighted_aggregator_through_chunked_fused_path():
    """T_i > chunk exercises the chained-chunk finalize with a mixing
    matrix; must match the python engine."""
    cfg = CoLearnConfig(n_participants=3, T0=5, eta0=0.05, epsilon=0.0,
                        schedule="clr", epochs_rule="fle", max_rounds=2)
    b = tiny_batches(3, 2, 8)
    out = {}
    for label, eng in (("python", api.PythonEngine()),
                       ("chunked", api.FusedEngine(chunk=2))):
        learner = CoLearner(cfg, tiny_loss, aggregator=api.RingGossip(),
                            round_engine=eng)
        state = learner.init(tiny_params())
        for _ in range(2):
            state = learner.run_round(state, lambda i, j: b)
        out[label] = learner.shared_model(state)
    assert max_abs_diff(out["python"], out["chunked"]) <= 1e-5


def test_flat_codec_partial_participation_fused_engine_acceptance():
    """The ISSUE 3 acceptance bar: flat-buffer codec x partial participation
    x fused engine runs a 3-round sim with correct per-round comm bytes."""
    K, m = 3, 2
    cfg = CoLearnConfig(n_participants=K, T0=1, eta0=0.05, epsilon=0.5,
                        max_rounds=3)
    d = 256                 # >= one quantization block per participant
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (K, 3, 8, d))
    w_true = jax.random.normal(jax.random.PRNGKey(1), (d, 1)) / np.sqrt(d)
    b = (x, x @ w_true)
    codec = api.FlatFusedInt8()
    learner = CoLearner(cfg, tiny_loss, codec=codec,
                        aggregator=api.PartialParticipation(m=m),
                        round_engine=api.FusedEngine())
    state = learner.init(tiny_params(d=256))
    for _ in range(3):
        state = learner.run_round(state, lambda i, j: b)
    assert len(state["log"]) == 3
    losses = [np.mean(l.local_losses) for l in state["log"]]
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]
    wire = codec.wire_bytes(state["params"])
    down = learner.param_bytes(state)
    for log in state["log"]:
        assert log.comm_bytes == math.ceil(m * wire / K) + down


# ---------------------------------------------------------------------------
# Satellite regressions: comm accounting + restart semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["exact", "leafwise", "fused"])
def test_round_log_comm_bytes_priced_by_codec(name):
    """Regression (ISSUE 3): compressed runs must report the compressed
    upload + f32 download, not 2 x raw param bytes."""
    cfg = CoLearnConfig(n_participants=3, T0=1, eta0=0.001, max_rounds=1)
    # d >= one quantization block so the leafwise codec engages; the flat
    # codec needs a larger tree to amortize its whole-tile padding (which
    # the accounting must include — that's the point of the regression)
    d = {"exact": 4, "leafwise": 256, "fused": 16384}[name]
    b = tiny_batches(3, 2, 8, d=d)
    learner = CoLearner(cfg, tiny_loss, codec=name)
    state = learner.init(tiny_params(d=d))
    state = learner.run_round(state, lambda i, j: b)
    raw = learner.param_bytes(state)
    wire = learner.codec.wire_bytes(state["params"])
    assert state["log"][0].comm_bytes == wire + raw
    if name == "exact":
        assert wire + raw == 2 * raw         # the paper-faithful accounting
    else:
        assert wire + raw < 2 * raw          # int8 upload, f32 download
        # upload leg compressed at least ~3x (int8 + scales vs f32)
        assert wire < 0.35 * raw


def test_comm_bytes_cache_reset_on_reinit():
    """Reusing one learner across init() calls with different param shapes
    must re-price the comm accounting, not serve the stale cached value."""
    cfg = CoLearnConfig(n_participants=2, T0=1, eta0=0.01, max_rounds=1)
    learner = CoLearner(cfg, tiny_loss)
    for d in (4, 16):
        b = tiny_batches(2, 1, 4, d=d)
        state = learner.init(tiny_params(d=d))
        state = learner.run_round(state, lambda i, j: b)
        assert state["log"][0].comm_bytes == 2 * learner.param_bytes(state)


def test_restart_participant_resets_params_and_opt_state():
    """Regression (ISSUE 3): restart must also clear the participant's
    optimizer state (stale momentum would keep pushing the restarted
    replica along its pre-failure trajectory)."""
    cfg = CoLearnConfig(n_participants=3, T0=1, eta0=0.05, max_rounds=2)
    learner = CoLearner(cfg, tiny_loss, optimizer_name="momentum")
    state = learner.init(tiny_params())
    # advance one local epoch so momentum is nonzero, then fail replica 1
    b = tiny_batches(3, 2, 8)
    state["params"], state["opt"], _ = learner._jit_epoch(
        state["params"], state["opt"], b, 0.05)
    assert max(float(jnp.abs(m).max())
               for m in jax.tree.leaves(state["opt"])) > 0
    state["params"] = jax.tree.map(lambda t: t.at[1].add(100.0),
                                   state["params"])
    state = learner.restart_participant(state, 1)
    shared = learner.shared_model(state)
    for t, s in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(shared)):
        np.testing.assert_allclose(t[1], s)
    for m in jax.tree.leaves(state["opt"]):
        np.testing.assert_array_equal(m[1], jnp.zeros_like(m[1]))
        assert float(jnp.abs(m[0]).max()) > 0     # others keep their state
