"""Roofline analysis (deliverable g): derives the three terms per
(arch × shape) from the dry-run artifacts in artifacts/dryrun/.

  compute_s    = corrected_FLOPs/device / 197e12   (TPU v5e bf16 peak)
  memory_s     = HLO bytes/device       / 819e9    (HBM bandwidth)
  collective_s = link bytes/device      / 50e9     (ICI per link)

corrected_FLOPs = depth-extrapolated profile FLOPs + analytic corrections
for intra-layer chunk scans (launch/analytic.py; XLA counts scan bodies
once — measured). MODEL_FLOPS/HLO ratio flags remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
BASE_VARIANT = {"train_4k": "train_vanilla", "prefill_32k": "prefill",
                "decode_32k": "serve", "long_500k": "serve"}


def load(art_dir="artifacts/dryrun", mesh="single"):
    recs = {}
    for path in glob.glob(os.path.join(art_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["mesh"], r["variant"])] = r
    return recs


def terms(rec):
    n = rec["n_devices"]
    prof = rec.get("profile") or rec["scan_raw_cost"]
    corr = rec["analytic"]["scan_correction_flops"] / n
    # the train step scans over `microbatch` grad-accumulation slices and
    # XLA counts the scan body once — scale to the full step (slight
    # overcount on the once-per-step gradient all-reduce; documented)
    mb = rec.get("microbatch", 1)
    flops = prof["flops"] * mb + corr
    t_c = flops / PEAK_FLOPS
    t_m = prof["bytes"] * mb / HBM_BW
    t_l = prof["link_bytes"] * mb / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
              key=lambda kv: kv[1])[0]
    model = rec["analytic"]["model_flops"]
    ratio = model / max(flops * n, 1.0)
    return {"flops_dev": flops, "compute_s": t_c, "memory_s": t_m,
            "collective_s": t_l, "dominant": dom, "model_flops": model,
            "useful_ratio": ratio,
            "peak_gib": rec["memory"]["peak_bytes_per_device"] / 2 ** 30,
            "step_s_bound": max(t_c, t_m, t_l)}


def mitigation(rec, t):
    if t["dominant"] == "collective":
        return ("amortize/shrink sync: co-learning round-averaging or int8 "
                "collectives; check for redundant all-gathers")
    if t["dominant"] == "memory":
        if rec["shape"].startswith("decode") or rec["shape"] == "long_500k":
            return ("KV/state-cache bound: shard cache wider, shrink cache "
                    "dtype, or batch more requests per step")
        return "fuse/realign layouts; bigger per-step arithmetic intensity"
    return ("compute bound (good); raise MFU via MXU-aligned tiles / less "
            "remat recompute" if t["useful_ratio"] < 0.5 else
            "compute bound near useful-FLOPs parity")


def table(recs, mesh="single", out_md=None):
    rows = []
    for (arch, shape, m, variant), rec in sorted(recs.items()):
        if m != mesh or variant != BASE_VARIANT.get(shape):
            continue
        t = terms(rec)
        rows.append({"arch": arch, "shape": shape, **t,
                     "note": mitigation(rec, t)})
    if out_md:
        with open(out_md, "w") as f:
            f.write("| arch | shape | compute_s | memory_s | collective_s | "
                    "dominant | useful | peak GiB |\n|---|---|---|---|---|---|---|---|\n")
            for r in rows:
                f.write(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
                        f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                        f"{r['dominant']} | {r['useful_ratio']:.2f} | "
                        f"{r['peak_gib']:.1f} |\n")
    return rows


def colearn_vs_vanilla(recs, arch, steps_per_round):
    """The paper's own roofline story on the multi-pod mesh: per-step
    collective seconds of vanilla vs colearn + amortized average."""
    van = recs.get((arch, "train_4k", "multi", "train_vanilla"))
    col = recs.get((arch, "train_4k", "multi", "train_colearn"))
    avg = recs.get((arch, "train_4k", "multi", "average"))
    if not (van and col and avg):
        return None
    out = {}
    for name, rec in (("vanilla", van), ("colearn", col)):
        c = rec.get("profile") or rec["scan_raw_cost"]
        out[name] = {"coll_s": c["link_bytes"] / LINK_BW,
                     "cross_pod_bytes": c["cross_pod_link_bytes"]}
    a = avg["scan_raw_cost"]
    out["average_event"] = {"coll_s": a["link_bytes"] / LINK_BW,
                            "cross_pod_bytes": a["cross_pod_link_bytes"]}
    out["colearn_amortized_coll_s"] = (
        out["colearn"]["coll_s"]
        + out["average_event"]["coll_s"] / max(steps_per_round, 1))
    return out


def _synthetic_recs():
    """Hand-built dry-run records spanning the three roofline regimes —
    lets --check exercise the full analysis path with no artifacts dir."""
    def rec(arch, shape, mesh, variant, flops, bytes_, link, cross=0.0,
            n=4, mb=1):
        cost = {"flops": flops, "bytes": bytes_, "link_bytes": link,
                "cross_pod_link_bytes": cross}
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "variant": variant, "n_devices": n, "microbatch": mb,
                "profile": dict(cost), "scan_raw_cost": dict(cost),
                "analytic": {"scan_correction_flops": 0.0,
                             "model_flops": flops * n * 0.8},
                "memory": {"peak_bytes_per_device": 8 * 2 ** 30}}

    recs = {}
    for r in (
        # compute-bound train, memory-bound decode, collective-bound long
        rec("a", "train_4k", "single", "train_vanilla",
            flops=1e15, bytes_=1e11, link=1e9),
        rec("a", "decode_32k", "single", "serve",
            flops=1e12, bytes_=1e12, link=1e9),
        rec("a", "long_500k", "single", "serve",
            flops=1e12, bytes_=1e11, link=1e12, cross=1e11),
        # multi-pod pair for the colearn amortization story
        rec("a", "train_4k", "multi", "train_vanilla",
            flops=1e15, bytes_=1e11, link=5e11, cross=4e11),
        rec("a", "train_4k", "multi", "train_colearn",
            flops=1e15, bytes_=1e11, link=1e11, cross=0.0),
        rec("a", "train_4k", "multi", "average",
            flops=1e9, bytes_=1e10, link=5e11, cross=5e11),
    ):
        recs[(r["arch"], r["shape"], r["mesh"], r["variant"])] = r
    return recs


def check():
    """CI smoke: regime classification + colearn amortization math on
    synthetic records (the artifacts dir is not present in CI)."""
    recs = _synthetic_recs()
    rows = {r["shape"]: r for r in table(recs)}
    assert rows["train_4k"]["dominant"] == "compute", rows["train_4k"]
    assert rows["decode_32k"]["dominant"] == "memory", rows["decode_32k"]
    assert rows["long_500k"]["dominant"] == "collective", rows["long_500k"]
    for r in rows.values():
        assert r["step_s_bound"] > 0 and 0 < r["useful_ratio"] <= 1
        assert r["note"]
    cv = colearn_vs_vanilla(recs, "a", steps_per_round=100)
    assert cv is not None
    # per-step cross-pod traffic amortizes: colearn + average/steps < vanilla
    assert cv["colearn_amortized_coll_s"] < cv["vanilla"]["coll_s"], cv
    assert cv["colearn"]["cross_pod_bytes"] == 0.0
    print("roofline --check OK", flush=True)
    return 0


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("art", nargs="?", default="artifacts/dryrun",
                    help="dry-run artifacts directory")
    ap.add_argument("--check", action="store_true",
                    help="fast CI smoke mode on synthetic records — no "
                         "artifacts dir needed")
    args = ap.parse_args(argv)
    if args.check:
        return check()
    recs = load(args.art)
    rows = table(recs,
                 out_md=f"artifacts/roofline_{os.path.basename(args.art)}.md")
    for r in rows:
        print(f"roofline,{r['arch']},{r['shape']},c={r['compute_s']:.4f},"
              f"m={r['memory_s']:.4f},l={r['collective_s']:.4f},"
              f"dom={r['dominant']},useful={r['useful_ratio']:.2f}",
              flush=True)
    return rows


if __name__ == "__main__":
    main()
