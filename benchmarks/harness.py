"""Shared harness for the paper-claims benchmarks: runs vanilla-learning
(centralized), ensemble-learning, and co-learning (any CLR/ELR × ILE/FLE
combo) on a classification task and reports accuracy per round."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.core.ensemble import ensemble_accuracy
from repro.data import partition as part_mod
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.models.layers import softmax_xent


def build_participant_data(train, K, batch_size, seed, *, partition="iid",
                           dirichlet_alpha=1.0, sizes=None, k_max=None):
    """Shard (x, y) under a data scenario -> ``ParticipantData``.

    partition: "iid" (the paper's random split, remainder round-robin) |
    "dirichlet" (label-skew non-IID over y, ``dirichlet_alpha``) |
    "sizes" (quantity skew, ``sizes`` counts/fractions). Dispatch is the
    shared ``repro.data.partition.scenario_indices`` (same semantics as
    ``launch/train.py``).
    """
    x, y = train
    idx = part_mod.scenario_indices(
        len(x), K, seed, scenario=partition, labels=y,
        dirichlet_alpha=dirichlet_alpha, sizes=sizes, min_size=batch_size)
    shards = part_mod.shard_by_indices([x, y], idx)
    return ParticipantData(shards, batch_size, seed, k_max=k_max)


def cls_loss(apply_fn):
    def loss_fn(params, batch):
        x, y = batch
        logits = apply_fn(params, x)
        loss = softmax_xent(logits[:, None, :], y[:, None])
        return loss, {"loss": loss}
    return loss_fn


def accuracy(apply_fn, params, x, y, bs=256):
    correct = n = 0
    for i in range(0, len(x), bs):
        lg = apply_fn(params, jnp.asarray(x[i:i + bs]))
        correct += int((jnp.argmax(lg, -1) == jnp.asarray(y[i:i + bs])).sum())
        n += len(x[i:i + bs])
    return correct / n


def run_colearn(init_fn, apply_fn, train, test, *, K=5, rounds=6, T0=1,
                eta0=0.02, epsilon=0.02, schedule="clr", epochs_rule="ile",
                batch_size=32, seed=0, steps_cap=0, engine="python",
                compress=None, codec=None, aggregator=None,
                lr_schedule=None, sync_policy=None, partition="iid",
                dirichlet_alpha=1.0, sizes=None, weighted=False,
                churn=None, liveness_aware=True, k_max=None,
                drift=None, stream=None, on_round_end=None):
    """Returns dict with per-round accuracy, controller history, comm stats.

    engine: "python" (reference per-epoch loop) or "fused" (one compiled
    executable per round — see repro.core.engine); identical results.
    codec / aggregator / lr_schedule / sync_policy: round-strategy objects
    or registry names (repro.core.api) — e.g. codec="leafwise" | "fused",
    aggregator=PartialParticipation(m=2) | "ring",
    sync_policy=DivergenceTrigger(delta=0.1). lr_schedule/sync_policy left
    as None resolve the schedule/epochs_rule strings through the same
    registries. compress is the legacy alias for codec (None | "leafwise"
    | "fused").

    Data scenario: ``partition`` / ``dirichlet_alpha`` / ``sizes`` pick the
    split (see ``build_participant_data``); ``weighted=True`` switches
    Eq. 2 to the example-count-weighted FedAvg average
    (``FullAverage(weights=shard sizes)``; default aggregator only).
    Ragged shards automatically thread their validity mask into the
    engines, and the shard sizes are handed to the learner so partial
    participation weights by them.

    Elastic membership: ``churn`` takes a ``repro.core.membership``
    schedule (or registry name) injecting per-round participant failures;
    ``liveness_aware=False`` keeps the static mixing matrix under churn
    (the naive ablation — dead rows pollute the mean); ``k_max`` reserves
    standby slots beyond K (the extra slots cycle the real shards). The
    result dict gains ``live`` (per-round live counts) when churn is on.

    Continuous operation: ``drift`` takes a ``repro.data.stream`` schedule
    (or registry name) and stages each round on a drifting ``ShardStream``
    instead of the frozen stack — per-round accuracy is then measured on
    the test set AS THAT ROUND'S DISTRIBUTION SEES IT (``transform_test``),
    the honest serving metric under drift. ``stream`` passes a prebuilt
    ``ShardStream`` directly (overrides the partition kwargs).
    ``on_round_end(learner, state)`` fires after every round's state
    transition — the ``ModelBank.publish_from`` hook.
    """
    if compress is not None:
        if codec is not None:
            raise ValueError("pass codec= or the legacy compress=, not both")
        codec = compress
    if stream is not None:
        if drift is not None:
            raise ValueError("pass stream= (prebuilt) or drift=, not both")
        data = stream
    elif drift is not None:
        from repro.data.stream import ShardStream
        data = ShardStream(list(train), K, batch_size, seed, drift=drift,
                           partition=partition,
                           dirichlet_alpha=dirichlet_alpha, sizes=sizes,
                           k_max=k_max)
    else:
        data = build_participant_data(train, K, batch_size, seed,
                                      partition=partition,
                                      dirichlet_alpha=dirichlet_alpha,
                                      sizes=sizes, k_max=k_max)
    if k_max is not None:
        K = k_max
    if weighted:
        if aggregator is not None:
            raise ValueError("weighted=True builds the FullAverage "
                             "aggregator; pass one or the other")
        aggregator = api.FullAverage(weights=data.sizes)
    batch_mask = data.batch_mask if data.ragged else None
    if batch_mask is not None and steps_cap:
        batch_mask = batch_mask[:, :steps_cap]
    ccfg = CoLearnConfig(n_participants=K, T0=T0, eta0=eta0, epsilon=epsilon,
                         schedule=schedule, epochs_rule=epochs_rule,
                         max_rounds=rounds)
    learner = CoLearner(ccfg, cls_loss(apply_fn), codec=codec,
                        aggregator=aggregator, round_engine=engine,
                        schedule=lr_schedule, sync_policy=sync_policy,
                        shard_sizes=data.sizes, batch_mask=batch_mask,
                        churn=churn, liveness_aware=liveness_aware)
    params = init_fn(jax.random.PRNGKey(seed))
    state = learner.init(params)
    accs, Ts, times = [], [], []
    for _ in range(rounds):
        t0 = time.time()

        def eb(i_, j_):
            bx, by = data.epoch_batches(i_, j_)
            if steps_cap:
                bx, by = bx[:, :steps_cap], by[:, :steps_cap]
            return (jnp.asarray(bx), jnp.asarray(by))

        state = learner.run_round(state, eb, on_round_end=on_round_end)
        times.append(time.time() - t0)
        Ts.append(state["log"][-1].T)
        # under drift, score against the test set as THIS round's
        # distribution sees it (content drift moves the eval too)
        round_test = (data.transform_test(test, state["round"])
                      if hasattr(data, "transform_test") else test)
        accs.append(accuracy(apply_fn, learner.shared_model(state),
                             *round_test))
    # per-round wire cost of a SYNCED round (round 0 may be quiet and bill
    # 0 under a divergence-gated policy); totals cover the whole run
    per_round = next((l.comm_bytes for l in state["log"] if l.synced), 0)
    return {"acc": accs, "T": Ts, "round_s": times,
            "shard_sizes": data.sizes,
            "live": [l.live for l in state["log"]],
            "comm_bytes": per_round,
            "total_comm_bytes": sum(l.comm_bytes for l in state["log"]),
            "synced_rounds": sum(1 for l in state["log"] if l.synced),
            "history": state["ctrl"].history,
            "final_params": learner.shared_model(state), "state": state,
            "learner": learner}


def run_vanilla(init_fn, apply_fn, train, test, *, epochs=6, eta0=0.02,
                batch_size=32, seed=0, schedule="elr", steps_cap=0):
    """Centralized baseline: K=1, all data, ELR (paper's vanilla setting)."""
    out = run_colearn(init_fn, apply_fn, train, test, K=1, rounds=epochs,
                      T0=1, eta0=eta0, epsilon=0.0, schedule=schedule,
                      epochs_rule="fle", batch_size=batch_size, seed=seed,
                      steps_cap=steps_cap)
    return out


def run_ensemble(init_fn, apply_fn, train, test, *, K=5, epochs=6, eta0=0.02,
                 batch_size=32, seed=0, steps_cap=0):
    """Paper's ensemble baseline: independent local training, avg outputs."""
    x, y = train
    shards = partition_arrays([x, y], K, seed)
    data = ParticipantData(shards, batch_size, seed)
    ccfg = CoLearnConfig(n_participants=K, T0=epochs, eta0=eta0,
                         epsilon=0.0, schedule="clr", epochs_rule="fle",
                         max_rounds=1)
    learner = CoLearner(ccfg, cls_loss(apply_fn))
    state = learner.init(init_fn(jax.random.PRNGKey(seed)))

    # one "round" of T0=epochs local epochs, but NO averaging: grab the
    # participant replicas right before aggregation
    def eb(i_, j_):
        bx, by = data.epoch_batches(i_, j_)
        if steps_cap:
            bx, by = bx[:, :steps_cap], by[:, :steps_cap]
        return (jnp.asarray(bx), jnp.asarray(by))

    cfg = learner.cfg
    for j in range(cfg.T0):
        from repro.core.schedule import round_lr
        lr = float(round_lr(cfg, 0, j, cfg.T0, j, cfg.T0))
        batches = eb(0, j)
        state["params"], state["opt"], _ = learner._jit_epoch(
            state["params"], state["opt"], batches, lr)
    xt, yt = test
    acc = float(ensemble_accuracy(lambda p, b: apply_fn(p, b),
                                  state["params"], jnp.asarray(xt),
                                  jnp.asarray(yt)))
    # per-participant local accuracies for reference
    local = [accuracy(apply_fn, jax.tree.map(lambda t: t[k], state["params"]),
                      xt, yt) for k in range(K)]
    return {"acc": acc, "local_acc": local}
