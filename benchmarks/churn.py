"""Convergence under participant churn (elastic-membership benchmark).

The paper assumes a static K; the ISSUE-6 membership layer makes the live
set a per-round quantity. This benchmark measures what that buys: the
image-like task trained under 20% i.i.d. per-round failures
(``RandomChurn(p_fail=0.2)``), three arms —

* ``none``   — the static-K baseline (no churn; the paper path),
* ``aware``  — churn with liveness-aware aggregation: the mixing matrix
  renormalizes over the live set, dead rows neither upload nor count,
* ``naive``  — churn with the STATIC mixing matrix
  (``liveness_aware=False``): a dead slot's stale parameters keep their
  1/K weight in every average — the failure mode the membership layer
  exists to remove.

The committed result lives in benchmarks/BENCH_churn.json; the headline
is ``aware`` holding near the no-churn curve while ``naive`` drags
behind it. ``--check`` is the CI smoke: a reduced run asserting the
structural invariants (the live trace matches the schedule's replay, the
no-churn arm stays all-live, accuracies finite) without timing anything.

Usage:
  PYTHONPATH=src python -m benchmarks.churn [--out benchmarks/BENCH_churn.json]
  PYTHONPATH=src python -m benchmarks.churn --check     # CI smoke
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.harness import run_colearn
from repro.core.membership import RandomChurn
from repro.data.synthetic import image_like
from repro.models.convnets import IMAGE_MODELS

#: the headline fault rate: every live slot fails with p=0.2 each round
P_FAIL = 0.2
#: failed slots rejoin (warm-started from the last synced model) with this
P_JOIN = 0.5

ARMS = ("none", "aware", "naive")


def run_arms(model="resnet_tiny", K=5, rounds=8, n=4000, seed=0,
             batch_size=32, p_fail=P_FAIL, churn_seed=0, engine="fused",
             quiet=False):
    """One row per arm: per-round accuracy + live counts under churn."""
    xtr, ytr = image_like(seed, n=n)
    xte, yte = image_like(seed + 1000, n=1000)
    init_fn, apply_fn = IMAGE_MODELS[model]
    churn = RandomChurn(p_fail=p_fail, p_join=P_JOIN, seed=churn_seed)
    rows = []
    for arm in ARMS:
        kw = {}
        if arm != "none":
            kw = dict(churn=churn, liveness_aware=(arm == "aware"))
        r = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                        K=K, rounds=rounds, T0=1, epsilon=0.03,
                        batch_size=batch_size, seed=seed, engine=engine,
                        **kw)
        rows.append({"arm": arm, "final_acc": r["acc"][-1],
                     "curve": r["acc"], "live": list(r["live"]),
                     "T_per_round": r["T"],
                     "comm_bytes": r["comm_bytes"]})
        if not quiet:
            print(f"churn,{arm},{r['acc'][-1]:.4f},live={list(r['live'])}",
                  flush=True)
    return rows


def check(quiet=False):
    """CI smoke: reduced run, structural invariants only (no timings)."""
    K, rounds, churn_seed = 4, 3, 7
    rows = run_arms(K=K, rounds=rounds, n=800, batch_size=16,
                    churn_seed=churn_seed, quiet=quiet)
    by_arm = {r["arm"]: r for r in rows}
    assert set(by_arm) == set(ARMS)
    # the no-churn arm never loses a participant
    assert by_arm["none"]["live"] == [K] * rounds, by_arm["none"]["live"]
    # both churn arms replay the SAME deterministic (seed, round) trace,
    # and it matches the schedule's own replay
    sched = RandomChurn(p_fail=P_FAIL, p_join=P_JOIN, seed=churn_seed)
    expect = [int(sched.live_mask(i, K).sum()) for i in range(rounds)]
    assert by_arm["aware"]["live"] == expect, (by_arm["aware"]["live"],
                                               expect)
    assert by_arm["naive"]["live"] == expect
    for row in rows:
        assert all(1 <= lv <= K for lv in row["live"]), row
        assert all(np.isfinite(a) and 0 < a <= 1 for a in row["curve"]), row
    print("churn --check OK: live traces deterministic, no-churn all-live, "
          "accuracies finite")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="CI smoke: reduced run, structural invariants only")
    ap.add_argument("--out", default="",
                    help="write the arm rows as JSON")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--churn-seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.check:
        return check()
    rows = run_arms(rounds=args.rounds, churn_seed=args.churn_seed)
    by_arm = {r["arm"]: r["final_acc"] for r in rows}
    print(f"churn_summary,none={by_arm['none']:.4f},"
          f"aware={by_arm['aware']:.4f},naive={by_arm['naive']:.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"task": "image_like", "p_fail": P_FAIL,
                       "p_join": P_JOIN, "rows": rows}, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
