"""Table 1 analog: communication interval & volume per model per round.

Volume is exact (2 × parameter bytes per participant per round, as in the
paper's upload+download accounting), reported for every assigned full-scale
architecture; the int8-compressed volume (beyond-paper) is shown alongside.
Interval is measured on the CPU-scale smoke run (wall time of a T_0-epoch
round) and, for the full configs, derived from the dry-run compute terms.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.compression import compressed_bytes
from repro.launch import analytic
from repro.launch.steps import params_shapes


def volume_rows(quiet=False):
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = params_shapes(cfg, jnp.bfloat16)
        nbytes = sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(shapes))
        comp = compressed_bytes(shapes)
        rows.append({"arch": arch, "params": sum(
            v.size for v in jax.tree.leaves(shapes)),
            "volume_mb_per_round": 2 * nbytes / 2 ** 20,
            "volume_int8_mb": 2 * comp / 2 ** 20})
        if not quiet:
            r = rows[-1]
            print(f"table1,{arch},params={r['params']:,},"
                  f"vol={r['volume_mb_per_round']:.0f}MB,"
                  f"vol_int8={r['volume_int8_mb']:.0f}MB", flush=True)
    return rows


def interval_rows(archs=("internlm2-1.8b",), T0=1, quiet=False):
    """Measured smoke-scale round interval + the ILE doubling effect."""
    from benchmarks.harness import run_colearn
    from repro.data.synthetic import lm_examples
    from repro.models import transformer as tr

    rows = []
    for arch in archs:
        cfg = get_smoke_config(arch)
        x, y = lm_examples(0, 400, 32, cfg.vocab_size)

        def init_fn(key, cfg=cfg):
            return tr.init_params(key, cfg, jnp.float32)

        def apply_fn(params, xb, cfg=cfg):
            logits, _ = tr.forward(params, cfg, {"tokens": xb})
            return logits[:, -1]                      # last-token classifier

        r = run_colearn(init_fn, apply_fn, (x, y[:, -1]), (x[:100], y[:100, -1]),
                        K=5, rounds=3, T0=T0, epsilon=1.0,   # force ILE fire
                        batch_size=8, seed=0)
        rows.append({"arch": arch, "round_s": r["round_s"], "T": r["T"]})
        if not quiet:
            print(f"table1_interval,{arch},round_s="
                  f"{['%.1f' % s for s in r['round_s']]},T={r['T']}",
                  flush=True)
    return rows


def main():
    rows = volume_rows()
    rows += interval_rows()
    return rows


if __name__ == "__main__":
    main()
