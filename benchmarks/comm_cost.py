"""Table 1 analog: communication interval & volume per model per round,
plus the end-of-round finalize-latency benchmark (ISSUE 2 tentpole).

Volume is exact (2 x parameter bytes per participant per round, as in the
paper's upload+download accounting), reported for every assigned full-scale
architecture; the int8-compressed volume (beyond-paper) is shown alongside
in both wire accountings: leafwise (small leaves bypass the codec and ride
uncompressed) and flat-buffer (every element on the wire format, exact by
construction).

``finalize_latency_rows`` times the jitted Eq. 2 compressed-averaging step
— the one hot path the PR 1 fused round engine did not touch — under both
wire paths on the smoke-scale model param trees:

* ``leafwise``    — per-leaf quantize-roundtrip + separate stacked mean
                    (``core.compression`` + ``averaging.average_pjit``);
* ``flat_buffer`` — the flat-buffer wire codec: one contiguous (K, N_pad)
                    buffer, one fused quantize->average->dequantize pass
                    (``core.flatbuf`` + ``kernels.comm`` via
                    ``engine.make_fused_compressed_average``).

``wire_precision_rows`` (ISSUE 7) adds the payload-bit-width axis: exact
wire bytes + fused-average latency at 8/4/1 bits for both codec families,
and measured quickstart-task convergence of the flat wire — int8 baseline
vs int4 and 1-bit with error-feedback residual memory.

Timings are min-of-N over jitted, block_until_ready'd calls (robust on a
shared box); compile time is excluded by a warmup call. The result JSON is
committed as benchmarks/BENCH_comm_cost.json.

Usage:
  PYTHONPATH=src python -m benchmarks.comm_cost \
      [--reps 30] [--out benchmarks/BENCH_comm_cost.json]
  PYTHONPATH=src python -m benchmarks.comm_cost --check   # CI smoke mode
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import api, averaging, flatbuf
from repro.core.compression import compressed_bytes, flat_compressed_bytes
from repro.launch.steps import params_shapes

# smoke trees spanning few-leaf dense to many-leaf MoE/hybrid structures,
# plus a deep-narrow unrolled-segment variant (the dryrun PROFILE config
# family): ~580 small leaves, the regime where the leafwise path's
# per-leaf codec overhead dominates — on CPU this stands in for the
# per-leaf kernel-launch cost real models pay on TPU
LATENCY_ARCHS = ("internlm2-1.8b", "xlstm-1.3b", "jamba-v0.1-52b",
                 "deepseek-v3-671b", "internlm2-1.8b:unrolled-deep")


def _latency_config(arch):
    if arch.endswith(":unrolled-deep"):
        base = get_smoke_config(arch.split(":")[0])
        L = 96
        return base.with_(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                          d_ff=64, n_layers=L,
                          segments=((("gqa:dense",), 1),) * L)
    return get_smoke_config(arch)


def volume_rows(quiet=False):
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = params_shapes(cfg, jnp.bfloat16)
        nbytes = sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(shapes))
        comp = compressed_bytes(shapes)
        stacked = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct((1, *v.shape), v.dtype), shapes)
        flat = flat_compressed_bytes(stacked)
        rows.append({"arch": arch, "params": sum(
            v.size for v in jax.tree.leaves(shapes)),
            "volume_mb_per_round": 2 * nbytes / 2 ** 20,
            "volume_int8_mb": 2 * comp / 2 ** 20,
            "volume_int8_flat_mb": 2 * flat / 2 ** 20})
        if not quiet:
            r = rows[-1]
            print(f"table1,{arch},params={r['params']:,},"
                  f"vol={r['volume_mb_per_round']:.0f}MB,"
                  f"vol_int8={r['volume_int8_mb']:.0f}MB,"
                  f"vol_int8_flat={r['volume_int8_flat_mb']:.0f}MB",
                  flush=True)
    return rows


def _stacked_smoke_params(arch, K, dtype=jnp.float32):
    from repro.models import transformer as tr
    cfg = _latency_config(arch)
    params = tr.init_params(jax.random.PRNGKey(0), cfg, dtype)
    return averaging.stack_participants(params, K)


def _time_pair(fn_a, fn_b, arg, reps):
    """Interleaved min/mean seconds for two jitted fns on the same input.

    Alternating A/B per rep makes shared-box load drift hit both paths
    equally — sequential blocks were observed to skew either way by 1.5x.
    """
    jax.block_until_ready(fn_a(arg))                    # warmup (compile)
    jax.block_until_ready(fn_b(arg))
    ta, tb = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(arg))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(arg))
        tb.append(time.perf_counter() - t0)
    return ((min(ta), float(np.mean(ta))), (min(tb), float(np.mean(tb))))


def finalize_latency_rows(archs=LATENCY_ARCHS, K=4, reps=30, block=256,
                          impl="ref", quiet=False):
    """Jitted compressed-average latency, leafwise vs flat-buffer codec."""
    leaf_codec = api.LeafwiseInt8(block=block, impl=impl)
    flat_codec = api.FlatFusedInt8(block=block, impl=impl)
    full = api.FullAverage()
    rows = []
    for arch in archs:
        stacked = _stacked_smoke_params(arch, K)
        leaves = jax.tree.leaves(stacked)
        # FullAverage composes each codec its own way: leafwise = per-leaf
        # roundtrip + separate mean, flat = the codec's fused-mean kernel
        leaf_fn = jax.jit(full.make_aggregate_fn(leaf_codec))
        flat_fn = jax.jit(full.make_aggregate_fn(flat_codec))
        (l_min, l_mean), (f_min, f_mean) = _time_pair(leaf_fn, flat_fn,
                                                      stacked, reps)
        layout = flatbuf.make_layout(stacked, block=block)
        rows.append({
            "arch": arch, "K": K, "n_leaves": len(leaves),
            "params_per_participant": layout.n,
            # what the TIMED leafwise fn bypasses: it roundtrips the
            # stacked tree, so the threshold sees the K*size leaf
            "small_leaves_bypassed_leafwise": sum(
                1 for v in leaves if v.ndim == 0 or v.size < block),
            "leafwise_ms_min": l_min * 1e3, "leafwise_ms_mean": l_mean * 1e3,
            "flat_buffer_ms_min": f_min * 1e3,
            "flat_buffer_ms_mean": f_mean * 1e3,
            "speedup_min": l_min / f_min,
            "wire_bytes_leafwise": leaf_codec.wire_bytes(stacked),
            "wire_bytes_flat": flat_codec.wire_bytes(stacked),
        })
        if not quiet:
            r = rows[-1]
            print(f"finalize,{arch},leaves={r['n_leaves']},"
                  f"leafwise={r['leafwise_ms_min']:.2f}ms,"
                  f"flat={r['flat_buffer_ms_min']:.2f}ms,"
                  f"speedup={r['speedup_min']:.2f}x", flush=True)
    return rows


WIRE_BITS = (8, 4, 1)


def _wire_bytes_table(stacked, block=256):
    """Exact per-participant upload bytes at every payload width, both
    codec families. EF never changes the wire (residual is device-side
    memory) — asserted here so the benchmark can't drift from the codecs."""
    rows = []
    for bits in WIRE_BITS:
        row = {"bits": bits,
               "wire_bytes_leafwise": api.get_codec(
                   "leafwise", block=block, bits=bits).wire_bytes(stacked),
               "wire_bytes_flat": api.get_codec(
                   "fused", block=block, bits=bits).wire_bytes(stacked)}
        assert api.get_codec(
            "fused", block=block, bits=bits,
            error_feedback=True).wire_bytes(stacked) == row["wire_bytes_flat"]
        rows.append(row)
    return rows


def wire_precision_rows(rounds=4, K=5, reps=10, seed=0, quiet=False):
    """ISSUE 7 axis: payload bit width x error feedback on the quickstart
    task (smoke internlm2, K=5, synthetic LM shards — the quickstart.py /
    interval_rows setup as a last-token classifier).

    Reports (a) the exact wire-byte table at 8/4/1 bits for both codec
    families, (b) jitted fused-average latency per width, and (c) measured
    convergence + billed comm of the flat wire: int8 baseline vs int4+EF
    vs 1-bit+EF. The int4+EF row is the acceptance pin: >= 1.9x fewer
    wire bytes than int8 at comparable accuracy.
    """
    from benchmarks.harness import run_colearn
    from repro.data.synthetic import lm_examples
    from repro.models import transformer as tr

    cfg = get_smoke_config("internlm2-1.8b")
    shapes = params_shapes(cfg, jnp.float32)
    abstract = jax.tree.map(
        lambda v: jax.ShapeDtypeStruct((K, *v.shape), v.dtype), shapes)
    bytes_rows = _wire_bytes_table(abstract)

    # jitted Eq. 2 latency of the fused flat wire per payload width
    stacked = _stacked_smoke_params("internlm2-1.8b", 4)
    full = api.FullAverage()
    for row in bytes_rows:
        fn = jax.jit(full.make_aggregate_fn(
            api.get_codec("fused", bits=row["bits"])))
        jax.block_until_ready(fn(stacked))            # warmup (compile)
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(stacked))
            ts.append(time.perf_counter() - t0)
        row["flat_finalize_ms_min"] = min(ts) * 1e3
        if not quiet:
            print(f"wire_bytes,bits={row['bits']},"
                  f"leafwise={row['wire_bytes_leafwise']:,},"
                  f"flat={row['wire_bytes_flat']:,},"
                  f"finalize={row['flat_finalize_ms_min']:.2f}ms", flush=True)

    x, y = lm_examples(0, 400, 32, cfg.vocab_size)

    def init_fn(key, cfg=cfg):
        return tr.init_params(key, cfg, jnp.float32)

    def apply_fn(params, xb, cfg=cfg):
        logits, _ = tr.forward(params, cfg, {"tokens": xb})
        return logits[:, -1]                          # last-token classifier

    conv_rows = []
    for label, bits, ef in (("int8", 8, False), ("int4+ef", 4, True),
                            ("1bit+ef", 1, True)):
        r = run_colearn(init_fn, apply_fn, (x, y[:, -1]),
                        (x[:100], y[:100, -1]), K=K, rounds=rounds, T0=1,
                        batch_size=8, seed=seed, engine="fused",
                        codec=api.get_codec("fused", bits=bits,
                                            error_feedback=ef))
        conv_rows.append({"codec": label, "bits": bits, "error_feedback": ef,
                          "final_acc": r["acc"][-1], "acc": r["acc"],
                          "comm_bytes_per_round": r["comm_bytes"],
                          "total_comm_bytes": r["total_comm_bytes"]})
        if not quiet:
            print(f"wire_convergence,{label},acc={r['acc'][-1]:.4f},"
                  f"comm={r['comm_bytes'] / 2 ** 20:.1f}MiB/round",
                  flush=True)
    return {"task": "quickstart (smoke internlm2, K=5, synthetic LM)",
            "bytes": bytes_rows, "convergence": conv_rows}


def interval_rows(archs=("internlm2-1.8b",), T0=1, quiet=False):
    """Measured smoke-scale round interval + the ILE doubling effect."""
    from benchmarks.harness import run_colearn
    from repro.data.synthetic import lm_examples
    from repro.models import transformer as tr

    rows = []
    for arch in archs:
        cfg = get_smoke_config(arch)
        x, y = lm_examples(0, 400, 32, cfg.vocab_size)

        def init_fn(key, cfg=cfg):
            return tr.init_params(key, cfg, jnp.float32)

        def apply_fn(params, xb, cfg=cfg):
            logits, _ = tr.forward(params, cfg, {"tokens": xb})
            return logits[:, -1]                      # last-token classifier

        r = run_colearn(init_fn, apply_fn, (x, y[:, -1]), (x[:100], y[:100, -1]),
                        K=5, rounds=3, T0=T0, epsilon=1.0,   # force ILE fire
                        batch_size=8, seed=0)
        rows.append({"arch": arch, "round_s": r["round_s"], "T": r["T"]})
        if not quiet:
            print(f"table1_interval,{arch},round_s="
                  f"{['%.1f' % s for s in r['round_s']]},T={r['T']}",
                  flush=True)
    return rows


def check():
    """CI smoke mode: fast invariants so the codec benchmark can't rot.

    No timing assertions (CI boxes are noisy) — correctness only:
    roundtrip bit-exactness, fused-vs-leafwise numerics, exact wire-byte
    accounting, and that both benchmark paths still jit and run.
    """
    K, block = 3, 256
    stacked = _stacked_smoke_params("xlstm-1.3b", K)   # has small leaves
    layout = flatbuf.make_layout(stacked, block=block)
    buf = flatbuf.flatten(stacked, layout)
    back = flatbuf.unflatten(buf, layout)
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), \
            "flatten/unflatten roundtrip not bit-exact"
    assert flatbuf.wire_bytes(layout) == layout.n_pad + 4 * (
        layout.n_pad // block)

    exact = averaging.average_pjit(stacked)
    fused = jax.jit(api.FullAverage().make_aggregate_fn(
        api.FlatFusedInt8(block=block, impl="ref")))(stacked)
    for a, b, t in zip(jax.tree.leaves(fused), jax.tree.leaves(exact),
                       jax.tree.leaves(stacked)):
        amax = np.abs(np.asarray(t, np.float32)).max()
        err = np.abs(np.asarray(a, np.float32)
                     - np.asarray(b, np.float32)).max()
        assert err <= amax / 127.0 + 1e-6, \
            f"fused average outside the int8 quantization bound: {err}"

    rows = finalize_latency_rows(archs=("internlm2-1.8b",), reps=2,
                                 quiet=True)
    assert rows and rows[0]["flat_buffer_ms_min"] > 0
    assert rows[0]["wire_bytes_flat"] >= rows[0]["params_per_participant"]
    vol = volume_rows(quiet=True)
    assert all(r["volume_int8_mb"] < r["volume_mb_per_round"] for r in vol)

    # sub-int8 wire: exact byte table holds the >= 1.9x-per-halving shape
    # and the stateful (error-feedback) fused average runs under jit
    wt = {r["bits"]: r for r in _wire_bytes_table(stacked)}
    for fam in ("wire_bytes_leafwise", "wire_bytes_flat"):
        assert wt[8][fam] / wt[4][fam] >= 1.9, (fam, wt)
        assert wt[4][fam] / wt[1][fam] >= 1.9, (fam, wt)
    ef_codec = api.FlatFusedIntN(bits=4, error_feedback=True, block=block,
                                 impl="ref")
    res0 = ef_codec.init_state(stacked)
    agg = jax.jit(api.FullAverage().make_aggregate_fn(ef_codec))
    mixed, res1 = jax.block_until_ready(agg(stacked, None, res0))
    assert any(np.abs(np.asarray(l)).max() > 0
               for l in jax.tree.leaves(res1)), \
        "int4 error-feedback residual stayed zero on a real param tree"
    for a, t in zip(jax.tree.leaves(mixed), jax.tree.leaves(stacked)):
        assert np.isfinite(np.asarray(a, np.float32)).all() and \
            a.dtype == t.dtype
    print("comm_cost --check OK", flush=True)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=30)
    ap.add_argument("--out", default="benchmarks/BENCH_comm_cost.json")
    ap.add_argument("--check", action="store_true",
                    help="fast CI smoke mode: invariants only, no timings")
    args = ap.parse_args(argv)
    if args.check:
        return check()
    rec = {"backend": jax.default_backend(), "reps": args.reps,
           "volume": volume_rows(),
           "finalize_latency": finalize_latency_rows(reps=args.reps),
           "wire_precision": wire_precision_rows(),
           "interval": interval_rows()}
    best = max(rec["finalize_latency"], key=lambda r: r["speedup_min"])
    wt = {r["bits"]: r for r in rec["wire_precision"]["bytes"]}
    conv = {r["codec"]: r for r in rec["wire_precision"]["convergence"]}
    rec["headline"] = {
        "best_finalize_speedup": best["speedup_min"],
        "best_finalize_arch": best["arch"],
        "int4_vs_int8_wire_ratio":
            wt[8]["wire_bytes_flat"] / wt[4]["wire_bytes_flat"],
        "int4_ef_final_acc": conv["int4+ef"]["final_acc"],
        "int8_final_acc": conv["int8"]["final_acc"],
        "1bit_ef_final_acc": conv["1bit+ef"]["final_acc"],
        "note": "flat-buffer codec collapses the leafwise path's per-leaf "
                "pad/reshape + quant/dequant + separate mean into one "
                "fused pass over one contiguous buffer; leafwise also "
                "exempts sub-block leaves from the wire format, flat "
                "covers every element (wire_bytes exact). On CPU the win "
                "shows where per-leaf codec overhead dominates (the "
                "many-leaf unrolled-deep tree; leafwise cost grows with "
                "leaf count, flat is leaf-count-flat); wide-leaf smoke "
                "trees are XLA-CPU bandwidth-bound and favor leafwise's "
                "cache-resident per-leaf fusions — on TPU that regime is "
                "instead bound by the ~2L pallas launches the single "
                "kernel removes.",
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
