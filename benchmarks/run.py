"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark (us_per_call = wall
time of the benchmark; derived = its headline metric) followed by each
benchmark's own detail rows.

  table1   comm interval & volume            (benchmarks/comm_cost.py)
  fig2     CLR/ELR × ILE/FLE ablation        (benchmarks/ablation.py)
  table2   vanilla vs ensemble vs co-learn   (benchmarks/cifar_like.py)
  table3-6 text + audio parity               (benchmarks/tasks.py)
  roofline dry-run roofline terms            (benchmarks/roofline.py)
"""
from __future__ import annotations

import time


def _timed(name, fn):
    t0 = time.time()
    try:
        derived = fn()
    except Exception as e:  # keep the harness running; record the failure
        print(f"{name},FAILED,{type(e).__name__}:{e}")
        return None
    dt = (time.time() - t0) * 1e6
    print(f"{name},{dt:.0f},{derived}")
    return derived


def bench_table1():
    from benchmarks import comm_cost
    rows = comm_cost.volume_rows(quiet=True)
    iv = comm_cost.interval_rows(quiet=True)
    biggest = max(rows, key=lambda r: r["volume_mb_per_round"])
    for r in rows:
        print(f"table1,{r['arch']},vol_mb={r['volume_mb_per_round']:.0f},"
              f"vol_int8_mb={r['volume_int8_mb']:.0f}")
    for r in iv:
        print(f"table1_interval,{r['arch']},round_s={r['round_s']},T={r['T']}")
    return f"max_vol_mb={biggest['volume_mb_per_round']:.0f}"


def bench_fig2():
    from benchmarks import ablation
    rows = ablation.run(models=("resnet_tiny",), rounds=7, n=3000, quiet=True)
    accs = {r["combo"]: r["final_acc"] for r in rows}
    for r in rows:
        print(f"fig2,{r['model']},{r['combo']},acc={r['final_acc']:.4f},"
              f"T={r['T_per_round']}")
    return (f"clr+ile={accs['clr+ile']:.4f},elr+fle={accs['elr+fle']:.4f}")


def bench_table2():
    from benchmarks import cifar_like
    rows = cifar_like.run(rounds=5, n=3000, quiet=True)
    for r in rows:
        print(f"table2,{r['model']},vanilla={r['vanilla']:.4f},"
              f"ensemble={r['ensemble']:.4f},colearn={r['colearn']:.4f}")
    gap = sum(r["colearn"] - r["vanilla"] for r in rows) / len(rows)
    egap = sum(r["ensemble"] - r["vanilla"] for r in rows) / len(rows)
    return f"colearn_minus_vanilla={gap:+.4f},ensemble_minus_vanilla={egap:+.4f}"


def bench_tables_3_to_6():
    from benchmarks import tasks
    rows = tasks.run(rounds=4, quiet=True)
    for r in rows:
        print(f"table_3to6,{r['task']},{r['model']},"
              f"vanilla={r['vanilla']:.4f},colearn={r['colearn']:.4f}")
    gap = sum(r["colearn"] - r["vanilla"] for r in rows) / len(rows)
    return f"mean_parity_gap={gap:+.4f}"


def bench_roofline():
    from benchmarks import roofline
    recs = roofline.load()
    rows = roofline.table(recs, out_md="artifacts/roofline_single.md")
    for r in rows:
        print(f"roofline,{r['arch']},{r['shape']},c={r['compute_s']:.4f},"
              f"m={r['memory_s']:.4f},l={r['collective_s']:.4f},"
              f"dom={r['dominant']},useful={r['useful_ratio']:.2f},"
              f"peak_gib={r['peak_gib']:.1f}")
    if not rows:
        return "no_dryrun_artifacts"
    doms = [r["dominant"] for r in rows]
    return (f"rows={len(rows)},compute_bound={doms.count('compute')},"
            f"memory_bound={doms.count('memory')},"
            f"collective_bound={doms.count('collective')}")


def main() -> None:
    print("name,us_per_call,derived")
    _timed("table1_comm", bench_table1)
    _timed("fig2_ablation", bench_fig2)
    _timed("table2_cifar_like", bench_table2)
    _timed("tables_3to6_modalities", bench_tables_3_to_6)
    _timed("roofline", bench_roofline)


if __name__ == "__main__":
    main()
