"""Continuous-operation benchmark: serving stays live while training runs.

Two scenarios over the `repro.serving` subsystem:

* ``closed_loop`` — the end-to-end train->publish->hot-swap->decode loop
  on a reduced LM: a ``ShardStream`` (covariate drift) feeds
  ``CoLearner.run_round``, every synced round publishes into a
  ``ModelBank`` (the ``on_round_end`` hook), and a ``ServeLoop`` polls,
  hot-swaps, and serves a prompt batch between rounds. Reports tokens/s
  served during training, swap latency, and the decode compile count
  (which must stay 1 across every swap — params are traced arguments).

* ``drift_recovery`` — staleness-vs-accuracy under concept drift on the
  image task: ``DivergenceTrigger`` keeps rounds quiet while the locals
  agree (the bank serves the stale-but-fine last synced model), an
  ``AbruptDrift`` task switch spikes the divergence, the trigger forces a
  re-sync, and post-swap serving accuracy recovers the pre-drift level.
  Every accuracy is measured on the test set as THAT round's distribution
  sees it (``ShardStream.transform_test``) — the honest serving metric.

The committed result lives in benchmarks/BENCH_serving.json; ``--check``
is the CI smoke (reduced run, structural invariants, no timings).

Usage:
  PYTHONPATH=src python -m benchmarks.serving [--out benchmarks/BENCH_serving.json]
  PYTHONPATH=src python -m benchmarks.serving --check    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.harness import accuracy, run_colearn
from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.data.stream import AbruptDrift, CovariateDrift, ShardStream
from repro.data.synthetic import image_like, lm_examples
from repro.models import transformer as tr
from repro.models.convnets import IMAGE_MODELS
from repro.serving import ModelBank, ServeLoop


# ---------------------------------------------------------------------------
# Scenario 1: the closed loop (LM + ServeLoop)
# ---------------------------------------------------------------------------
def closed_loop(rounds=6, K=3, seed=0, quiet=False):
    """Train a reduced LM on a drifting stream; serve between every round."""
    cfg = get_smoke_config("internlm2-1.8b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=64, segments=((("gqa:dense",), 1),))
    x, y = lm_examples(seed, 240, 16, cfg.vocab_size)
    stream = ShardStream([x, y], K, 8, seed, drift=CovariateDrift(rate=0.05))

    def loss_fn(params, batch):
        bx, by = batch
        return tr.loss_fn(params, cfg, {"tokens": bx, "labels": by})

    ccfg = CoLearnConfig(n_participants=K, T0=2, eta0=0.05, epsilon=0.05,
                         max_rounds=rounds)
    learner = CoLearner(ccfg, loss_fn, round_engine="fused",
                        shard_sizes=stream.sizes,
                        batch_mask=stream.batch_mask if stream.ragged
                        else None)
    params = tr.init_params(jax.random.PRNGKey(seed), cfg, jnp.float32)
    state = learner.init(params)

    bank = ModelBank()
    bank.publish(learner.shared_model(state), round_i=0)  # v1 = init model
    serve = ServeLoop(cfg, learner.shared_model(state), batch=4, max_seq=16)
    serve.poll(bank)
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 7), (4, 6), 0,
                                 cfg.vocab_size)

    def eb(i, j):
        bx, by = stream.epoch_batches(i, j)
        return (jnp.asarray(bx), jnp.asarray(by))

    per_round, swaps = [], 0
    for _ in range(rounds):
        state = learner.run_round(state, eb, on_round_end=bank.publish_from)
        t0 = time.time()
        swapped = serve.poll(bank)
        swap_ms = (time.time() - t0) * 1e3
        swaps += int(swapped)
        _, stats = serve.generate(prompts, 8)
        per_round.append({"round": state["round"], "version": serve.version,
                          "swapped": bool(swapped), "swap_ms": swap_ms,
                          "tokens": stats["tokens"],
                          "tokens_per_s": stats["tokens_per_s"],
                          "compile_count": stats["compile_count"],
                          "staleness": bank.staleness(state["round"])})
        if not quiet:
            print(f"closed_loop,round={state['round']},v{serve.version},"
                  f"{'swap' if swapped else 'hold'},"
                  f"{stats['tokens_per_s']:.0f}tok/s,"
                  f"compiles={stats['compile_count']}", flush=True)
    return {"rounds_served": len(per_round), "swaps": swaps,
            "compile_count": serve.compile_count(),
            "tokens_served": serve.tokens_served,
            "tokens_per_s_mean": float(np.mean(
                [r["tokens_per_s"] for r in per_round])),
            "swap_ms_mean": float(np.mean(
                [r["swap_ms"] for r in per_round if r["swapped"]])),
            "per_round": per_round}


# ---------------------------------------------------------------------------
# Scenario 2: staleness vs accuracy under drift (image task + ModelBank)
# ---------------------------------------------------------------------------
def drift_recovery(rounds=12, drift_round=8, delta=0.12, K=4, n=2000,
                   seed=0, quiet=False):
    """DivergenceTrigger re-syncs after an abrupt drift; quiet rounds keep
    serving the stale model. Serving accuracy is the BANK's (what a user
    hits), not the learner's."""
    xtr, ytr = image_like(seed, n=n)
    xte, yte = image_like(seed + 1000, n=max(400, n // 4))
    init_fn, apply_fn = IMAGE_MODELS["resnet_tiny"]
    stream = ShardStream([xtr, ytr], K, 32, seed,
                         drift=AbruptDrift(at_round=drift_round))
    bank = ModelBank()
    # v1 = the init model (identical to run_colearn's init), so serving is
    # live from round 0 even though the first rounds may stay quiet
    bank.publish(init_fn(jax.random.PRNGKey(seed)), round_i=0)
    served = []

    def hook(learner, state):
        bank.publish_from(learner, state)
        r_i = state["round"]
        dx, dy = stream.transform_test((xte, yte), r_i)
        served.append({"round": r_i, "version": bank.version,
                       "staleness": bank.staleness(r_i),
                       "synced": bool(state["log"][-1].synced),
                       "divergence": float(state["log"][-1].rel_change),
                       "serve_acc": accuracy(apply_fn,
                                             bank.current().params, dx, dy)})
        if not quiet:
            row = served[-1]
            print(f"drift_recovery,round={r_i},v{row['version']},"
                  f"{'sync' if row['synced'] else 'quiet'},"
                  f"stale={row['staleness']},acc={row['serve_acc']:.3f}",
                  flush=True)

    run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte), K=K,
                rounds=rounds, T0=2, eta0=0.05, epsilon=0.03, batch_size=32,
                seed=seed, engine="fused", stream=stream,
                sync_policy=api.DivergenceTrigger(delta=delta),
                on_round_end=hook)
    sync_rounds = [r["round"] for r in served if r["synced"]]
    pre = [r["serve_acc"] for r in served if r["round"] <= drift_round]
    post = [r["serve_acc"] for r in served if r["round"] > drift_round]
    return {"drift_round": drift_round, "delta": delta,
            "sync_rounds": sync_rounds,
            "quiet_rounds": [r["round"] for r in served if not r["synced"]],
            "pre_drift_acc": max(pre) if pre else 0.0,
            "crater_acc": min(r["serve_acc"] for r in served
                              if r["round"] >= drift_round),
            "recovered_acc": max(post) if post else 0.0,
            "per_round": served}


# ---------------------------------------------------------------------------
def check(quiet=False):
    """CI smoke: reduced runs, structural invariants only (no timings)."""
    cl = closed_loop(rounds=5, quiet=quiet)
    # the ISSUE acceptance bar: live across >= 5 rounds, >= 2 hot-swaps,
    # decode compile count flat across every swap
    assert cl["rounds_served"] >= 5, cl["rounds_served"]
    assert cl["swaps"] >= 2, cl["swaps"]
    assert cl["compile_count"] == 1, cl["compile_count"]
    assert cl["tokens_served"] == sum(r["tokens"] for r in cl["per_round"])
    assert all(r["compile_count"] == 1 for r in cl["per_round"])

    # smaller corpus => smaller per-round divergence increments, so the
    # reduced run tightens delta to keep the same sync cadence
    dr = drift_recovery(rounds=9, drift_round=6, n=1200, delta=0.06,
                        quiet=quiet)
    rounds_seen = [r["round"] for r in dr["per_round"]]
    assert rounds_seen == list(range(1, 10)), rounds_seen  # served every round
    # the trigger kept at least one round quiet (stale serving) and forced
    # a re-sync within two rounds of the drift
    assert dr["quiet_rounds"], dr
    assert any(r["staleness"] > 0 for r in dr["per_round"]), dr
    assert any(dr["drift_round"] <= s <= dr["drift_round"] + 2
               for s in dr["sync_rounds"]), dr["sync_rounds"]
    # drift craters the stale model; the post-sync swap recovers it
    assert dr["crater_acc"] < dr["pre_drift_acc"] - 0.2, dr
    assert dr["recovered_acc"] > dr["crater_acc"] + 0.2, dr
    print("serving --check OK: closed loop live 5 rounds / "
          f"{cl['swaps']} swaps / compile_count=1; drift recovery "
          f"{dr['pre_drift_acc']:.2f} -> {dr['crater_acc']:.2f} -> "
          f"{dr['recovered_acc']:.2f} with re-sync at {dr['sync_rounds']}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="CI smoke: reduced run, structural invariants only")
    ap.add_argument("--out", default="", help="write the results as JSON")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--drift-round", type=int, default=8)
    ap.add_argument("--delta", type=float, default=0.12)
    args = ap.parse_args(argv)
    if args.check:
        return check()
    cl = closed_loop()
    dr = drift_recovery(rounds=args.rounds, drift_round=args.drift_round,
                        delta=args.delta)
    print(f"serving_summary,tokens_per_s={cl['tokens_per_s_mean']:.0f},"
          f"swaps={cl['swaps']},compiles={cl['compile_count']},"
          f"recovery={dr['pre_drift_acc']:.3f}->{dr['crater_acc']:.3f}->"
          f"{dr['recovered_acc']:.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"closed_loop": cl, "drift_recovery": dr}, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
