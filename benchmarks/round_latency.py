"""Round-latency benchmark: fused round engine vs the python reference loop.

Measures the wall-clock of one full communication round (T_i local epochs
+ Eq. 2 averaging + Eq. 4 metric) under both ``CoLearner`` engines, in two
regimes (ISSUE 1 tentpole; the result JSON is committed as
benchmarks/BENCH_round_latency.json):

* ``dispatch_bound`` — a tiny linear-regression workload whose per-epoch
  compute is microseconds, so the round time IS the protocol overhead the
  fused engine exists to remove: one jit dispatch + one blocking host sync
  + host-side Eq. 3/Eq. 4 per epoch. The fused engine collapses that to
  one dispatch and one sync per round; the gap grows with T_i.
* ``compute_bound`` — the smoke transformer. On CPU the "device" compute
  shares cores with the host, so there is no dispatch/compute overlap to
  reclaim and the engines run at parity (the fused path additionally pays
  a T_i-epoch batch-stacking copy). On an accelerator the python loop's
  per-epoch blocking sync serializes host work with device steps; this
  regime is where the fused win scales with real hardware.

Per-round times are min-of-N (robust against shared-machine noise); the
first round of each engine (compile) is reported separately.

Usage:
  PYTHONPATH=src python -m benchmarks.round_latency \
      [--rounds 5] [--out benchmarks/BENCH_round_latency.json]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.colearn import CoLearner
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr


def _time_rounds(learner, state, eb, rounds):
    """Per-round wall seconds; round 0 (compile) returned separately."""
    t0 = time.perf_counter()
    state = learner.run_round(state, eb)
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        state = learner.run_round(state, eb)
        times.append(time.perf_counter() - t0)
    return times, compile_s


# ---------------------------------------------------------------------------
# Regime 1: dispatch-bound (tiny model, device-resident data)
# ---------------------------------------------------------------------------
def dispatch_bound(engine, T, rounds, K=4, d=16, n_batches=2, B=4):
    def loss_fn(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] + params["b"] - y) ** 2), {}

    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}
    x = jax.random.normal(k, (K, n_batches, B, d))
    batches = (x, x @ jnp.ones((d, 1)))
    # epsilon=0 keeps T_i fixed so every measured round runs the same work
    ccfg = CoLearnConfig(n_participants=K, T0=T, eta0=0.01, epsilon=0.0,
                         max_rounds=rounds + 1)
    learner = CoLearner(ccfg, loss_fn, round_engine=engine)
    state = learner.init(params)
    return _time_rounds(learner, state, lambda i, j: batches, rounds)


# ---------------------------------------------------------------------------
# Regime 2: compute-bound (smoke transformer, host-staged data)
# ---------------------------------------------------------------------------
def compute_bound(engine, T, rounds, K=4, seq=32, n=512, batch=8):
    cfg = get_smoke_config("internlm2-1.8b").with_(
        n_layers=1, segments=((("gqa:dense",), 1),))
    x, y = lm_examples(0, n, seq, cfg.vocab_size)
    data = ParticipantData(partition_arrays([x, y], K, 0), batch, 0)

    def loss_fn(params, b):
        bx, by = b
        return tr.loss_fn(params, cfg, {"tokens": bx, "labels": by})

    def eb(i, j):
        return tuple(map(jnp.asarray, data.epoch_batches(i, j)))

    ccfg = CoLearnConfig(n_participants=K, T0=T, eta0=0.01, epsilon=0.0,
                         max_rounds=rounds + 1)
    learner = CoLearner(ccfg, loss_fn, round_engine=engine)
    state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg,
                                        jnp.float32))
    return _time_rounds(learner, state, eb, rounds)


SCENARIOS = {
    "dispatch_bound_T8": (dispatch_bound, 8),
    "dispatch_bound_T32": (dispatch_bound, 32),
    "compute_bound_T6": (compute_bound, 6),
}


def run(rounds=5, quiet=False):
    rec = {"backend": jax.default_backend(), "rounds_timed": rounds,
           "scenarios": {}}
    for name, (fn, T) in SCENARIOS.items():
        srec = {"T": T, "engines": {}}
        for engine in ("python", "fused"):
            times, compile_s = fn(engine, T, rounds)
            srec["engines"][engine] = {
                "round_s_min": min(times),
                "round_s_mean": statistics.mean(times),
                "round_s_all": times,
                "first_round_s": compile_s,   # includes compile
            }
        py = srec["engines"]["python"]["round_s_min"]
        fu = srec["engines"]["fused"]["round_s_min"]
        srec["speedup_min"] = py / fu
        rec["scenarios"][name] = srec
        if not quiet:
            print(f"{name:22s} T={T:3d}: python {py*1e3:9.1f} ms  "
                  f"fused {fu*1e3:9.1f} ms  speedup {py/fu:5.2f}x "
                  f"(min of {rounds})", flush=True)
    rec["headline"] = {
        "dispatch_overhead_speedup":
            rec["scenarios"]["dispatch_bound_T32"]["speedup_min"],
        "note": "dispatch_bound isolates the per-epoch dispatch+sync "
                "overhead the fused engine removes; compute_bound on CPU "
                "is parity because host and 'device' share cores — the "
                "overlap win needs a real accelerator.",
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--out", default="benchmarks/BENCH_round_latency.json")
    args = ap.parse_args(argv)
    rec = run(rounds=args.rounds)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
