"""Round-latency benchmark: fused round engine vs the python reference loop.

Measures the wall-clock of one full communication round (T_i local epochs
+ Eq. 2 averaging + Eq. 4 metric) under both ``CoLearner`` engines, in two
regimes (ISSUE 1 tentpole; the result JSON is committed as
benchmarks/BENCH_round_latency.json):

* ``dispatch_bound`` — a tiny linear-regression workload whose per-epoch
  compute is microseconds, so the round time IS the protocol overhead the
  fused engine exists to remove: one jit dispatch + one blocking host sync
  + host-side Eq. 3/Eq. 4 per epoch. The fused engine collapses that to
  one dispatch and one sync per round; the gap grows with T_i.
* ``compute_bound`` — the smoke transformer. On CPU the "device" compute
  shares cores with the host, so there is no dispatch/compute overlap to
  reclaim and the engines run at parity (the fused path additionally pays
  a T_i-epoch batch-stacking copy). On an accelerator the python loop's
  per-epoch blocking sync serializes host work with device steps; this
  regime is where the fused win scales with real hardware.

Per-round times are min-of-N (robust against shared-machine noise); the
first round of each engine (compile) is reported separately.

``--check-retrace`` (the CI smoke, no timings) asserts the ISSUE 4
hot-path invariant instead: the schedule rides into the fused executables
as traced data (``repro.core.engine``), so an ILE doubling of T_i, a
built-in schedule swap (CLR -> ELR -> cosine -> warmup), and the per-round
warmup/budget re-parameterizations all reuse ONE compiled program per
chunk shape — the compile count must stay flat.

Usage:
  PYTHONPATH=src python -m benchmarks.round_latency \
      [--rounds 5] [--out benchmarks/BENCH_round_latency.json] \
      [--check-retrace]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.analysis import guards
from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr


def _time_rounds(learner, state, eb, rounds):
    """Per-round wall seconds; round 0 (compile) returned separately."""
    t0 = time.perf_counter()
    state = learner.run_round(state, eb)
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        state = learner.run_round(state, eb)
        times.append(time.perf_counter() - t0)
    return times, compile_s


# ---------------------------------------------------------------------------
# Regime 1: dispatch-bound (tiny model, device-resident data)
# ---------------------------------------------------------------------------
def dispatch_bound(engine, T, rounds, K=4, d=16, n_batches=2, B=4):
    def loss_fn(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] + params["b"] - y) ** 2), {}

    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (d, 1)), "b": jnp.zeros((1,))}
    x = jax.random.normal(k, (K, n_batches, B, d))
    batches = (x, x @ jnp.ones((d, 1)))
    # epsilon=0 keeps T_i fixed so every measured round runs the same work
    ccfg = CoLearnConfig(n_participants=K, T0=T, eta0=0.01, epsilon=0.0,
                         max_rounds=rounds + 1)
    learner = CoLearner(ccfg, loss_fn, round_engine=engine)
    state = learner.init(params)
    return _time_rounds(learner, state, lambda i, j: batches, rounds)


# ---------------------------------------------------------------------------
# Regime 2: compute-bound (smoke transformer, host-staged data)
# ---------------------------------------------------------------------------
def compute_bound(engine, T, rounds, K=4, seq=32, n=512, batch=8):
    cfg = get_smoke_config("internlm2-1.8b").with_(
        n_layers=1, segments=((("gqa:dense",), 1),))
    x, y = lm_examples(0, n, seq, cfg.vocab_size)
    data = ParticipantData(partition_arrays([x, y], K, 0), batch, 0)

    def loss_fn(params, b):
        bx, by = b
        return tr.loss_fn(params, cfg, {"tokens": bx, "labels": by})

    def eb(i, j):
        return tuple(map(jnp.asarray, data.epoch_batches(i, j)))

    ccfg = CoLearnConfig(n_participants=K, T0=T, eta0=0.01, epsilon=0.0,
                         max_rounds=rounds + 1)
    learner = CoLearner(ccfg, loss_fn, round_engine=engine)
    state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg,
                                        jnp.float32))
    return _time_rounds(learner, state, eb, rounds)


SCENARIOS = {
    "dispatch_bound_T8": (dispatch_bound, 8),
    "dispatch_bound_T32": (dispatch_bound, 32),
    "compute_bound_T6": (compute_bound, 6),
}


def run(rounds=5, quiet=False):
    rec = {"backend": jax.default_backend(), "rounds_timed": rounds,
           "scenarios": {}}
    for name, (fn, T) in SCENARIOS.items():
        srec = {"T": T, "engines": {}}
        for engine in ("python", "fused"):
            times, compile_s = fn(engine, T, rounds)
            srec["engines"][engine] = {
                "round_s_min": min(times),
                "round_s_mean": statistics.mean(times),
                "round_s_all": times,
                "first_round_s": compile_s,   # includes compile
            }
        py = srec["engines"]["python"]["round_s_min"]
        fu = srec["engines"]["fused"]["round_s_min"]
        srec["speedup_min"] = py / fu
        rec["scenarios"][name] = srec
        if not quiet:
            print(f"{name:22s} T={T:3d}: python {py*1e3:9.1f} ms  "
                  f"fused {fu*1e3:9.1f} ms  speedup {py/fu:5.2f}x "
                  f"(min of {rounds})", flush=True)
    rec["headline"] = {
        "dispatch_overhead_speedup":
            rec["scenarios"]["dispatch_bound_T32"]["speedup_min"],
        "note": "dispatch_bound isolates the per-epoch dispatch+sync "
                "overhead the fused engine removes; compute_bound on CPU "
                "is parity because host and 'device' share cores — the "
                "overlap win needs a real accelerator.",
    }
    return rec


def check_retrace():
    """CI smoke: fused-engine compile counts stay flat across an ILE
    doubling of T_i AND built-in schedule swaps/re-parameterizations."""
    def zero_loss(params, batch):
        return jnp.zeros(()), {}

    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (4, 1)), "b": jnp.zeros((1,))}
    x = jax.random.normal(k, (2, 1, 2, 4))
    batches = (x, x @ jnp.ones((4, 1)))

    # 1) chunked path: zero gradients => rel=0 => Eq. 4 doubles T every
    #    round (2,2,4,8 with chunk=2 => every chunk is the same C=2 shape);
    #    then swap the schedule mid-run, three times
    cfg = CoLearnConfig(n_participants=2, T0=2, epsilon=0.01,
                        epochs_rule="ile", max_rounds=8)
    learner = CoLearner(cfg, zero_loss,
                        round_engine=api.FusedEngine(chunk=2))
    state = learner.init(params)
    for _ in range(4):
        state = learner.run_round(state, lambda i, j: batches)
    assert [l.T for l in state["log"]] == [2, 2, 4, 8], \
        [l.T for l in state["log"]]
    for spec in ("elr", "cosine",
                 api.WarmupCLR(eta0=0.02, warmup_rounds=16)):
        learner.set_schedule(spec)
        state = learner.run_round(state, lambda i, j: batches)
    guards.assert_compile_count(learner._fused_epochs, 1,
                                "chunk executable")
    guards.assert_compile_count(learner._fused_finalize, 1, "finalize")

    # 2) single-shot path at fixed T: schedule swaps + a warmup ramping
    #    eta^i per round must reuse the one round executable
    cfg2 = CoLearnConfig(n_participants=2, T0=2, epsilon=0.0, max_rounds=8,
                         epochs_rule="fle")
    learner2 = CoLearner(cfg2, zero_loss, round_engine="fused",
                         schedule=api.WarmupCLR(eta0=0.04, warmup_rounds=4))
    state2 = learner2.init(params)
    for _ in range(3):
        state2 = learner2.run_round(state2, lambda i, j: batches)
    learner2.set_schedule("elr")
    state2 = learner2.run_round(state2, lambda i, j: batches)
    guards.assert_compile_count(learner2._fused_round, 1,
                                "round executable")
    # the warmup actually ramped (the traced eta^i changed per round)
    lrs = [l.lr_first for l in state2["log"][:3]]
    assert lrs[0] < lrs[1] < lrs[2], lrs

    # 3) heterogeneity scenario: the ragged batch mask AND the example-
    #    count weight matrix ride into the masked/weighted executables as
    #    traced data, so an ILE doubling (chunked path, same C) still
    #    compiles each program exactly once
    import numpy as _np
    x3 = jax.random.normal(k, (2, 2, 2, 4))       # ragged: 2 vs 1 batches
    batches3 = (x3, x3 @ jnp.ones((4, 1)))
    cfg3 = CoLearnConfig(n_participants=2, T0=2, epsilon=0.01,
                         epochs_rule="ile", max_rounds=8)
    learner3 = CoLearner(cfg3, zero_loss,
                         round_engine=api.FusedEngine(chunk=2),
                         aggregator=api.FullAverage(weights=(3.0, 1.0)),
                         batch_mask=_np.array([[True, True],
                                               [True, False]]))
    state3 = learner3.init(params)
    for _ in range(4):
        state3 = learner3.run_round(state3, lambda i, j: batches3)
    assert [l.T for l in state3["log"]] == [2, 2, 4, 8], \
        [l.T for l in state3["log"]]
    guards.assert_compile_count(learner3._fused_epochs, 1,
                                "masked chunk executable")
    guards.assert_compile_count(learner3._fused_finalize, 1,
                                "weighted finalize")

    # 4) elastic membership: the (K,) liveness row is traced data, so
    #    crashes/rejoins flipping the live set EVERY round (plus the live-
    #    renormalized mixing matrix changing with it) must reuse the same
    #    executables — membership churn never recompiles
    from repro.core.membership import ScriptedChurn
    churn = ScriptedChurn(events=(("crash", 1, 1), ("rejoin", 2, 1),
                                  ("crash", 3, 0)))
    cfg4 = CoLearnConfig(n_participants=2, T0=2, epsilon=0.0, max_rounds=8,
                         epochs_rule="fle")
    learner4 = CoLearner(cfg4, zero_loss, round_engine="fused", churn=churn)
    state4 = learner4.init(params)
    for _ in range(4):
        state4 = learner4.run_round(state4, lambda i, j: batches)
    assert [l.live for l in state4["log"]] == [2, 1, 2, 1]
    guards.assert_compile_count(learner4._fused_round, 1,
                                "round executable under churn")

    # event rounds HOLD the ILE doubling (a membership change perturbs the
    # rel signal), so interleave quiet rounds to still exercise T growth:
    # T = 2,2,2,4,4,8 with churn flips at rounds 1, 3, 5
    churn5 = ScriptedChurn(events=(("crash", 1, 1), ("rejoin", 3, 1),
                                   ("crash", 5, 0)))
    cfg5 = CoLearnConfig(n_participants=2, T0=2, epsilon=0.01,
                         epochs_rule="ile", max_rounds=8)
    learner5 = CoLearner(cfg5, zero_loss, churn=churn5,
                         round_engine=api.FusedEngine(chunk=2))
    state5 = learner5.init(params)
    for _ in range(6):
        state5 = learner5.run_round(state5, lambda i, j: batches)
    assert [l.T for l in state5["log"]] == [2, 2, 2, 4, 4, 8], \
        [l.T for l in state5["log"]]
    guards.assert_compile_count(learner5._fused_epochs, 1,
                                "chunk executable under churn")
    guards.assert_compile_count(learner5._fused_finalize, 1,
                                "finalize under churn")

    # 6) error-feedback wire: the residual is traced data threaded through
    #    round/chunk/finalize (ISSUE 7 acceptance) — an ILE doubling on the
    #    chunked path and schedule swaps on the single-shot path must still
    #    compile each stateful executable exactly once
    ef = api.FlatFusedIntN(bits=4, error_feedback=True)
    cfg6 = CoLearnConfig(n_participants=2, T0=2, epsilon=0.01,
                         epochs_rule="ile", max_rounds=8)
    learner6 = CoLearner(cfg6, zero_loss, codec=ef,
                         round_engine=api.FusedEngine(chunk=2))
    state6 = learner6.init(params)
    for _ in range(4):
        state6 = learner6.run_round(state6, lambda i, j: batches)
    assert [l.T for l in state6["log"]] == [2, 2, 4, 8], \
        [l.T for l in state6["log"]]
    assert state6["residual"] is not None
    guards.assert_compile_count(learner6._fused_epochs, 1,
                                "EF chunk executable")
    guards.assert_compile_count(learner6._fused_finalize, 1,
                                "EF stateful finalize")

    cfg6b = CoLearnConfig(n_participants=2, T0=2, epsilon=0.0, max_rounds=8,
                          epochs_rule="fle")
    learner6b = CoLearner(cfg6b, zero_loss, codec=ef, round_engine="fused")
    state6b = learner6b.init(params)
    for _ in range(2):
        state6b = learner6b.run_round(state6b, lambda i, j: batches)
    learner6b.set_schedule("elr")
    state6b = learner6b.run_round(state6b, lambda i, j: batches)
    guards.assert_compile_count(learner6b._fused_round, 1,
                                "EF round executable")

    # 7) time-varying topology: the per-round gossip matrix of the one-
    #    peer exponential graph is traced data, so the graph changing
    #    EVERY round (and the D² correction riding the stateful slot on
    #    top) must reuse one executable per program — a topology change
    #    never recompiles
    cfg7 = CoLearnConfig(n_participants=4, T0=2, epsilon=0.0, max_rounds=8,
                         epochs_rule="fle")
    k7 = jax.random.PRNGKey(0)
    x7 = jax.random.normal(k7, (4, 1, 2, 4))
    batches7 = (x7, x7 @ jnp.ones((4, 1)))
    learner7 = CoLearner(cfg7, zero_loss, round_engine="fused",
                         aggregator=api.GraphGossip("exponential"))
    state7 = learner7.init(params)
    for _ in range(4):                   # period 2: every matrix seen twice
        state7 = learner7.run_round(state7, lambda i, j: batches7)
    guards.assert_compile_count(
        learner7._fused_round, 1,
        "round executable under time-varying topology")

    cfg7b = CoLearnConfig(n_participants=4, T0=2, epsilon=0.01,
                          epochs_rule="ile", max_rounds=8)
    learner7b = CoLearner(cfg7b, zero_loss,
                          aggregator=api.D2Gossip("exponential"),
                          round_engine=api.FusedEngine(chunk=2))
    state7b = learner7b.init(params)
    for _ in range(4):
        state7b = learner7b.run_round(state7b, lambda i, j: batches7)
    assert state7b["residual"] is not None
    guards.assert_compile_count(
        learner7b._fused_epochs, 1,
        "chunk executable under D2+time-varying topology")
    guards.assert_compile_count(
        learner7b._fused_finalize, 1,
        "stateful finalize under D2+time-varying topology")

    # 8) streaming drift restage: a ShardStream re-stages DIFFERENT shard
    #    contents every round (covariate rotation re-transforms, label
    #    shift re-deals the assignment), but shapes are a round-0
    #    invariant — the drifted snapshots ride into the executables as
    #    traced arguments, so a drifting stream never recompiles
    from repro.data.stream import CovariateDrift, LabelShift, ShardStream
    x8 = _np.asarray(jax.random.normal(k, (32, 4)), _np.float32)
    y8 = _np.arange(32) % 4
    for drift8 in (CovariateDrift(rate=0.3), LabelShift(rate=0.25)):
        stream8 = ShardStream([x8, y8], 2, 4, 0, drift=drift8)
        # the stream actually moved: round 3 stages different contents
        b0 = stream8.epoch_batches(0, 0)
        b3 = stream8.epoch_batches(3, 0)
        assert not all(_np.array_equal(a, b) for a, b in zip(b0, b3)), \
            f"{drift8.name} staged identical contents at rounds 0 and 3"
        cfg8 = CoLearnConfig(n_participants=2, T0=2, epsilon=0.01,
                             epochs_rule="ile", max_rounds=8)
        learner8 = CoLearner(cfg8, zero_loss,
                             round_engine=api.FusedEngine(chunk=2))
        state8 = learner8.init(params)
        for _ in range(4):
            state8 = learner8.run_round(
                state8,
                lambda i, j: tuple(map(jnp.asarray,
                                       stream8.epoch_batches(i, j))))
        assert [l.T for l in state8["log"]] == [2, 2, 4, 8], \
            [l.T for l in state8["log"]]
        guards.assert_compile_count(
            learner8._fused_epochs, 1,
            f"chunk executable under {drift8.name} drift")
        guards.assert_compile_count(
            learner8._fused_finalize, 1,
            f"finalize under {drift8.name} drift")

    print("check-retrace OK: chunk/finalize/round executables compiled "
          "once across an ILE doubling, 4 schedule swaps, a warmup "
          "ramp, the masked+weighted heterogeneity scenario, "
          "per-round membership churn, the stateful error-feedback "
          "wire (residual traced through both engine paths), a "
          "per-round time-varying gossip topology (plain and D²), and "
          "a drifting ShardStream restaged every round")
    return 0


def check_transfer(rounds=3):
    """CI smoke: after the warmup round, the fused round loop holds zero
    *implicit* host<->device transfers — host-staged numpy batches enter
    through the engine's one explicit device_put, per-round scalars/packs
    are staged explicitly, and the only D2H is the aux fetch. Runs both
    the single-executable path and the chunked (epochs+finalize) path
    under ``guards.no_transfer()``."""
    import numpy as np

    from repro.data.pipeline import ParticipantData

    def loss_fn(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] + params["b"] - y) ** 2), {}

    rng = np.random.default_rng(0)
    K, n, B, d = 4, 64, 8, 6
    shards = [[rng.standard_normal((n, d)).astype(np.float32),
               rng.standard_normal((n, 1)).astype(np.float32)]
              for _ in range(K)]
    data = ParticipantData(shards, batch_size=B, seed=0)
    params = {"w": jnp.zeros((d, 1)), "b": jnp.zeros((1,))}
    for chunk, label in ((32, "single-executable"), (1, "chunked")):
        ccfg = CoLearnConfig(n_participants=K, T0=2, eta0=0.01,
                             epsilon=0.01, max_rounds=rounds + 2)
        learner = CoLearner(ccfg, loss_fn,
                            round_engine=api.FusedEngine(chunk=chunk))
        state = learner.init(params)
        state = learner.run_round(state, data.epoch_batches)  # compile
        with guards.no_transfer():
            for _ in range(rounds):
                state = learner.run_round(state, data.epoch_batches)
        print(f"check-transfer OK ({label}): {rounds} post-warmup rounds "
              "with host-staged numpy batches held zero implicit "
              "transfers")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--out", default="benchmarks/BENCH_round_latency.json")
    ap.add_argument("--check-retrace", action="store_true",
                    help="assert fused compile counts stay flat across an "
                         "ILE doubling and schedule swaps (CI smoke, no "
                         "timings)")
    ap.add_argument("--check-transfer", action="store_true",
                    help="assert the post-warmup fused round loop is free "
                         "of implicit host<->device transfers (CI smoke, "
                         "no timings)")
    args = ap.parse_args(argv)
    if args.check_retrace:
        return check_retrace()
    if args.check_transfer:
        return check_transfer()
    rec = run(rounds=args.rounds)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
