"""Tables 3–6 analog: vanilla vs co-learning across the three modalities
(image handled by cifar_like; here text + audio, incl. the CRNN pooling
variants of Table 6). Paper claim C1/C4: parity across tasks and archs."""
from __future__ import annotations

from benchmarks.harness import run_colearn, run_vanilla
from repro.data.synthetic import audio_like, text_like
from repro.models.convnets import AUDIO_MODELS, TEXT_MODELS


def run(rounds=5, seed=0, quiet=False):
    rows = []
    xtr, ytr = text_like(seed, n=4000)
    xte, yte = text_like(seed + 1000, n=1000)
    for name, (init_fn, apply_fn) in TEXT_MODELS.items():
        van = run_vanilla(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                          epochs=rounds, seed=seed)
        col = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                          K=5, rounds=rounds, T0=1, epsilon=0.03, seed=seed)
        rows.append({"task": "text", "model": name,
                     "vanilla": van["acc"][-1], "colearn": col["acc"][-1]})
        if not quiet:
            r = rows[-1]
            print(f"table4,{name},vanilla={r['vanilla']:.4f},"
                  f"colearn={r['colearn']:.4f}", flush=True)

    xtr, ytr = audio_like(seed, n=4000)
    xte, yte = audio_like(seed + 1000, n=1000)
    for name, (init_fn, apply_fn) in AUDIO_MODELS.items():
        van = run_vanilla(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                          epochs=rounds, seed=seed)
        col = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                          K=5, rounds=rounds, T0=1, epsilon=0.03, seed=seed)
        rows.append({"task": "audio", "model": name,
                     "vanilla": van["acc"][-1], "colearn": col["acc"][-1]})
        if not quiet:
            r = rows[-1]
            print(f"table56,{name},vanilla={r['vanilla']:.4f},"
                  f"colearn={r['colearn']:.4f}", flush=True)
    return rows


def main():
    return run()


if __name__ == "__main__":
    main()
