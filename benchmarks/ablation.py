"""Figure 2 analog + the heterogeneity sweep on the image-like task.

Paper claim C2 (``run``): CLR+ILE is the best combo; ELR+FLE stalls.
Emits one CSV row per (model, combo): final accuracy + accuracy curve.

Heterogeneity sweep (``heterogeneity`` — ISSUE 5 tentpole): the paper's
"different types of data" claim as a measured axis. Dirichlet label skew
alpha ∈ {0.1, 1, inf} (inf = the paper's IID split) × {uniform, example-
count-weighted} Eq. 2 averaging, all through the ragged masked pipeline
(shard sizes come out unequal under skew; nothing is clamped or dropped).
The committed result lives in benchmarks/BENCH_heterogeneity.json;
``--check`` is the CI smoke: a reduced sweep asserting the structural
invariants (exact example coverage, finite accuracies, weighted==uniform
bit-closeness on equal shards) without timing anything.

Drift sweep (``--drift`` — ISSUE 9 continuous operation): abrupt-task-
switch severity × sync policy (FLE every-round | ILE doubling |
divergence-triggered). Each cell trains on a drifting ``ShardStream`` and
scores per round on the drifted test set; rows report pre-drift / crater /
recovered accuracy plus how many rounds actually synced (the comm the
trigger saves). Committed in benchmarks/BENCH_drift.json.

Usage:
  PYTHONPATH=src python -m benchmarks.ablation                # Figure 2 CSV
  PYTHONPATH=src python -m benchmarks.ablation --heterogeneity \
      [--out benchmarks/BENCH_heterogeneity.json]
  PYTHONPATH=src python -m benchmarks.ablation --drift \
      [--out benchmarks/BENCH_drift.json]
  PYTHONPATH=src python -m benchmarks.ablation --check        # CI smoke
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.harness import run_colearn
from repro.data.synthetic import image_like
from repro.models.convnets import IMAGE_MODELS

COMBOS = [("clr", "ile"), ("clr", "fle"), ("elr", "ile"), ("elr", "fle")]

#: Dirichlet concentrations for the heterogeneity sweep; None = alpha->inf,
#: i.e. the paper's IID split (the equal-shard control arm)
ALPHAS = (0.1, 1.0, None)


def run(models=("resnet_tiny", "densenet_tiny"), rounds=6, n=4000, seed=0,
        quiet=False):
    xtr, ytr = image_like(seed, n=n)
    xte, yte = image_like(seed + 1000, n=1000)
    rows = []
    for name in models:
        init_fn, apply_fn = IMAGE_MODELS[name]
        for sched, erule in COMBOS:
            r = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                            K=5, rounds=rounds, T0=1, epsilon=0.03,
                            schedule=sched, epochs_rule=erule, seed=seed)
            rows.append({"model": name, "combo": f"{sched}+{erule}",
                         "final_acc": r["acc"][-1], "curve": r["acc"],
                         "T_per_round": r["T"]})
            if not quiet:
                print(f"ablation,{name},{sched}+{erule},"
                      f"{r['acc'][-1]:.4f},T={r['T']}", flush=True)
    return rows


def heterogeneity(model="resnet_tiny", rounds=5, n=4000, K=5, seed=0,
                  batch_size=32, quiet=False, keep_params=False):
    """alpha x weighting sweep: one row per (alpha, weighted) cell.

    ``keep_params=True`` attaches each cell's final shared model under the
    non-JSON ``"_final_params"`` key — ``check`` uses it to compare
    weighted-vs-uniform without re-training; the JSON-writing path leaves
    it off."""
    xtr, ytr = image_like(seed, n=n)
    xte, yte = image_like(seed + 1000, n=1000)
    init_fn, apply_fn = IMAGE_MODELS[model]
    rows = []
    for alpha in ALPHAS:
        for weighted in (False, True):
            kw = (dict(partition="dirichlet", dirichlet_alpha=alpha)
                  if alpha is not None else dict(partition="iid"))
            r = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                            K=K, rounds=rounds, T0=1, epsilon=0.03,
                            batch_size=batch_size, seed=seed,
                            engine="fused", weighted=weighted, **kw)
            sizes = list(r["shard_sizes"])
            rows.append({
                "model": model, "alpha": alpha if alpha is not None
                else "inf",
                "weighted": weighted, "final_acc": r["acc"][-1],
                "curve": r["acc"], "shard_sizes": sizes,
                "coverage": int(sum(sizes)),
            })
            if keep_params:
                rows[-1]["_final_params"] = r["final_params"]
            if not quiet:
                a = "inf" if alpha is None else alpha
                print(f"heterogeneity,{model},alpha={a},"
                      f"weighted={int(weighted)},{r['acc'][-1]:.4f},"
                      f"shards={sizes}", flush=True)
    return rows


#: drift sweep axes: relabeled label-space fraction x Eq.4 sync policy
SEVERITIES = (0.5, 1.0)
POLICIES = ("fle", "ile", "divtrigger")


def drift_sweep(model="resnet_tiny", rounds=10, drift_round=6, n=2000, K=4,
                seed=0, delta=0.12, quiet=False):
    """Drift severity x sync policy: recovery after an abrupt task switch.

    One row per (severity, policy) cell, trained on a ``ShardStream`` with
    ``AbruptDrift(at_round=drift_round, severity=...)`` and evaluated per
    round on the drifted test set (``run_colearn(drift=...)`` plumbing).
    The headline: ``divtrigger`` recovers like the every-round policies
    while syncing only the rounds the divergence forces — the quiet-round
    comm it skips is the benefit measured here.
    """
    from repro.core import api
    from repro.data.stream import AbruptDrift

    xtr, ytr = image_like(seed, n=n)
    xte, yte = image_like(seed + 1000, n=max(400, n // 4))
    init_fn, apply_fn = IMAGE_MODELS[model]
    rows = []
    for severity in SEVERITIES:
        for policy in POLICIES:
            kw = (dict(sync_policy=api.DivergenceTrigger(delta=delta))
                  if policy == "divtrigger" else dict(epochs_rule=policy))
            r = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                            K=K, rounds=rounds, T0=2, eta0=0.05,
                            epsilon=0.03, batch_size=32, seed=seed,
                            engine="fused",
                            drift=AbruptDrift(at_round=drift_round,
                                              severity=severity), **kw)
            # acc[i] is scored at stream round i+1: the drift first hits
            # the eval at index drift_round - 1
            post = r["acc"][drift_round - 1:]
            rows.append({"model": model, "severity": severity,
                         "policy": policy, "drift_round": drift_round,
                         "pre_drift_acc": max(r["acc"][:drift_round - 1]),
                         "crater_acc": min(post),
                         "recovered_acc": max(post),
                         "final_acc": r["acc"][-1], "curve": r["acc"],
                         "synced_rounds": r["synced_rounds"],
                         "total_comm_bytes": r["total_comm_bytes"]})
            if not quiet:
                row = rows[-1]
                print(f"drift,{model},sev={severity},{policy},"
                      f"{row['pre_drift_acc']:.3f}->{row['crater_acc']:.3f}"
                      f"->{row['recovered_acc']:.3f},"
                      f"synced={row['synced_rounds']}/{rounds}", flush=True)
    return rows


def check(quiet=False):
    """CI smoke: reduced sweep, structural invariants only (no timings)."""
    n, K, rounds = 800, 4, 2
    rows = heterogeneity(rounds=rounds, n=n, K=K, batch_size=16,
                         quiet=quiet, keep_params=True)
    assert len(rows) == 2 * len(ALPHAS), len(rows)
    for row in rows:
        # no silent data loss: every example landed in exactly one shard
        assert row["coverage"] == n, row
        assert len(row["shard_sizes"]) == K and min(row["shard_sizes"]) > 0
        assert np.isfinite(row["final_acc"]) and 0 < row["final_acc"] <= 1
    # skew actually skewed: alpha=0.1 shard sizes spread far wider than IID
    spread = {r["alpha"]: max(r["shard_sizes"]) - min(r["shard_sizes"])
              for r in rows}
    assert spread[0.1] > spread["inf"], spread
    assert spread["inf"] <= 1          # round-robined remainder only
    # on equal (IID) shards the example-count weights are uniform, so the
    # weighted path must reproduce the uniform Eq. 2 model — compared on
    # the sweep's own alpha=inf arms at params level (<=1e-6; accuracy
    # curves quantize at 1/len(test) and would make this flaky)
    models = {r["weighted"]: r["_final_params"] for r in rows
              if r["alpha"] == "inf"}
    diff = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(models[True]),
                   jax.tree.leaves(models[False])))
    assert diff <= 1e-6, f"weighted != uniform on equal shards: {diff}"
    print("ablation --check OK: coverage exact, skew present, "
          "weighted==uniform on equal shards")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--heterogeneity", action="store_true",
                    help="run the alpha x weighting sweep instead of the "
                         "Figure 2 combo ablation")
    ap.add_argument("--drift", action="store_true",
                    help="run the drift severity x sync policy sweep "
                         "(abrupt task switch, recovery per policy)")
    ap.add_argument("--out", default="",
                    help="write the heterogeneity/drift rows as JSON")
    ap.add_argument("--check", action="store_true",
                    help="CI smoke: reduced heterogeneity sweep, "
                         "structural invariants only")
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args(argv)
    if args.check:
        return check()
    if args.drift:
        rows = drift_sweep()
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"task": "image_like", "drift": "abrupt",
                           "rows": rows}, f, indent=1)
            print(f"wrote {args.out}")
        return 0
    if args.heterogeneity:
        rows = heterogeneity(rounds=args.rounds)
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"task": "image_like", "rows": rows}, f, indent=1)
            print(f"wrote {args.out}")
        return 0
    rows = run()
    # the paper's headline: CLR+ILE >= every other combo (per model)
    for name in {r["model"] for r in rows}:
        sub = {r["combo"]: r["final_acc"] for r in rows if r["model"] == name}
        best = max(sub, key=sub.get)
        print(f"ablation_summary,{name},best={best},clr+ile={sub['clr+ile']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
