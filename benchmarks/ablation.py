"""Figure 2 analog: CLR/ELR × ILE/FLE ablation on the image-like task.

Paper claim C2: CLR+ILE is the best combo; ELR+FLE stalls.
Emits one CSV row per (model, combo): final accuracy + accuracy curve.
"""
from __future__ import annotations

import numpy as np

from benchmarks.harness import run_colearn
from repro.data.synthetic import image_like
from repro.models.convnets import IMAGE_MODELS

COMBOS = [("clr", "ile"), ("clr", "fle"), ("elr", "ile"), ("elr", "fle")]


def run(models=("resnet_tiny", "densenet_tiny"), rounds=6, n=4000, seed=0,
        quiet=False):
    xtr, ytr = image_like(seed, n=n)
    xte, yte = image_like(seed + 1000, n=1000)
    rows = []
    for name in models:
        init_fn, apply_fn = IMAGE_MODELS[name]
        for sched, erule in COMBOS:
            r = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                            K=5, rounds=rounds, T0=1, epsilon=0.03,
                            schedule=sched, epochs_rule=erule, seed=seed)
            rows.append({"model": name, "combo": f"{sched}+{erule}",
                         "final_acc": r["acc"][-1], "curve": r["acc"],
                         "T_per_round": r["T"]})
            if not quiet:
                print(f"ablation,{name},{sched}+{erule},"
                      f"{r['acc'][-1]:.4f},T={r['T']}", flush=True)
    return rows


def main():
    rows = run()
    # the paper's headline: CLR+ILE >= every other combo (per model)
    for name in {r["model"] for r in rows}:
        sub = {r["combo"]: r["final_acc"] for r in rows if r["model"] == name}
        best = max(sub, key=sub.get)
        print(f"ablation_summary,{name},best={best},clr+ile={sub['clr+ile']:.4f}")
    return rows


if __name__ == "__main__":
    main()
