"""Table 2 analog: vanilla vs ensemble vs co-learning, three image archs.

Paper claim C1: co-learning ≈ vanilla; ensemble ~10 pts worse.
"""
from __future__ import annotations

from benchmarks.harness import run_colearn, run_ensemble, run_vanilla
from repro.data.synthetic import image_like
from repro.models.convnets import IMAGE_MODELS


def run(models=("vgg_tiny", "resnet_tiny", "densenet_tiny"), rounds=6,
        n=4000, seed=0, quiet=False):
    xtr, ytr = image_like(seed, n=n)
    xte, yte = image_like(seed + 1000, n=1000)
    rows = []
    for name in models:
        init_fn, apply_fn = IMAGE_MODELS[name]
        van = run_vanilla(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                          epochs=rounds, seed=seed)
        ens = run_ensemble(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                           K=5, epochs=rounds, seed=seed)
        col = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                          K=5, rounds=rounds + 2, T0=1, epsilon=0.03, seed=seed)
        rows.append({"model": name, "vanilla": van["acc"][-1],
                     "ensemble": ens["acc"], "colearn": col["acc"][-1],
                     "local_mean": sum(ens["local_acc"]) / len(ens["local_acc"])})
        if not quiet:
            r = rows[-1]
            print(f"table2,{name},vanilla={r['vanilla']:.4f},"
                  f"ensemble={r['ensemble']:.4f},colearn={r['colearn']:.4f},"
                  f"local_mean={r['local_mean']:.4f}", flush=True)
    return rows


def check():
    """CI smoke: one tiny arch, tiny corpus, 1 round — asserts the three
    baselines still run end-to-end and report sane accuracies."""
    rows = run(models=("vgg_tiny",), rounds=1, n=320, quiet=True)
    assert len(rows) == 1
    r = rows[0]
    for key in ("vanilla", "ensemble", "colearn", "local_mean"):
        assert 0.0 <= r[key] <= 1.0, (key, r)
    print("cifar_like --check OK", flush=True)
    return 0


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--check", action="store_true",
                    help="fast CI smoke mode: one tiny arch, 1 round")
    args = ap.parse_args(argv)
    if args.check:
        return check()
    return run(rounds=args.rounds)


if __name__ == "__main__":
    main()
