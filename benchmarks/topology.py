"""Topology sweep: graph gossip x D² on the non-IID Dirichlet task.

The PR-5 heterogeneity sweep measured plain averaging collapsing at
Dirichlet alpha=0.1 (0.30 unweighted vs 0.93 IID; example-count weighting
recovers 0.49 — the committed BENCH_heterogeneity.json baseline). This
benchmark puts the topology subsystem on that same task: each arm is a
communication graph (ring | time-varying one-peer exponential | complete)
x {plain GraphGossip, D2Gossip}, against the weighted FullAverage
baseline. Decentralized gossip rows stay distinct within a round, so
every arm is ALSO evaluated on the consensus mean of the K replicas —
doubly-stochastic mixing preserves it, and it is what the deployment
would serve. Expected shape (committed BENCH_topology.json): plain
sparse gossip degrades under the shard drift, D² over the well-mixing
sparse graphs (torus, exponential) recovers the weighted
full-averaging baseline at a fraction of the per-round wire bytes
(O(degree), not O(K)); on the slowly-mixing DIRECTED ring the D²
correction hurts instead — Exact Diffusion assumes symmetric
well-conditioned W — and that negative row is kept on purpose.

``--check`` is the CI smoke (forced 8-device host platform, no timings):

  * sparse pod path == dense host reference: GraphGossip / D2Gossip
    mesh specializations (one ppermute per neighbor permutation) match
    the dense-einsum host mixing on an 8-pod mesh;
  * D² >= plain gossip on a reduced non-IID (alpha=0.1) smoke, compared
    on consensus-mean accuracy;
  * comm bill O(degree), never O(K): ring K-independent, hypercube
    log2(K), complete (K-1)-proportional;
  * every registered topology yields a doubly-stochastic matrix.

Usage:
  PYTHONPATH=src python -m benchmarks.topology \
      [--out benchmarks/BENCH_topology.json]
  PYTHONPATH=src python -m benchmarks.topology --check      # CI smoke
"""
from __future__ import annotations

import os
import sys

if "--check" in sys.argv:
    # the mesh-parity smoke needs a multi-device pod axis; flags must be
    # set before jax initializes
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.harness import accuracy, run_colearn
from repro.core import api
from repro.core import topology as topo_mod
from repro.data.synthetic import image_like
from repro.models.convnets import IMAGE_MODELS

#: (arm name, aggregator factory) — None = weighted FullAverage baseline.
#: ring (directed legacy gossip) vs torus (symmetric MH cycle — at prime K
#: the 2-d torus degenerates to the K-cycle) separates the D² story: the
#: Exact-Diffusion correction assumes symmetric well-mixing W, so it wins
#: on torus/exponential and loses on the slowly-mixing directed ring —
#: the committed JSON keeps that negative row on purpose.
ARMS = [
    ("full_weighted", None),
    ("ring_plain", lambda: api.GraphGossip("ring")),
    ("ring_d2", lambda: api.D2Gossip("ring")),
    ("torus_plain", lambda: api.GraphGossip("torus")),
    ("torus_d2", lambda: api.D2Gossip("torus")),
    ("exponential_plain", lambda: api.GraphGossip("exponential")),
    ("exponential_d2", lambda: api.D2Gossip("exponential")),
    ("complete_plain", lambda: api.GraphGossip("complete")),
]


def consensus_mean(state):
    """Mean of the K replicas — what decentralized deployment serves.
    Doubly-stochastic mixing preserves it round to round."""
    return jax.tree.map(lambda t: t.mean(0), state["params"])


def sweep(model="resnet_tiny", rounds=10, n=4000, K=5, seed=0,
          batch_size=32, alpha=0.1, quiet=False):
    """One row per arm on the alpha-Dirichlet task: slot-0 accuracy curve,
    final consensus-mean accuracy, and the per-round wire bill."""
    xtr, ytr = image_like(seed, n=n)
    xte, yte = image_like(seed + 1000, n=1000)
    init_fn, apply_fn = IMAGE_MODELS[model]
    rows = []
    for name, make_agg in ARMS:
        kw = (dict(weighted=True) if make_agg is None
              else dict(aggregator=make_agg()))
        r = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                        K=K, rounds=rounds, T0=1, epsilon=0.03,
                        batch_size=batch_size, seed=seed, engine="fused",
                        partition="dirichlet", dirichlet_alpha=alpha, **kw)
        acc_mean = accuracy(apply_fn, consensus_mean(r["state"]),
                            xte, yte)
        rows.append({
            "arm": name, "alpha": alpha, "rounds": rounds,
            "final_acc_slot0": r["acc"][-1],
            "final_acc_mean": float(acc_mean),
            "curve_slot0": r["acc"],
            "comm_bytes_per_round": r["comm_bytes"],
            "total_comm_bytes": r["total_comm_bytes"],
            "shard_sizes": list(r["shard_sizes"]),
        })
        if not quiet:
            print(f"topology,{model},alpha={alpha},{name},"
                  f"slot0={r['acc'][-1]:.4f},mean={acc_mean:.4f},"
                  f"comm={r['comm_bytes']}", flush=True)
    return rows


def _check_mesh_parity():
    """Sparse pod wire pattern == dense host reference on an 8-pod mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_sim_mesh

    mesh = make_sim_mesh((8,), ("pod",))
    K = 8
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    stacked = {"w": jax.random.normal(ks[0], (K, 4, 16)),
               "b": jax.random.normal(ks[1], (K, 7))}
    specs = {"w": P("pod"), "b": P("pod")}
    sharded = jax.tree.map(
        lambda t, s: jax.device_put(t, NamedSharding(mesh, s)),
        stacked, specs)
    codec = api.ExactF32()

    def maxdiff(a, b):
        return max(float(jnp.abs(jnp.asarray(x, jnp.float32)
                                 - jnp.asarray(y, jnp.float32)).max())
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    for tname in ("ring", "grid2d", "hypercube", "complete"):
        agg = api.GraphGossip(tname)
        W = jnp.asarray(agg.mixing_matrix(0, K))
        mesh_fn = agg._make_mesh_aggregate_fn(codec, mesh, specs, "pod")
        assert mesh_fn is not None, f"{tname}: sparse path not engaged"
        got = jax.jit(mesh_fn)(sharded, W)
        want = agg._make_host_aggregate_fn(codec)(stacked, W)
        d = maxdiff(got, want)
        assert d <= 1e-6, f"{tname}: sparse pod path != dense ({d})"

    d2 = api.D2Gossip("hypercube")
    W = jnp.asarray(d2.mixing_matrix(0, K))
    corr = jax.tree.map(lambda t: 0.01 * jnp.ones(t.shape, jnp.float32),
                        stacked)
    mesh_fn = d2._make_mesh_aggregate_fn(codec, mesh, specs, "pod")
    assert mesh_fn is not None, "d2: sparse path not engaged"
    gm, gc = jax.jit(mesh_fn)(sharded, W, corr)
    wm, wc = d2._make_host_aggregate_fn(codec)(stacked, W, corr)
    assert maxdiff((gm, gc), (wm, wc)) <= 1e-6, "d2 sparse != dense"


def _check_comm_and_matrices():
    """Comm bill O(degree) not O(K); registry matrices doubly stochastic."""
    codec = api.ExactF32()
    for K in (4, 8, 16):
        stacked = {"w": jnp.zeros((K, 64))}
        wire = codec.wire_bytes(stacked)
        assert (api.GraphGossip("ring").comm_bytes(codec, stacked, 0)
                == 2 * wire), "ring bill must be K-independent"
        assert (api.GraphGossip("hypercube").comm_bytes(codec, stacked, 0)
                == 2 * int(np.log2(K)) * wire)
        assert (api.GraphGossip("complete").comm_bytes(codec, stacked, 0)
                == 2 * (K - 1) * wire)
    for name in ("ring", "grid2d", "hypercube", "exponential", "complete"):
        t = topo_mod.get_topology(name)
        for K in (4, 8):
            for r in range(t.period(K)):
                W = t.mixing_matrix(r, K)
                assert np.allclose(W.sum(0), 1.0, atol=1e-6)
                assert np.allclose(W.sum(1), 1.0, atol=1e-6)


def check(quiet=False):
    """CI smoke: structural invariants + a reduced non-IID D² comparison,
    no timings."""
    _check_mesh_parity()
    _check_comm_and_matrices()

    # reduced alpha=0.1 smoke: D² must not lose to plain gossip on the
    # consensus mean — the whole point of carrying the correction
    n, K, rounds = 800, 4, 6
    xtr, ytr = image_like(0, n=n)
    xte, yte = image_like(1000, n=400)
    init_fn, apply_fn = IMAGE_MODELS["vgg_tiny"]
    accs = {}
    for name, agg in (("plain", api.GraphGossip("ring")),
                      ("d2", api.D2Gossip("ring"))):
        r = run_colearn(init_fn, apply_fn, (xtr, ytr), (xte, yte),
                        K=K, rounds=rounds, T0=1, epsilon=0.03,
                        batch_size=16, seed=0, engine="fused",
                        partition="dirichlet", dirichlet_alpha=0.1,
                        aggregator=agg)
        accs[name] = float(accuracy(apply_fn, consensus_mean(r["state"]),
                                    xte, yte))
        assert np.isfinite(accs[name]) and 0 < accs[name] <= 1
        if not quiet:
            print(f"smoke,{name},mean_acc={accs[name]:.4f}", flush=True)
    assert accs["d2"] >= accs["plain"] - 1e-9, accs
    print("topology --check OK: sparse pod paths match the dense host "
          "reference, comm bills scale O(degree), registry matrices "
          "doubly stochastic, and D2 >= plain gossip on the non-IID "
          f"smoke ({accs['d2']:.3f} vs {accs['plain']:.3f})")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--model", default="resnet_tiny")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    if args.check:
        return check(quiet=args.quiet)
    rows = sweep(model=args.model, rounds=args.rounds, quiet=args.quiet)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"task": f"image_like dirichlet alpha=0.1 "
                               f"{args.model}",
                       "rows": rows}, f, indent=1)
        print(f"wrote {args.out}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
