"""ModelBank: versioned publication of trained models into serving.

The bridge between the learner and a live serving path: after each
communication round the learner *publishes* its shared model (or, for the
paper's Table 2 ensemble baseline, the whole per-participant stack) into
the bank; serving loops *poll* the bank and hot-swap to the newest
version between batches. Publication is a single reference assignment of
a fully-built immutable snapshot, so a reader never observes a
half-updated model; versions are strictly monotonic.

Staleness is first-class metadata: every snapshot records the round and
global epoch it was trained through and whether that round synced, and
``staleness(state_round)`` reports how many rounds the serving copy lags
the learner. Under a divergence-gated sync policy the default
``publish_on="synced"`` keeps the bank on the last *synced* shared model
through quiet rounds — the participant slots hold divergent local models
then, which are NOT the shared model the serving contract promises
(``publish_on="always"`` is the ensemble-baseline mode, where the local
replicas are exactly what gets served).

Persistence rides ``repro.checkpoint.io``: ``dir=`` makes every publish
also write ``v<version>.npz`` + a json meta, and :meth:`ModelBank.load`
restores the newest version into a fresh bank (e.g. a serving process
that restarts independently of training).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import time
from typing import Any

import jax

from repro.checkpoint.io import restore_pytree, save_pytree
from repro.core import ensemble as ensemble_mod

#: publication modes: "shared" = the synced shared model (one replica);
#: "ensemble" = the whole (K,)-stacked participant params, served through
#: ``repro.core.ensemble`` output averaging (paper Table 2 baseline)
MODES = ("shared", "ensemble")


@dataclasses.dataclass(frozen=True)
class ModelSnapshot:
    """One published model: immutable params + staleness metadata."""

    version: int
    params: Any
    round: int              # rounds completed when published
    global_epoch: int
    synced: bool            # did the publishing round communicate
    mode: str               # "shared" | "ensemble"
    published_at: float     # host wall-clock (time.time())


class ModelBank:
    """Monotonic-versioned model publication with atomic swap."""

    def __init__(self, mode: str = "shared", publish_on: str = "synced",
                 dir: str | None = None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; want one of {MODES}")
        if publish_on not in ("synced", "always"):
            raise ValueError(f"publish_on must be 'synced' or 'always', "
                             f"got {publish_on!r}")
        self.mode = mode
        self.publish_on = publish_on
        self.dir = dir
        self._current: ModelSnapshot | None = None

    # -- write side ---------------------------------------------------------
    def publish(self, params, *, round_i: int, global_epoch: int = 0,
                synced: bool = True) -> ModelSnapshot:
        """Publish ``params`` as the next version (atomic swap)."""
        snap = ModelSnapshot(
            version=self.version + 1, params=params, round=round_i,
            global_epoch=global_epoch, synced=synced, mode=self.mode,
            published_at=time.time())
        if self.dir is not None:
            self._persist(snap)
        # the swap: one reference assignment of the fully-built snapshot
        self._current = snap
        return snap

    def publish_from(self, learner, state) -> ModelSnapshot | None:
        """The ``CoLearner.run_round(on_round_end=...)`` hook: snapshot
        the learner's round-``state`` into the bank.

        Returns the new snapshot, or None when the round was quiet and
        ``publish_on="synced"`` (the bank keeps serving the stale — but
        still *shared* — previous version)."""
        log = state["log"][-1] if state["log"] else None
        synced = log.synced if log is not None else True
        if self.publish_on == "synced" and not synced:
            return None
        params = (state["params"] if self.mode == "ensemble"
                  else learner.shared_model(state))
        return self.publish(params, round_i=state["round"],
                            global_epoch=state["global_epoch"],
                            synced=synced)

    # -- read side ----------------------------------------------------------
    def current(self) -> ModelSnapshot | None:
        return self._current

    @property
    def version(self) -> int:
        return 0 if self._current is None else self._current.version

    def staleness(self, state_round: int) -> int:
        """Rounds the serving copy lags the learner (inf before the first
        publish)."""
        if self._current is None:
            return int(1e9)
        return max(0, int(state_round) - self._current.round)

    # -- serving-path inference ---------------------------------------------
    def predict_logits(self, predict_fn, batch):
        """Log-probabilities of the CURRENT snapshot for ``batch``.

        ``mode="ensemble"`` routes through the paper's output-averaging
        baseline (``repro.core.ensemble.ensemble_logits`` over the stacked
        params; K=1 reduces to plain log-softmax); ``mode="shared"`` is
        the plain single-model forward. Either way the result is a
        log-prob tensor, so the Table 2 comparison runs through ONE
        serving surface."""
        snap = self._current
        if snap is None:
            raise RuntimeError("ModelBank is empty — nothing published yet")
        if snap.mode == "ensemble":
            return ensemble_mod.ensemble_logits(predict_fn, snap.params,
                                                batch)
        return jax.nn.log_softmax(
            predict_fn(snap.params, batch).astype("float32"), -1)

    def accuracy(self, predict_fn, batch, labels):
        """Serving-path accuracy of the current snapshot (either mode)."""
        import jax.numpy as jnp
        lp = self.predict_logits(predict_fn, batch)
        return jnp.mean((jnp.argmax(lp, -1) == labels).astype(jnp.float32))

    # -- persistence (checkpoint/io-backed) ----------------------------------
    def _persist(self, snap: ModelSnapshot):
        os.makedirs(self.dir, exist_ok=True)
        save_pytree(os.path.join(self.dir, f"v{snap.version}.npz"),
                    snap.params)
        meta = {"version": snap.version, "round": snap.round,
                "global_epoch": snap.global_epoch, "synced": snap.synced,
                "mode": snap.mode, "published_at": snap.published_at}
        with open(os.path.join(self.dir,
                               f"v{snap.version}.meta.json"), "w") as f:
            json.dump(meta, f)

    @classmethod
    def load(cls, dir: str, like, publish_on: str = "synced") -> "ModelBank":
        """Restore the newest persisted version into a fresh bank.

        ``like`` is a params pytree of the published structure (shared
        model or stacked, matching the persisted mode)."""
        metas = sorted(glob.glob(os.path.join(dir, "v*.meta.json")))
        if not metas:
            raise FileNotFoundError(f"no published versions under {dir}")
        with open(max(metas, key=lambda p: int(
                os.path.basename(p)[1:].split(".")[0]))) as f:
            meta = json.load(f)
        bank = cls(mode=meta["mode"], publish_on=publish_on, dir=dir)
        params = restore_pytree(
            os.path.join(dir, f"v{meta['version']}.npz"), like)
        bank._current = ModelSnapshot(
            version=meta["version"], params=params, round=meta["round"],
            global_epoch=meta["global_epoch"], synced=meta["synced"],
            mode=meta["mode"], published_at=meta["published_at"])
        return bank
