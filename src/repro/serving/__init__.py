"""Continuous-operation serving subsystem: train -> publish -> hot-swap.

``ModelBank`` (``repro.serving.bank``) versions each round's shared model
(or the stacked ensemble baseline) with staleness metadata and an atomic
swap; ``ServeLoop`` (``repro.serving.loop``) is the reusable batched
KV-cache decode loop that polls the bank and hot-swaps params into its
one compiled decode step between rounds. ``launch/continuous.py`` closes
the loop end-to-end; ``benchmarks/serving.py`` measures it.
"""
from repro.serving.bank import MODES, ModelBank, ModelSnapshot
from repro.serving.loop import ServeLoop, serve_rounds_stats

__all__ = ["MODES", "ModelBank", "ModelSnapshot", "ServeLoop",
           "serve_rounds_stats"]
