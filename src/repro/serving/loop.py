"""ServeLoop: reusable batched KV-cache decode with between-round hot-swap.

The serving half of the continuous-operation loop. One ``ServeLoop`` owns
ONE jitted decode step (compiled against a fixed config / batch / cache
geometry); the model parameters are plain arguments to that step, so
swapping to a newly-published ``ModelBank`` version is a pointer update —
same treedef and shapes mean the next decode reuses the already-compiled
executable (compile count asserted flat across swaps in
``benchmarks/serving.py --check`` and tests/test_serving.py).

Prefill reuses the SAME jitted step, one token at a time with the
position as a traced scalar: the old ``launch/serve.py`` called the
un-jitted ``tr.decode_step`` per prefill token, paying an op-by-op eager
dispatch for every prompt position; here prompt length costs one compiled
call per token and zero extra compiles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.analysis import guards
from repro.models import transformer as tr


def _tree_signature(params):
    """(treedef, leaf shapes+dtypes) — the swap-compatibility contract."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return treedef, tuple((t.shape, jnp.asarray(t).dtype) for t in leaves)


class ServeLoop:
    """Batched greedy decode against a KV cache, hot-swappable params.

    ``generate(prompts, new_tokens)`` validates that the prompt and the
    requested continuation fit the cache (``max_seq``) before touching the
    device, prefills through the jitted step, then decodes greedily.
    ``poll(bank)`` swaps in the bank's current version when it is newer
    than what is being served; ``swap(params, version)`` is the low-level
    entry (used by tests and by restarts restoring from a persisted bank).
    """

    def __init__(self, cfg, params, *, batch: int, max_seq: int,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.batch = int(batch)
        self.max_seq = int(max_seq)
        self.dtype = dtype
        self.params = params
        self.version = 0          # bank version currently served (0 = init)
        self._signature = _tree_signature(params)
        # the decode step lives behind the shared no_retrace guard: a
        # swap (or prompt) that would recompile raises RetraceError at
        # the offending call instead of silently serving 10x slower
        self._step = guards.no_retrace(
            jax.jit(lambda p, c, t, i: tr.decode_step(p, cfg, c, t, i)),
            limit=1, what="ServeLoop decode step")
        #: lifetime counters for the benchmark's tokens/s-during-training
        self.tokens_served = 0
        self.batches_served = 0

    # -- hot swap ------------------------------------------------------------
    def compile_count(self) -> int:
        """Distinct compiled decode executables (must stay 1 across
        swaps — params are traced arguments, never constants)."""
        return self._step.compile_count()

    def swap(self, params, version: int) -> None:
        """Atomically point the loop at new params (same treedef/shapes)."""
        sig = _tree_signature(params)
        if sig[0] != self._signature[0] or sig[1] != self._signature[1]:
            raise ValueError(
                "hot-swap params have a different treedef/shapes than the "
                "compiled decode step was built for — that swap would "
                "recompile; publish a matching model or build a new loop")
        self.params = params
        self.version = int(version)

    def poll(self, bank) -> bool:
        """Swap to the bank's current version if newer. Returns whether a
        swap happened. Ensemble-mode snapshots are not decodable (K
        stacked replicas, one cache) — the bank's own ``predict_logits``
        serves those; this loop rejects them loudly."""
        snap = bank.current()
        if snap is None:
            return False
        if snap.mode != "shared":
            raise ValueError(
                f"ServeLoop decodes a single shared model; bank publishes "
                f"mode={snap.mode!r} (use ModelBank.predict_logits for the "
                "ensemble serving path)")
        if snap.version <= self.version:
            return False
        self.swap(snap.params, snap.version)
        return True

    # -- decode --------------------------------------------------------------
    def prefill(self, prompts):
        """Prefill a (B, P) prompt batch through the jitted step; returns
        (last logits, cache). One compiled executable, P calls."""
        cache = tr.init_cache(self.cfg, prompts.shape[0], self.max_seq,
                              self.dtype)
        logits = None
        for t in range(prompts.shape[1]):
            logits, cache = self._step(self.params, cache,
                                       prompts[:, t:t + 1], jnp.int32(t))
        return logits, cache

    def generate(self, prompts, new_tokens: int):
        """Greedy-decode ``new_tokens`` continuations for a prompt batch.

        Returns ``(tokens (B, new_tokens), stats)`` where stats carries
        prefill/decode wall seconds, tokens/s, and the served version.
        """
        prompts = jnp.asarray(prompts)
        B, P = prompts.shape
        if B != self.batch:
            raise ValueError(f"prompt batch {B} != loop batch {self.batch}")
        if P + new_tokens > self.max_seq:
            raise ValueError(
                f"prompt_len {P} + new_tokens {new_tokens} overruns the "
                f"KV cache (max_seq={self.max_seq}) — decode would index "
                "past the cache")
        t0 = time.perf_counter()
        logits, cache = self.prefill(prompts)
        t1 = time.perf_counter()
        out = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(new_tokens):
            out.append(tok)
            logits, cache = self._step(self.params, cache, tok,
                                       jnp.int32(P + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        gen = jnp.concatenate(out, axis=1)
        gen.block_until_ready()
        t2 = time.perf_counter()
        self.tokens_served += B * new_tokens
        self.batches_served += 1
        decode_s = max(t2 - t1, 1e-9)
        stats = {"prefill_s": t1 - t0, "decode_s": t2 - t1,
                 "tokens": B * new_tokens,
                 "tokens_per_s": B * new_tokens / decode_s,
                 "version": self.version,
                 "compile_count": self.compile_count()}
        return gen, stats


def serve_rounds_stats(per_round):
    """Aggregate per-round ``generate`` stats dicts into the benchmark's
    summary row (total tokens, mean tokens/s, served versions)."""
    toks = sum(s["tokens"] for s in per_round)
    secs = sum(s["decode_s"] for s in per_round)
    return {"rounds_served": len(per_round),
            "total_tokens": toks,
            "tokens_per_s_mean": toks / max(secs, 1e-9),
            "versions": [s["version"] for s in per_round]}
