"""Hand-built optimizers (optax is not available offline).

API mirrors the optax triple: ``init(params) -> state``,
``update(grads, state, params, lr) -> (updates, state)``; ``apply(params,
updates)`` adds them. The learning rate is passed per call because the
paper's CLR schedule changes it every local epoch (Eq. 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(t.astype(jnp.float32) ** 2)
                        for t in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


class SGD:
    """Plain SGD — the paper's local optimizer ("localSGD", Algorithm 1)."""

    def init(self, params):
        return ()

    def update(self, grads, state, params, lr):
        return _tmap(lambda g: -lr * g.astype(jnp.float32), grads), state


class Momentum:
    def __init__(self, beta=0.9):
        self.beta = beta

    def init(self, params):
        return _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(self, grads, state, params, lr):
        new_m = _tmap(lambda m, g: self.beta * m + g.astype(jnp.float32),
                      state, grads)
        return _tmap(lambda m: -lr * m, new_m), new_m


class AdamW:
    def __init__(self, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
        self.b1, self.b2, self.eps, self.wd = b1, b2, eps, weight_decay

    def init(self, params):
        z = _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr):
        t = state["t"] + 1
        m = _tmap(lambda m, g: self.b1 * m + (1 - self.b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v, g: self.b2 * v
                  + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        bc1 = 1 - self.b1 ** t.astype(jnp.float32)
        bc2 = 1 - self.b2 ** t.astype(jnp.float32)
        upd = _tmap(
            lambda m, v, p: -lr * ((m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
                                   + self.wd * p.astype(jnp.float32)),
            m, v, params)
        return upd, {"m": m, "v": v, "t": t}


def get_optimizer(name: str, *, momentum=0.9, weight_decay=0.0):
    if name == "sgd":
        return SGD()
    if name == "momentum":
        return Momentum(momentum)
    if name == "adamw":
        return AdamW(weight_decay=weight_decay)
    raise KeyError(name)


def apply_updates(params, updates):
    return _tmap(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                 params, updates)
