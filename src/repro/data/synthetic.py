"""Deterministic synthetic datasets (the offline stand-ins for CIFAR-10 /
Toxic-comments / Google-commands — see DESIGN.md §1: the paper's *systems*
claims are validated exactly; accuracy-parity claims are validated on these
teacher-generated tasks of the same three modalities).

All generators are pure functions of a seed.
"""
from __future__ import annotations

import numpy as np


def _teacher_warp(rng, x, width=64, depth=2):
    """Fixed random MLP warp so classes are not linearly separable."""
    d = x.shape[-1]
    h = x
    for _ in range(depth):
        w = rng.normal(size=(h.shape[-1], width)) / np.sqrt(h.shape[-1])
        h = np.tanh(h @ w)
    w = rng.normal(size=(width, d)) / np.sqrt(width)
    return h @ w + 0.1 * x


def image_like(seed=0, n=6000, n_classes=10, hw=16, channels=3, noise=1.0):
    """CIFAR-10 analog: smooth class templates + pixel noise. Returns
    (x:(n,hw,hw,c) f32, y:(n,) i32). Templates are low-frequency (conv-net
    learnable); noise keeps the task non-trivial (~70-90% achievable)."""
    rng = np.random.default_rng(seed)
    # class templates come from a FIXED rng: every seed (train/test split)
    # must share the same classes — only sampling noise varies with `seed`
    rng_cls = np.random.default_rng(0xC1A55)
    y = rng.integers(0, n_classes, size=n)
    # low-frequency templates: random coarse 4x4 patterns upsampled
    coarse = 2.0 * rng_cls.normal(size=(n_classes, 4, 4, channels))
    templates = coarse.repeat(hw // 4, axis=1).repeat(hw // 4, axis=2)
    x = templates[y] + noise * rng.normal(size=(n, hw, hw, channels))
    x = x / x.std()                      # normalized inputs (stable SGD)
    return x.astype(np.float32), y.astype(np.int32)


def text_like(seed=0, n=6000, n_classes=6, seq_len=32, vocab=128):
    """Toxic-comments analog: class defined by planted class-specific bigrams
    in an otherwise random token stream. Returns (x:(n,S) i32, y:(n,) i32)."""
    rng = np.random.default_rng(seed + 1)
    # class-reserved marker tokens (disjoint from the noise-token range)
    markers = np.arange(n_classes * 3).reshape(n_classes, 3) % vocab
    y = rng.integers(0, n_classes, size=n)
    x = rng.integers(n_classes * 3, vocab, size=(n, seq_len))
    for i in range(n):
        pos = rng.integers(0, seq_len - 3)
        x[i, pos:pos + 3] = markers[y[i]]
    return x.astype(np.int32), y.astype(np.int32)


def audio_like(seed=0, n=6000, n_classes=10, frames=24, mels=32):
    """Speech-commands analog: class-dependent spectro-temporal patterns.
    Returns (x:(n,frames,mels) f32, y:(n,) i32)."""
    rng = np.random.default_rng(seed + 2)
    y = rng.integers(0, n_classes, size=n)
    t = np.linspace(0, 1, frames)[None, :, None]
    m = np.linspace(0, 1, mels)[None, None, :]
    f0 = (1 + y[:, None, None]) * 2.0
    chirp = np.sin(2 * np.pi * f0 * t * (1 + m))           # class chirp
    x = chirp + 0.8 * rng.normal(size=(n, frames, mels))
    return x.astype(np.float32), y.astype(np.int32)


def lm_tokens(seed=0, n_tokens=2 ** 16, vocab=256, order=2):
    """Synthetic language: sparse random Markov chain (learnable structure).
    Returns a (n_tokens,) int32 stream."""
    rng = np.random.default_rng(seed + 3)
    n_ctx = vocab ** order if vocab ** order <= 65536 else 65536
    trans = rng.dirichlet(np.full(8, 0.5), size=n_ctx)      # 8 likely nexts
    nexts = rng.integers(0, vocab, size=(n_ctx, 8))
    out = np.empty(n_tokens, np.int32)
    ctx = 0
    for i in range(n_tokens):
        row = ctx % n_ctx
        out[i] = nexts[row, rng.choice(8, p=trans[row])]
        ctx = (ctx * vocab + int(out[i])) % n_ctx
    return out


def lm_examples(seed=0, n=2048, seq_len=64, vocab=256):
    """(tokens:(n,S), labels:(n,S)) next-token pairs from the Markov stream."""
    stream = lm_tokens(seed, n * (seq_len + 1) + 1, vocab)
    xs = np.stack([stream[i * (seq_len + 1):(i + 1) * (seq_len + 1)]
                   for i in range(n)])
    return xs[:, :-1].astype(np.int32), xs[:, 1:].astype(np.int32)
