"""Batching pipeline: per-participant, per-epoch shuffled batch stacks.

Produces the (K, n_batches, B, ...) arrays the vmapped participant step
consumes. Host-side numpy; deterministic in (seed, round, epoch).
"""
from __future__ import annotations

import numpy as np


class ParticipantData:
    """Holds K disjoint shards; yields stacked epoch batches."""

    def __init__(self, shards, batch_size: int, seed: int = 0):
        # shards: list of K lists of arrays, all same leading length per k
        self.shards = shards
        self.K = len(shards)
        self.B = batch_size
        self.seed = seed
        n = min(len(s[0]) for s in shards)
        self.n_batches = n // batch_size
        assert self.n_batches > 0, "shard smaller than one batch"

    def epoch_batches(self, round_i: int, epoch_j: int):
        """(K, n_batches, B, ...) tuple of arrays for one local epoch."""
        out = [[] for _ in self.shards[0]]
        for k, shard in enumerate(self.shards):
            rng = np.random.default_rng(
                (self.seed, k, round_i, epoch_j, 0xC0))
            perm = rng.permutation(len(shard[0]))[: self.n_batches * self.B]
            for a_i, a in enumerate(shard):
                out[a_i].append(a[perm].reshape(
                    self.n_batches, self.B, *a.shape[1:]))
        return tuple(np.stack(x) for x in out)

    def full(self, k=None):
        """All data of participant k (or concatenated) for evaluation."""
        if k is not None:
            return self.shards[k]
        return [np.concatenate([s[i] for s in self.shards])
                for i in range(len(self.shards[0]))]
