"""Batching pipeline: per-participant, per-epoch shuffled batch stacks.

Produces the (K, n_batches, B, ...) arrays the vmapped participant step
consumes. Host-side numpy; deterministic in (seed, round, epoch).

Shards may be *ragged* (unequal lengths — quantity skew, Dirichlet label
skew, or a round-robined remainder). Raggedness is handled with
per-participant batch counts: shard k contributes ``len(shard_k) // B``
real batches per epoch, the stack is padded to the max count ``n_batches``
and :attr:`ParticipantData.batch_mask` marks which ``(k, batch)`` slots are
real. The engines thread that mask through the epoch scan (a masked step is
an identity carry — see ``repro.core.engine``), so no shard is ever clamped
to the global minimum length and no example outside the per-epoch batch
remainder is dropped (the per-epoch shuffle rotates which examples land in
the remainder, so every shard example trains). Padding batches *cycle* the
shard's own permutation — real data, never zeros — so a mask-unaware
consumer degrades to slight oversampling instead of training on garbage.

For equal shards everything reduces bit-for-bit to the classic equal-IID
pipeline: ``ragged`` is False, the mask is all-True, and ``epoch_batches``
returns exactly the arrays it always did.

Elastic membership adds one knob: ``k_max``. Stacked shapes are a
compile-time invariant, so a run that wants standby slots (participants
that may *join* mid-run, see ``repro.core.membership``) must batch for
``K_max`` slots from round 0. ``k_max > len(shards)`` pads the slot list
by cycling the real shards — slot ``K+i`` serves ``shards[i % K]`` — so a
standby slot trains on real data the moment it goes live. The padding
slots are data *views*, not copies, and :meth:`full` still concatenates
each real shard exactly once.
"""
from __future__ import annotations

import numpy as np


class ParticipantData:
    """Holds K disjoint (possibly ragged) shards; yields stacked epoch
    batches plus the validity mask for the padded slots."""

    def __init__(self, shards, batch_size: int, seed: int = 0,
                 k_max=None):
        # shards: list of K lists of arrays, same leading length per k
        #: number of REAL shards (k_max padding slots alias these)
        self.n_shards = len(shards)
        if k_max is not None:
            if k_max < len(shards):
                raise ValueError(
                    f"k_max={k_max} smaller than the {len(shards)} shards")
            shards = list(shards) + [
                shards[i % len(shards)]
                for i in range(k_max - len(shards))]
        self.shards = shards
        self.K = len(shards)
        self.B = batch_size
        self.seed = seed
        #: per-participant example counts (the FedAvg averaging weights)
        self.sizes = tuple(len(s[0]) for s in shards)
        #: per-participant REAL batches per epoch (floor(n_k / B))
        self.batch_counts = tuple(n // batch_size for n in self.sizes)
        if min(self.batch_counts) <= 0:          # survives python -O
            raise ValueError(
                f"shard smaller than one batch: sizes={self.sizes} with "
                f"batch_size={batch_size}")
        self.n_batches = max(self.batch_counts)
        #: True when shards yield unequal batch counts (mask required)
        self.ragged = len(set(self.batch_counts)) > 1

    @property
    def batch_mask(self):
        """(K, n_batches) bool: True where the slot holds one of shard k's
        real per-epoch batches, False on cycled padding slots."""
        return (np.arange(self.n_batches)[None, :]
                < np.asarray(self.batch_counts)[:, None])

    def epoch_batches(self, round_i: int, epoch_j: int):
        """(K, n_batches, B, ...) tuple of arrays for one local epoch.

        Slots beyond shard k's ``batch_counts[k]`` (ragged shards only)
        cycle k's own shuffled examples; pair with :attr:`batch_mask` (the
        engines' identity-carry mask) for exact per-shard epoch semantics.
        """
        out = [[] for _ in self.shards[0]]
        for k, shard in enumerate(self.shards):
            rng = np.random.default_rng(
                (self.seed, k, round_i, epoch_j, 0xC0))
            # np.resize cycles the permutation when a ragged shard needs
            # padding; for n_k >= n_batches*B it is exactly perm[:need]
            perm = np.resize(rng.permutation(len(shard[0])),
                             self.n_batches * self.B)
            for a_i, a in enumerate(shard):
                out[a_i].append(a[perm].reshape(
                    self.n_batches, self.B, *a.shape[1:]))
        return tuple(np.stack(x) for x in out)

    def full(self, k=None):
        """All data of participant k (or concatenated) for evaluation.

        The concatenation covers each REAL shard exactly once — ``k_max``
        padding slots alias real shards and would double-count.
        """
        if k is not None:
            return self.shards[k]
        return [np.concatenate([s[i] for s in self.shards[:self.n_shards]])
                for i in range(len(self.shards[0]))]
