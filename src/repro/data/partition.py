"""K-way disjoint data partitioning (paper: "randomly allocated to 5
participants in an equally distributed manner"). Participants never see
each other's shard — only parameters cross the WAN."""
from __future__ import annotations

import numpy as np


def partition(n: int, K: int, seed: int = 0):
    """Random equal disjoint split. Returns list of K index arrays; drops
    the n % K remainder (paper uses exactly-equal shards)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    per = n // K
    return [perm[k * per:(k + 1) * per] for k in range(K)]


def partition_arrays(arrays, K: int, seed: int = 0):
    """Apply the same disjoint split to every array in a tuple/list."""
    n = len(arrays[0])
    idx = partition(n, K, seed)
    return [[a[i] for a in arrays] for i in idx]
