"""K-way disjoint data partitioning. Participants never see each other's
shard — only parameters cross the WAN.

The paper evaluates the idealized setting ("randomly allocated to 5
participants in an equally distributed manner"), but its central claim is
robustness of model averaging *on different types of data* — so this module
provides the scenario axis as first-class partitioners, each returning K
disjoint index arrays that together cover **every example exactly once**
(property-tested in tests/test_data.py; nothing is silently dropped):

* :func:`partition` — the paper's random split. Equal-IID by default with
  the ``n % K`` remainder distributed round-robin (one extra example to the
  first ``n % K`` shards); ``drop_remainder=True`` restores the exactly-
  equal paper split as a loud opt-in.
* :func:`dirichlet_partition` — label-skew non-IID (the standard federated
  benchmark protocol, cf. FedAvg 1602.05629 / D² 1803.07068): each shard's
  class mixture is drawn from ``Dirichlet(alpha)``; small ``alpha`` gives
  near single-class shards, large ``alpha`` recovers IID.
* :func:`quantity_skew` — unequal shard *sizes* (given as counts or
  fractions), contents IID.

``ParticipantData`` (``repro.data.pipeline``) consumes the resulting ragged
shards with per-participant batch counts + a validity mask, and
``FullAverage(weights=...)`` / ``PartialParticipation`` weight Eq. 2 by the
shard sizes (FedAvg's example-count-weighted generalization).
"""
from __future__ import annotations

import numpy as np


def _assert_exact_cover(idx, n, dropped=0):
    """Every example in exactly one shard (minus the declared drops).

    A plain ``raise`` (not ``assert``) so the no-silent-data-loss guarantee
    survives ``python -O``."""
    all_ids = np.concatenate([np.asarray(i, np.int64) for i in idx]) \
        if idx else np.empty(0, np.int64)
    if len(all_ids) != n - dropped:
        raise ValueError(f"partitioner covered {len(all_ids)} of {n} "
                         f"examples ({dropped} declared drops)")
    if len(np.unique(all_ids)) != len(all_ids):
        raise ValueError("partitioner assigned an example to two shards")


def partition(n: int, K: int, seed: int = 0, *, drop_remainder: bool = False):
    """Random disjoint split into K shards covering all ``n`` examples.

    By default the ``n % K`` remainder is distributed round-robin (the
    first ``n % K`` shards hold one extra example) so no example is ever
    silently dropped. ``drop_remainder=True`` is the paper-faithful
    exactly-equal split — the remainder is *explicitly* discarded.
    Returns a list of K index arrays.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    per, rem = divmod(n, K)
    if drop_remainder:
        out = [perm[k * per:(k + 1) * per] for k in range(K)]
        _assert_exact_cover(out, n, dropped=rem)
        return out
    sizes = [per + (1 if k < rem else 0) for k in range(K)]
    bounds = np.cumsum([0] + sizes)
    out = [perm[bounds[k]:bounds[k + 1]] for k in range(K)]
    _assert_exact_cover(out, n)
    return out


def dirichlet_partition(labels, K: int, alpha: float = 0.5, seed: int = 0,
                        *, min_size: int = 1):
    """Label-skew non-IID split: shard k's class mixture ~ Dirichlet(alpha).

    For every class ``c`` the class's examples are dealt to the K shards in
    proportions drawn from ``Dirichlet(alpha * 1_K)`` (largest-remainder
    rounding, so the class's examples — and hence ALL examples — are covered
    exactly once). ``alpha -> 0`` concentrates each class on few shards;
    ``alpha -> inf`` recovers the IID mixture.

    ``min_size``: after allocation, shards smaller than this are topped up
    deterministically from the largest shards (a tiny-shard guard so a
    downstream batch pipeline always has at least one batch per shard).
    Returns a list of K index arrays.
    """
    labels = np.asarray(labels)
    n = len(labels)
    if not alpha > 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    if K * min_size > n:
        raise ValueError(f"cannot give {K} shards >= {min_size} examples "
                         f"each from n={n}")
    rng = np.random.default_rng(seed)
    shards = [[] for _ in range(K)]
    for c in np.unique(labels):
        ids = np.nonzero(labels == c)[0]
        rng.shuffle(ids)
        p = rng.dirichlet(np.full(K, float(alpha)))
        # largest-remainder rounding: counts sum exactly to len(ids)
        raw = p * len(ids)
        counts = np.floor(raw).astype(np.int64)
        short = len(ids) - int(counts.sum())
        if short:
            counts[np.argsort(raw - counts)[::-1][:short]] += 1
        bounds = np.cumsum(np.concatenate([[0], counts]))
        for k in range(K):
            shards[k].append(ids[bounds[k]:bounds[k + 1]])
    out = [np.concatenate(s) if s else np.empty(0, np.int64) for s in shards]
    # deterministic tiny-shard guard: move examples from the largest shards
    while min(len(s) for s in out) < min_size:
        small = int(np.argmin([len(s) for s in out]))
        big = int(np.argmax([len(s) for s in out]))
        out[small] = np.concatenate([out[small], out[big][-1:]])
        out[big] = out[big][:-1]
    out = [rng.permutation(s) for s in out]
    _assert_exact_cover(out, n)
    return out


def quantity_skew(n: int, sizes, seed: int = 0):
    """Unequal-size IID split: shard k gets ``sizes[k]`` examples.

    ``sizes`` is a length-K sequence of absolute counts (summing to ``n``)
    or of fractions (summing to ~1; converted with largest-remainder
    rounding so the counts sum exactly to ``n``). Every shard must end up
    non-empty. Returns a list of K index arrays.
    """
    sizes = np.asarray(sizes, np.float64)
    if sizes.ndim != 1 or len(sizes) == 0:
        raise ValueError("sizes must be a non-empty 1-D sequence")
    if (sizes < 0).any() or not np.isfinite(sizes).all():
        raise ValueError(f"sizes must be finite and >= 0; got {sizes}")
    if np.isclose(sizes.sum(), 1.0) and (sizes <= 1.0).all():
        raw = sizes / sizes.sum() * n
        counts = np.floor(raw).astype(np.int64)
        short = n - int(counts.sum())
        if short:
            counts[np.argsort(raw - counts)[::-1][:short]] += 1
    else:
        counts = sizes.astype(np.int64)
        if (counts != sizes).any():
            raise ValueError(
                f"absolute sizes must be integers; got {sizes}")
        if counts.sum() != n:
            raise ValueError(
                f"sizes sum to {counts.sum()}, expected n={n}")
    if (counts == 0).any():
        raise ValueError(f"every shard must be non-empty; counts={counts}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    bounds = np.cumsum(np.concatenate([[0], counts]))
    out = [perm[bounds[k]:bounds[k + 1]] for k in range(len(counts))]
    _assert_exact_cover(out, n)
    return out


def shard_by_indices(arrays, idx):
    """Apply precomputed shard index arrays to every array of a dataset:
    -> list (per shard) of lists (per array)."""
    return [[a[i] for a in arrays] for i in idx]


def partition_arrays(arrays, K: int, seed: int = 0, *,
                     drop_remainder: bool = False):
    """Random :func:`partition` applied to every array in a tuple/list."""
    n = len(arrays[0])
    return shard_by_indices(arrays, partition(n, K, seed,
                                              drop_remainder=drop_remainder))


def scenario_indices(n: int, K: int, seed: int = 0, *, scenario="iid",
                     labels=None, dirichlet_alpha: float = 0.5, sizes=None,
                     min_size: int = 1, drop_remainder: bool = False):
    """The ONE named-scenario dispatcher shared by every driver
    (``launch/train.py``, ``benchmarks/harness.py``): "iid" |
    "dirichlet" (requires ``labels``) | "sizes" (requires ``sizes``) ->
    K disjoint index arrays from the matching partitioner."""
    if scenario == "iid":
        return partition(n, K, seed, drop_remainder=drop_remainder)
    if scenario == "dirichlet":
        if labels is None:
            raise ValueError("the dirichlet scenario requires labels")
        return dirichlet_partition(labels, K, dirichlet_alpha, seed,
                                   min_size=min_size)
    if scenario == "sizes":
        if sizes is None:
            raise ValueError("the sizes scenario requires sizes")
        return quantity_skew(n, sizes, seed)
    raise ValueError(f"unknown partition scenario {scenario!r}")
