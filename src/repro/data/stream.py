"""Streaming non-stationary shards: the continuous-operation data layer.

The paper's data centers keep *producing* data while training runs; the
static ``ParticipantData`` stack models a frozen snapshot of that stream.
``ShardStream`` models the stream itself: ``snapshot(round)`` yields a
fresh per-round ``ParticipantData`` over the (possibly drifted) corpus, so
every communication round trains on that round's data instead of round 0's.

Concept drift is a first-class scenario axis (like partitioners were for
heterogeneity): a ``DriftSchedule`` decides HOW the stream moves, as a
pure function of ``(seed, round)`` — two streams built from the same
arguments replay bit-identical histories, which is what makes
resume-from-checkpoint exact (the round index *is* the stream position).

* :class:`NoDrift` — the static stream. ``is_static`` keeps the stream on
  the exact frozen-stack code path: ``snapshot(r)`` returns the ONE
  round-0 ``ParticipantData`` for every round, so a no-drift stream is
  bit-for-bit the classic pipeline (asserted in tests/test_serving.py).
* :class:`CovariateDrift` — gradual input-distribution rotation. Float
  inputs are rotated in fixed random feature 2-planes by an angle growing
  ``rate`` per round (an exact orthogonal transform — labels untouched);
  integer token inputs swap a growing fraction of fixed random vocab
  pairs. Round 0 is the identity.
* :class:`LabelShift` — per-round re-skew of WHICH shard sees which
  labels: the class preference of each shard rotates with the round
  (``rate`` revolutions per round), and examples are re-dealt into
  fixed-size shards by circular class-to-shard affinity. Contents are
  untouched; only the assignment drifts. Exact coverage and the round-0
  shard sizes are preserved by construction.
* :class:`AbruptDrift` — a task switch at ``at_round``: from that round
  on, a ``severity`` fraction of the label space is cyclically relabeled
  (y -> roll(y)); before it, the stream is the static one. The classic
  recovery scenario for divergence-triggered re-synchronization.

Every snapshot re-partitions/re-transforms on the host, but the *shapes*
``(K, n_batches, B, ...)`` are a round-0 invariant (guarded in
:meth:`ShardStream.snapshot`): new shard contents ride into the unchanged
round executables as traced arguments, so a drifting stream never
recompiles (``benchmarks/round_latency.py --check-retrace`` scenario 8).
"""
from __future__ import annotations

import numpy as np

from repro.data import partition as part_mod
from repro.data.pipeline import ParticipantData


# ---------------------------------------------------------------------------
# Drift schedules
# ---------------------------------------------------------------------------
class DriftSchedule:
    """How the stream moves. Pure in ``(seed, round)``; stateless."""

    name = "drift"
    #: True => the stream is frozen and ShardStream must stay bit-for-bit
    #: on the static-stack code path (one snapshot, reused every round)
    is_static = False
    #: True => the schedule re-deals examples to shards per round
    #: (assignment drift); False => the round-0 assignment is reused
    reassigns = False

    def transform(self, x, y, round_i, seed):
        """Content drift: corpus ``(x, y)`` as seen at ``round_i``."""
        return x, y

    def assign(self, labels, sizes, K, round_i, seed):
        """Assignment drift: K index arrays of exactly ``sizes`` lengths
        covering every example once (only called when ``reassigns``)."""
        raise NotImplementedError


class NoDrift(DriftSchedule):
    """The frozen stream (the pre-stream pipeline, bit-for-bit)."""

    name = "none"
    is_static = True


class CovariateDrift(DriftSchedule):
    """Gradual input-distribution shift, ``rate`` radians (float inputs)
    or vocab-pair-fraction (int inputs) per round. Labels untouched."""

    name = "covariate"

    def __init__(self, rate: float = 0.1):
        if not rate >= 0:
            raise ValueError(f"rate must be >= 0, got {rate}")
        self.rate = float(rate)

    def transform(self, x, y, round_i, seed):
        if round_i == 0 or self.rate == 0:
            return x, y
        rng = np.random.default_rng((seed, 0xC0D1))  # round-INdependent
        if np.issubdtype(x.dtype, np.floating):
            # rotate fixed random feature 2-planes by theta = rate * round:
            # an exact orthogonal transform of the input space, smoothly
            # leaving the training distribution as rounds advance
            theta = self.rate * round_i
            flat = x.reshape(len(x), -1)
            d = flat.shape[1]
            perm = rng.permutation(d)
            a, b = perm[: d // 2], perm[d // 2: 2 * (d // 2)]
            out = flat.copy()
            ca, sa = np.cos(theta), np.sin(theta)
            out[:, a] = ca * flat[:, a] - sa * flat[:, b]
            out[:, b] = sa * flat[:, a] + ca * flat[:, b]
            return out.reshape(x.shape).astype(x.dtype), y
        # integer tokens: swap a growing fraction of fixed random vocab
        # pairs (identity at round 0, full pair swap at rate*round >= 1)
        vocab = int(x.max()) + 1
        pairs = rng.permutation(vocab)
        n_pairs = vocab // 2
        n_swap = min(n_pairs, int(self.rate * round_i * n_pairs))
        if n_swap == 0:
            return x, y
        lut = np.arange(vocab)
        a, b = pairs[:n_swap], pairs[n_pairs:n_pairs + n_swap]
        lut[a], lut[b] = b, a
        return lut[x].astype(x.dtype), y


class LabelShift(DriftSchedule):
    """Per-round re-skew of the shard<-label assignment: shard k's
    preferred classes rotate with the round. Contents untouched."""

    name = "label_shift"
    reassigns = True

    def __init__(self, rate: float = 0.1, temperature: float = 0.0):
        if not rate >= 0:
            raise ValueError(f"rate must be >= 0, got {rate}")
        self.rate = float(rate)
        #: optional angular jitter per (seed, round) draw — 0 keeps the
        #: rotation purely deterministic geometry
        self.temperature = float(temperature)

    def assign(self, labels, sizes, K, round_i, seed):
        labels = np.asarray(labels)
        n = len(labels)
        classes, inv = np.unique(labels, return_inverse=True)
        C = len(classes)
        rng = np.random.default_rng((seed, round_i, 0x5817))
        # class c sits at angle 2*pi*c/C; shard k's preference center
        # rotates by `rate` revolutions per round
        class_angle = 2 * np.pi * inv / C
        out = []
        remaining = np.ones(n, bool)
        order = rng.permutation(n)  # deterministic tie-break within class
        for k in range(K):
            center = 2 * np.pi * (k / K + self.rate * round_i)
            if self.temperature:
                center += self.temperature * rng.normal()
            # circular distance of every example's class to the center
            d = np.angle(np.exp(1j * (class_angle - center)))
            score = np.abs(d)[order] + np.where(remaining[order], 0, np.inf)
            take = order[np.argsort(score, kind="stable")[: sizes[k]]]
            remaining[take] = False
            out.append(take)
        part_mod._assert_exact_cover(out, n)
        return out


class AbruptDrift(DriftSchedule):
    """Task switch at ``at_round``: a ``severity`` fraction of the label
    space is cyclically relabeled from that round on."""

    name = "abrupt"

    def __init__(self, at_round: int = 3, severity: float = 1.0):
        if at_round < 0:
            raise ValueError(f"at_round must be >= 0, got {at_round}")
        if not 0 <= severity <= 1:
            raise ValueError(f"severity must be in [0, 1], got {severity}")
        self.at_round = int(at_round)
        self.severity = float(severity)

    def transform(self, x, y, round_i, seed):
        if round_i < self.at_round or self.severity == 0:
            return x, y
        classes = np.unique(y)
        n_moved = int(round(self.severity * len(classes)))
        if n_moved < 2:
            return x, y
        # cycle the first `n_moved` classes (a full cycle has no fixed
        # point: every affected class maps to a different one)
        moved = classes[:n_moved]
        lut = np.arange(int(classes.max()) + 1)
        lut[moved] = np.roll(moved, -1)
        return x, lut[y].astype(y.dtype)


#: drift registry — the scenario axis, like partitioners / churn schedules
DRIFTS = {"none": NoDrift, "covariate": CovariateDrift,
          "label_shift": LabelShift, "abrupt": AbruptDrift}


def get_drift(spec=None, **kw) -> DriftSchedule:
    """None -> NoDrift(); a name -> ``DRIFTS[name](**kw)``; an object (any
    DriftSchedule-shaped instance) passes through."""
    if spec is None:
        return NoDrift()
    if isinstance(spec, str):
        if spec not in DRIFTS:
            raise ValueError(f"unknown drift {spec!r}; "
                             f"registered: {sorted(DRIFTS)}")
        return DRIFTS[spec](**kw)
    if kw:
        raise ValueError("drift kwargs only apply to registry names")
    return spec


# ---------------------------------------------------------------------------
# The stream
# ---------------------------------------------------------------------------
class ShardStream:
    """Per-round ``ParticipantData`` snapshots over a drifting corpus.

    Mirrors the ``ParticipantData`` surface (``sizes`` / ``batch_counts``
    / ``batch_mask`` / ``ragged`` / ``epoch_batches(round, epoch)``), so
    every consumer of the static stack (``CoLearner.run_round``'s
    ``epoch_batches_fn``, the harness, ``launch/train.py``) can take a
    stream instead without touching the engines. Shapes are a round-0
    invariant; contents are whatever the drift schedule says round ``r``
    looks like.

    ``partition_labels``: the labels the (re-)partitioners skew over.
    Defaults to ``y`` when 1-D (classification) else the coarse
    first-target-token proxy ``y[:, 0] % 10`` (the ``launch/train.py``
    convention for LM corpora).
    """

    def __init__(self, train, K: int, batch_size: int, seed: int = 0, *,
                 drift=None, partition: str = "iid", dirichlet_alpha=1.0,
                 sizes=None, k_max=None, drop_remainder: bool = False,
                 partition_labels=None):
        self.arrays = [np.asarray(a) for a in train]
        self.K = K
        self.B = batch_size
        self.seed = seed
        self.drift = get_drift(drift)
        self.k_max = k_max
        y = self.arrays[-1]
        if partition_labels is not None:
            self._labels = np.asarray(partition_labels)
        else:
            self._labels = y if y.ndim == 1 else y[:, 0] % 10
        n = len(self.arrays[0])
        #: the round-0 assignment, reused every round unless the drift
        #: schedule re-deals (exact coverage asserted by the partitioner)
        self._base_idx = part_mod.scenario_indices(
            n, K, seed, scenario=partition, labels=self._labels,
            dirichlet_alpha=dirichlet_alpha, sizes=sizes,
            min_size=batch_size, drop_remainder=drop_remainder)
        self._base_sizes = tuple(len(i) for i in self._base_idx)
        self._cache = (-1, None)
        base = self.snapshot(0)
        # delegate the static-shape surface (a compile-time invariant)
        self.sizes = base.sizes
        self.batch_counts = base.batch_counts
        self.n_batches = base.n_batches
        self.ragged = base.ragged
        self.n_shards = base.n_shards

    @property
    def batch_mask(self):
        return self.snapshot(0).batch_mask

    def snapshot(self, round_i: int) -> ParticipantData:
        """The stream as staged for round ``round_i``. Pure in
        ``(constructor args, round_i)``; consecutive calls are cached."""
        if self.drift.is_static:
            round_i = 0                      # ONE snapshot, every round
        if self._cache[0] == round_i:
            return self._cache[1]
        x, y = self.drift.transform(self.arrays[0], self.arrays[-1],
                                    round_i, self.seed)
        arrays = [x, *self.arrays[1:-1], y]
        if self.drift.reassigns and round_i > 0:
            idx = self.drift.assign(self._labels, self._base_sizes, self.K,
                                    round_i, self.seed)
        else:
            idx = self._base_idx
        pd = ParticipantData(part_mod.shard_by_indices(arrays, idx),
                             self.B, self.seed, k_max=self.k_max)
        if hasattr(self, "sizes") and (
                pd.sizes != self.sizes
                or pd.batch_counts != self.batch_counts):
            raise ValueError(
                f"drift {self.drift.name!r} changed shard shapes at "
                f"round {round_i}: sizes {pd.sizes} != {self.sizes} — "
                "shapes are a compile-time invariant of the stream")
        self._cache = (round_i, pd)
        return pd

    def epoch_batches(self, round_i: int, epoch_j: int):
        """(K, n_batches, B, ...) arrays for one local epoch of the
        round's snapshot — the drop-in ``ParticipantData`` signature."""
        return self.snapshot(round_i).epoch_batches(round_i, epoch_j)

    def transform_test(self, test, round_i: int):
        """The held-out arrays as the round-``round_i`` distribution sees
        them (content drift only — assignment drift never moves the global
        distribution). The honest eval set for round ``round_i``."""
        x, y = self.drift.transform(np.asarray(test[0]), np.asarray(test[-1]),
                                    round_i, self.seed)
        return (x, *[np.asarray(a) for a in test[1:-1]], y)

    def full(self, k=None, round_i: int = 0):
        return self.snapshot(round_i).full(k)
