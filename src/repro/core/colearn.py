"""Algorithm 1 — the co-learning protocol.

The global-server logic (round state, Eq. 4 T_i control, failure restarts)
is plain Python; the heavy steps (K-participant local SGD epochs, Eq. 2
averaging) are jitted JAX. The same `CoLearner` drives both the simulation
path (K participants vmapped on one host — used by every paper-claims
experiment) and the production path (K = pods, `spmd_axis_name='pod'`).

Two round engines sit behind ``CoLearner(engine=...)``:

  * ``"python"`` — the reference path: a host loop dispatching one jitted
    epoch at a time, host-side Eq. 3 learning rates and Eq. 4 metric.
  * ``"fused"``  — ``repro.core.engine``: the whole round (T_i-epoch scan
    with the CLR computed traced in-graph, Eq. 2 averaging, on-device
    Eq. 4 relative_change) is one donated XLA executable with a single
    host sync; rounds longer than ``fused_chunk`` epochs chain chunk
    executables to bound staged-batch memory (still one final sync).
    Same state transitions and RoundLog; equivalence is asserted in
    tests/test_engine.py.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import averaging, compression, engine as engine_mod
from repro.core.schedule import EpochController, relative_change, round_lr
from repro.optim.optimizers import get_optimizer


@dataclass
class RoundLog:
    round: int
    T: int
    lr_first: float
    lr_last: float
    rel_change: float
    local_losses: list
    comm_bytes: int


@dataclass
class CoLearner:
    """K-participant co-learning driver.

    loss_fn(params, batch) -> (loss, metrics) for ONE participant.
    data: per-participant iterables of epochs; see ``run_round``.

    compress selects the beyond-paper int8 upload emulation for Eq. 2:
      * None       — exact f32 averaging (the paper-faithful default);
      * "leafwise" — per-leaf quantize-roundtrip then average (reference
        wire path; leaves smaller than ``compress_block`` bypass the codec);
      * "fused"    — the flat-buffer wire codec: one contiguous buffer, one
        quantize->average->dequantize kernel pass, every leaf on the wire
        format (``core.flatbuf`` + ``kernels.comm``).
    ``compress_impl`` picks the kernel backend ("ref" jnp oracle on CPU,
    "pallas" on TPU); ``compress_fn`` remains the low-level escape hatch
    (mutually exclusive with compress="fused").
    """
    cfg: Any                                  # CoLearnConfig
    loss_fn: Callable
    optimizer_name: str = "sgd"
    compress_fn: Optional[Callable] = None    # stacked params -> stacked params
    engine: str = "python"                    # python (reference) | fused
    fused_chunk: int = 32                     # max epochs staged on device
    compress: Optional[str] = None            # None | leafwise | fused
    compress_block: int = 256                 # int8 quantization block
    compress_impl: str = "ref"                # ref | pallas | interpret

    def __post_init__(self):
        if self.engine not in ("python", "fused"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.compress not in (None, "leafwise", "fused"):
            raise ValueError(f"unknown compress {self.compress!r}")
        # Eq. 2 upload emulation: "leafwise" quantize-roundtrips each leaf
        # then averages (the tested reference wire path); "fused" collapses
        # codec + averaging into one flat-buffer kernel pass (same wire
        # format, exact byte accounting, no small-leaf bypass).
        self._average_fn = averaging.average_pjit
        if self.compress == "leafwise":
            if self.compress_fn is None:
                self.compress_fn = compression.make_compress_fn(
                    self.compress_block, self.compress_impl)
        elif self.compress == "fused":
            if self.compress_fn is not None:
                raise ValueError(
                    "compress='fused' replaces compress_fn entirely; "
                    "pass one or the other")
            self._average_fn = engine_mod.make_fused_compressed_average(
                block=self.compress_block, impl=self.compress_impl)
        self.opt = get_optimizer(self.optimizer_name)
        # the ONE local-epoch body (engine_mod.make_epoch_fn) is shared:
        # the python path jits it per-epoch, the fused paths scan over it
        self._jit_epoch = jax.jit(
            engine_mod.make_epoch_fn(self.loss_fn, self.opt))
        self._jit_avg = jax.jit(self._average_fn)
        kw = dict(compress_fn=self.compress_fn,
                  average_fn=self._average_fn,
                  total_epochs=self.total_epochs_budget())
        self._fused_round = engine_mod.make_fused_round(
            self.loss_fn, self.opt, self.cfg, **kw)
        self._fused_epochs = engine_mod.make_fused_epochs(
            self.loss_fn, self.opt, self.cfg,
            total_epochs=self.total_epochs_budget())
        self._fused_finalize = engine_mod.make_fused_finalize(
            self.opt, compress_fn=self.compress_fn,
            average_fn=self._average_fn)

    # -- Algorithm 1 ---------------------------------------------------------
    def init(self, params):
        K = self.cfg.n_participants
        stacked = averaging.stack_participants(params, K)
        opt_state = jax.vmap(self.opt.init)(stacked)
        ctrl = EpochController(self.cfg.T0, self.cfg.epsilon,
                               self.cfg.epochs_rule)
        return {"params": stacked, "opt": opt_state, "ctrl": ctrl,
                "round": 0, "global_epoch": 0, "prev_avg": None, "log": []}

    def total_epochs_budget(self):
        # used by the ELR baseline's anneal denominator
        return max(self.cfg.T0 * self.cfg.max_rounds, 1)

    def param_bytes(self, state):
        one = averaging.unstack_participant(state["params"], 0)
        return sum(t.size * t.dtype.itemsize for t in jax.tree.leaves(one))

    def run_round(self, state, epoch_batches_fn):
        """One communication round.

        epoch_batches_fn(round, epoch) -> (K, n_batches, B, ...) pytree for
        that local epoch (each participant sees only its own disjoint shard —
        the data never crosses participants, only parameters do).

        Dispatches to the configured round engine; both engines apply the
        identical state transition (params, opt reset, controller, log).
        """
        if self.engine == "fused":
            return self._run_round_fused(state, epoch_batches_fn)
        return self._run_round_python(state, epoch_batches_fn)

    def _finish_round(self, state, i, T_i, rel, local_losses, lr_first,
                      lr_last, averaged, fresh_opt, new_avg):
        """The one round state transition, shared verbatim by both engines.

        ``fresh_opt`` is the per-participant opt reset (opt state is
        intentionally NOT averaged: the paper restarts local training from
        the shared model each round). ``new_avg`` stays device-side — no
        full-model host transfer per round.
        """
        state["params"], state["opt"] = averaged, fresh_opt
        state["prev_avg"] = new_avg
        state["ctrl"] = state["ctrl"].update(rel)
        state["global_epoch"] += T_i
        # comm volume: each participant uploads + downloads the full model
        comm = 2 * self.param_bytes(state)
        state["round"] = i + 1
        state["log"].append(RoundLog(i, T_i, lr_first, lr_last, rel,
                                     local_losses, comm))
        return state

    def _run_round_fused(self, state, epoch_batches_fn):
        """One round as one (or, past ``fused_chunk`` epochs, a few chained)
        donated executables — zero host syncs until the final aux fetch."""
        i = state["round"]
        T_i = state["ctrl"].T
        ge0 = jnp.int32(state["global_epoch"])
        # state["params"]/["opt"] are reassigned immediately after every
        # donating call below, so an exception mid-round (e.g. from
        # epoch_batches_fn) can never leave state holding deleted buffers.
        if T_i <= self.fused_chunk:
            batches = engine_mod.stack_epoch_batches(
                [epoch_batches_fn(i, j) for j in range(T_i)])
            averaged, fresh_opt, aux = self._fused_round(
                state["params"], state["opt"], batches, ge0)
            state["params"], state["opt"] = averaged, fresh_opt
            new_avg = aux["new_avg"]
            # the round's single host sync (scalars/loss curves only — the
            # averaged model itself stays on device)
            losses, lrs, rel_dev = jax.device_get(
                (aux["losses"], aux["lrs"], aux["rel"]))
        else:
            # staging all T_i epochs at once would cost device memory linear
            # in T_i (which ILE doubles); chain chunk executables instead.
            # j0/T_i/ge0 are traced, so chunks reuse one compiled program.
            old_avg = averaging.unstack_participant(state["params"], 0)
            lparts, rparts, j0 = [], [], 0
            while j0 < T_i:
                C = min(self.fused_chunk, T_i - j0)
                batches = engine_mod.stack_epoch_batches(
                    [epoch_batches_fn(i, j) for j in range(j0, j0 + C)])
                params, opt_st, l, r = self._fused_epochs(
                    state["params"], state["opt"], batches, jnp.int32(j0),
                    jnp.int32(T_i), ge0)
                state["params"], state["opt"] = params, opt_st
                lparts.append(l)
                rparts.append(r)
                j0 += C
            averaged, fresh_opt, rel_t, new_avg = self._fused_finalize(
                state["params"], old_avg)
            state["params"], state["opt"] = averaged, fresh_opt
            lparts, rparts, rel_dev = jax.device_get((lparts, rparts, rel_t))
            losses = np.concatenate(lparts)
            lrs = np.concatenate(rparts)
        rel = float("inf") if state["prev_avg"] is None else float(rel_dev)
        return self._finish_round(state, i, T_i, rel,
                                  [float(l.mean()) for l in losses],
                                  float(lrs[0]), float(lrs[-1]),
                                  averaged, fresh_opt, new_avg)

    def _run_round_python(self, state, epoch_batches_fn):
        """Reference path: one jit dispatch + host sync per local epoch."""
        cfg = self.cfg
        i = state["round"]
        T_i = state["ctrl"].T
        ge0 = state["global_epoch"]
        lrs = []
        losses = []
        for j in range(T_i):
            lr = float(round_lr(cfg, i, j, T_i, ge0 + j,
                                self.total_epochs_budget()))
            lrs.append(lr)
            batches = epoch_batches_fn(i, j)
            params, opt, l = self._jit_epoch(
                state["params"], state["opt"], batches, lr)
            state["params"], state["opt"] = params, opt
            losses.append(jax.device_get(l))

        # -- upload + aggregate (Eq. 2); optional beyond-paper compression --
        uploaded = state["params"]
        if self.compress_fn is not None:
            uploaded = self.compress_fn(uploaded)
        averaged = self._jit_avg(uploaded)
        new_avg = averaging.unstack_participant(averaged, 0)
        rel = (float("inf") if state["prev_avg"] is None
               else relative_change(new_avg, state["prev_avg"]))
        fresh_opt = jax.vmap(self.opt.init)(averaged)
        return self._finish_round(state, i, T_i, rel,
                                  [float(x.mean()) for x in losses],
                                  lrs[0], lrs[-1], averaged, fresh_opt,
                                  new_avg)

    def shared_model(self, state):
        return averaging.unstack_participant(state["params"], 0)

    # -- failure handling (paper: restart the participant's local training) --
    def restart_participant(self, state, k):
        """Reset participant k's replica to the current shared model."""
        shared = self.shared_model(state)
        def put(t, s):
            return t.at[k].set(s)
        state["params"] = jax.tree.map(put, state["params"], shared)
        return state
