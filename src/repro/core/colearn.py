"""Algorithm 1 — the co-learning protocol, as a thin round-strategy driver.

The global-server logic (round state, Eq. 4 T_i control, failure restarts)
is plain Python; the heavy steps (K-participant local SGD epochs, the
aggregation step) are jitted JAX. The same `CoLearner` drives both the
simulation path (K participants vmapped on one host — used by every
paper-claims experiment) and the production path (K = pods,
`spmd_axis_name='pod'`).

A learner composes five strategy objects (``repro.core.api``):

  * ``codec`` — the wire format of one participant's upload. ``ExactF32()``
    (paper-faithful), ``LeafwiseInt8(block, impl)`` (per-leaf int8
    reference roundtrip), ``FlatFusedInt8(block, impl)`` (flat-buffer wire
    format, one fused quantize->average->dequantize kernel under full
    averaging, exact byte accounting).
  * ``aggregator`` — who averages what: ``FullAverage()`` (paper Eq. 2),
    ``PartialParticipation(m=...)`` (FedAvg-style sampled uploads),
    ``RingGossip()`` (serverless neighbor exchange on a fixed ring).
  * ``round_engine`` — ``PythonEngine()`` (reference host loop, one jit
    dispatch per epoch) or ``FusedEngine(chunk=...)`` (the whole round as
    one donated executable, ``repro.core.engine``; long rounds chain chunk
    executables, still one host sync).
  * ``schedule`` — the Eq. 3 family: ``CLR()`` (paper per-round restart),
    ``ELR()`` (global anneal), ``WarmupCLR(warmup_rounds=...)``,
    ``CosineCyclical()``. Per-round parameters (η^i, decay, the epoch
    budget) ride into the fused executables as traced arguments, so
    warmups, budget updates, and built-in swaps
    (``set_schedule``) never recompile.
  * ``sync_policy`` — Eq. 4 generalized: ``ILE(epsilon=...)`` (paper
    doubling), ``FLE()`` (fixed T), ``DivergenceTrigger(delta=...)``
    (Kamp-style: skip the averaging/wire step — and its comm bill — on
    rounds where the local models haven't diverged past δ).

Registry names resolve too: ``CoLearner(ccfg, loss_fn, codec="leafwise",
aggregator="partial", round_engine="fused", schedule="clr",
sync_policy="ile")``; leaving ``schedule``/``sync_policy`` as None resolves
the legacy ``CoLearnConfig.schedule``/``epochs_rule`` strings through the
same registries, bit-for-bit. The pre-PR-3 flag surface (``engine=``,
``compress=``, ``compress_impl=``, ``compress_fn=``, ``compress_block=``,
``fused_chunk=``) lives on as ``CoLearner.from_flags`` — see ROADMAP.md
§Round strategy API for the flag -> object migration table. Engine
equivalence and flag/object parity are asserted in tests/test_engine.py,
tests/test_api.py, and tests/test_policies.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, averaging, engine as engine_mod
from repro.core import membership as membership_mod
from repro.optim.optimizers import get_optimizer


@dataclass
class RoundLog:
    round: int
    T: int
    lr_first: float
    lr_last: float
    rel_change: float        # Eq. 4 metric; the divergence on skipped rounds
    local_losses: list
    comm_bytes: int          # 0 on rounds a gated sync policy skipped
    synced: bool = True
    live: int = -1           # live participants this round (K when static;
                             # -1 only on legacy hand-built logs)


@dataclass
class CoLearner:
    """K-participant co-learning driver over (codec, aggregator, engine).

    loss_fn(params, batch) -> (loss, metrics) for ONE participant.
    data: per-participant iterables of epochs; see ``run_round``.

    codec / aggregator / round_engine / schedule / sync_policy each accept
    a strategy object from ``repro.core.api``, a registry name ("exact" |
    "leafwise" | "fused", "full" | "partial" | "ring", "python" | "fused",
    "clr" | "elr" | "warmup_clr" | "cosine", "ile" | "fle" | "divtrigger"),
    or None for the paper-faithful default (exact f32 wire, full Eq. 2
    averaging, python reference engine, and the ``cfg.schedule``/
    ``cfg.epochs_rule`` strings resolved through the registries). Use
    ``CoLearner.from_flags(...)`` for the legacy keyword surface.
    """
    cfg: Any                                  # CoLearnConfig
    loss_fn: Callable
    optimizer_name: str = "sgd"
    codec: Any = None                         # WireCodec | name | None
    aggregator: Any = None                    # Aggregator | name | None
    round_engine: Any = None                  # RoundEngine | name | None
    schedule: Any = None                      # LRSchedule | name | None
    sync_policy: Any = None                   # SyncPolicy | name | None
    #: per-participant example counts (``ParticipantData.sizes``). When
    #: given, a PartialParticipation aggregator with no explicit weights is
    #: auto-wired to the FedAvg shard-size weighting — the learner never
    #: silently falls back to a uniform average on unequal shards.
    shard_sizes: Any = None
    #: (K, n_batches) bool validity mask for ragged shards
    #: (``ParticipantData.batch_mask``). None = equal shards, the classic
    #: bit-compatible unmasked path; when given, both engines thread it
    #: through the epoch bodies as traced data (masked step = identity
    #: carry), so no shard is clamped to the global minimum length.
    batch_mask: Any = None
    #: elastic membership (``repro.core.membership``): a ChurnSchedule, a
    #: registry name ("none" | "scripted" | "random"), or None. A static
    #: schedule (``is_static``) keeps the learner on the exact pre-
    #: membership code path — bit-identical to a learner with no churn
    #: argument at all. An active schedule threads a traced (K,) liveness
    #: row through the engines: dead slots are identity carries (no
    #: training, no upload, no download) and rejoins warm-start from the
    #: last synced model via ``restart_participant``.
    churn: Any = None
    #: False = ablation baseline for benchmarks/churn.py: keep the STATIC
    #: mixing matrix under churn (dead rows' stale models pollute the
    #: mean) while the engine-side identity carries still apply. True
    #: (default) renormalizes the aggregator over the live set.
    liveness_aware: bool = True

    def __post_init__(self):
        self.codec = api.get_codec(self.codec)
        # error-feedback codecs carry per-participant residual memory
        # through the round state (init/run_round/restart/checkpoint)
        self._codec_stateful = getattr(self.codec, "stateful", False)
        self.aggregator = api.get_aggregator(self.aggregator)
        # stateful aggregators (D² correction) ride the same round-state
        # slot; either side being stateful turns on the residual plumbing
        self._round_stateful = (self._codec_stateful
                                or getattr(self.aggregator, "stateful",
                                           False))
        # topology-backed aggregators carry a connectivity guard: reject
        # graphs that can never reach consensus at this K up front
        validate = getattr(self.aggregator, "validate", None)
        if validate is not None:
            validate(self.cfg.n_participants)
        self.round_engine = api.get_engine(self.round_engine)
        # None resolves the legacy cfg.schedule / cfg.epochs_rule strings
        # through the same registries the names go through
        self.schedule = api.get_schedule(self.schedule, self.cfg)
        self.sync_policy = api.get_sync_policy(self.sync_policy, self.cfg)
        self.churn = membership_mod.get_churn(self.churn)
        # static schedules bypass the membership machinery entirely, so
        # "no churn" is bit-for-bit the pre-membership static-K path
        self._churn_active = not self.churn.is_static
        if self.shard_sizes is not None:
            self.shard_sizes = tuple(int(s) for s in self.shard_sizes)
            if len(self.shard_sizes) != self.cfg.n_participants:
                raise ValueError(
                    f"shard_sizes has {len(self.shard_sizes)} entries for "
                    f"K={self.cfg.n_participants} participants")
            if (isinstance(self.aggregator, api.PartialParticipation)
                    and self.aggregator.weights is None):
                import dataclasses as _dc
                self.aggregator = _dc.replace(self.aggregator,
                                              weights=self.shard_sizes)
        if self.batch_mask is not None:
            mask = jnp.asarray(self.batch_mask, bool)
            if mask.ndim != 2 or mask.shape[0] != self.cfg.n_participants:
                raise ValueError(
                    f"batch_mask must be (K={self.cfg.n_participants}, "
                    f"n_batches); got shape {mask.shape}")
            if not bool(mask.any(axis=1).all()):
                raise ValueError("batch_mask leaves some participant with "
                                 "zero valid batches")
            self.batch_mask = mask
        self.opt = get_optimizer(self.optimizer_name)
        # the ONE local-epoch body (engine_mod.make_epoch_fn) is shared:
        # the python engine jits it per-epoch, the fused engine scans over
        # it, so the SGD semantics cannot diverge
        self._jit_epoch = jax.jit(engine_mod.make_epoch_fn(
            self.loss_fn, self.opt, masked=self.batch_mask is not None,
            live=self._churn_active))
        # aggregate(stacked, weights): codec roundtrip + participant mixing;
        # dynamic = the matrix renormalizes over the live set per round
        self._aggregate_fn = self.aggregator.make_aggregate_fn(
            self.codec, dynamic=self._churn_active and self.liveness_aware)
        self._comm_cache = None

        # crash/join handling as ONE jitted row write (traced slot index:
        # one executable per params geometry, zero per-slot recompiles).
        # Eager .at[k].set dispatches scatters whose index scalars are
        # implicit H2D — restarts fire mid-round-loop, inside no_transfer.
        def _restart_row(stacked, opt_state, shared, k):
            new_p = jax.tree.map(lambda t, s: t.at[k].set(s),
                                 stacked, shared)
            fresh = self.opt.init(shared)
            new_o = jax.tree.map(lambda o, f: o.at[k].set(f),
                                 opt_state, fresh)
            return new_p, new_o
        self._jit_restart = jax.jit(_restart_row)
        self._jit_zero_row = jax.jit(
            lambda tree, k: jax.tree.map(lambda e: e.at[k].set(0.0), tree))
        self._runner = self.round_engine.bind(self)

    @classmethod
    def from_flags(cls, cfg, loss_fn, *, optimizer_name: str = "sgd",
                   compress_fn: Callable | None = None,
                   engine: str = "python", fused_chunk: int = 32,
                   compress: str | None = None, compress_block: int = 256,
                   compress_impl: str = "ref", aggregator=None):
        """The pre-PR-3 flag surface, mapped onto strategy objects.

        engine="python"|"fused" (+ fused_chunk) -> round_engine;
        compress=None|"leafwise"|"fused" (+ compress_block/compress_impl)
        -> codec; compress_fn stays the low-level escape hatch (an opaque
        stacked->stacked wire transform, mutually exclusive with
        compress="fused"). Behavior is flag-for-flag identical to the old
        constructor; parity is asserted in tests/test_api.py.
        """
        if engine not in ("python", "fused"):
            raise ValueError(f"unknown engine {engine!r}")
        if compress not in (None, "leafwise", "fused"):
            raise ValueError(f"unknown compress {compress!r}")
        if compress == "fused":
            if compress_fn is not None:
                raise ValueError(
                    "compress='fused' replaces compress_fn entirely; "
                    "pass one or the other")
            codec = api.FlatFusedInt8(block=compress_block,
                                      impl=compress_impl)
        elif compress_fn is not None:
            codec = api.CustomFn(compress_fn)
        elif compress == "leafwise":
            codec = api.LeafwiseInt8(block=compress_block,
                                     impl=compress_impl)
        else:
            codec = api.ExactF32()
        round_engine = (api.FusedEngine(chunk=fused_chunk)
                        if engine == "fused" else api.PythonEngine())
        return cls(cfg, loss_fn, optimizer_name=optimizer_name, codec=codec,
                   aggregator=aggregator, round_engine=round_engine)

    # -- Algorithm 1 ---------------------------------------------------------
    def init(self, params):
        K = self.cfg.n_participants
        self._comm_cache = None      # params shapes may differ from last init
        stacked = averaging.stack_participants(params, K)
        opt_state = jax.vmap(self.opt.init)(stacked)
        ctrl = self.sync_policy.init_state(self.cfg.T0)
        # membership starts at the schedule's round-0 mask so initially-
        # dead standby slots log no synthetic leave events; static runs
        # carry the all-live record for checkpoint uniformity
        if self._churn_active:
            mem = membership_mod.Membership(live=tuple(
                bool(a) for a in self.churn.live_mask(0, K)))
        else:
            mem = membership_mod.Membership.all_live(K)
        # stateful rounds start from zero memory — the codec's EF residual
        # (codec owns the mirror structure: leafwise trees / the flat wire
        # buffer), the aggregator's state (D² correction), or both
        residual = self.aggregator.init_round_state(self.codec, stacked)
        return {"params": stacked, "opt": opt_state, "ctrl": ctrl,
                "round": 0, "global_epoch": 0, "prev_avg": None, "log": [],
                "membership": mem, "residual": residual}

    def epochs_budget(self, state):
        """The ELR anneal denominator for the round about to run: epochs
        already run + the policy's extrapolation over the remaining rounds
        (= T0·max_rounds for fixed-T policies; re-estimated after every
        ILE doubling — the old static budget stranded the ELR anneal short
        once T_i doubled). Rides into the fused executables traced, so the
        per-round update is free."""
        return self.sync_policy.epochs_budget(
            state["ctrl"].T, state["round"], state["global_epoch"],
            self.cfg.max_rounds)

    def set_schedule(self, spec):
        """Swap the learning-rate schedule mid-run.

        All built-in schedules share one traced body, so swapping among
        them (or re-parameterizing one) reuses the fused engine's compiled
        executables — the new parameters simply ride in as the next
        round's traced arguments. A custom schedule with its own
        ``traced_lr`` rebinds the engine (one-time retrace)."""
        self.schedule = api.get_schedule(spec, self.cfg)
        # compare against the runner's COMPILED body (not the previous
        # schedule attribute) so a swap also repairs a direct assignment
        bound = getattr(self._runner, "_traced_lr", None)
        if bound is not None and api.traced_body(self.schedule) is not bound:
            self._runner = self.round_engine.bind(self)
        return self

    def set_sync_policy(self, spec):
        """Swap the sync policy mid-run.

        Threshold/epsilon changes ride in as the next round's host/traced
        values; only flipping the divergence gate itself (e.g. ILE ->
        DivergenceTrigger) or changing the traced gate body rebinds the
        fused engine, whose round executables are compiled with or
        without the on-device gate."""
        bound_gated = getattr(self._runner, "_gated", None)
        bound_gate = getattr(self._runner, "_traced_gate", None)
        self.sync_policy = api.get_sync_policy(spec, self.cfg)
        if bound_gated is not None and (
                self.sync_policy.divergence_gated != bound_gated
                or type(self.sync_policy).traced_should_sync
                is not bound_gate):
            self._runner = self.round_engine.bind(self)
        return self

    def param_bytes(self, state):
        one = averaging.unstack_participant(state["params"], 0)
        return sum(t.size * t.dtype.itemsize for t in jax.tree.leaves(one))

    def round_weights(self, round_index, state=None):
        """The aggregator's (K, K) mixing matrix for this round as a device
        array (None for statically-known schemes, e.g. Eq. 2).

        Under active churn with ``liveness_aware`` the matrix renormalizes
        over the round's live set (read from ``state["membership"]``), so
        a matrix is always produced — the aggregate fn was built dynamic.
        """
        if self._churn_active and self.liveness_aware:
            live = (state["membership"].live_mask() if state is not None
                    else None)
            return engine_mod.stage(self.aggregator.mixing_matrix(
                round_index, self.cfg.n_participants, live=live),
                np.float32)
        if not self.aggregator.uses_weights:
            return None
        return engine_mod.stage(self.aggregator.mixing_matrix(
            round_index, self.cfg.n_participants), np.float32)

    def _live_np(self, state):
        """The round's bool (K,) liveness row (None on the static path —
        the engines then run the pre-membership executables)."""
        if not self._churn_active:
            return None
        return state["membership"].live_mask()

    def _round_delta(self, state):
        """The round's divergence threshold: the sync policy's, possibly
        moved by this round's membership events (a join forces the sync so
        the rejoined slot gets the current shared model)."""
        events = (state["membership"].round_events(state["round"])
                  if self._churn_active else ())
        return self.sync_policy.round_delta(events)

    def run_round(self, state, epoch_batches_fn, on_round_end=None):
        """One communication round.

        epoch_batches_fn(round, epoch) -> (K, n_batches, B, ...) pytree for
        that local epoch (each participant sees only its own disjoint shard —
        the data never crosses participants, only parameters do).

        Dispatches to the bound round engine; both engines apply the
        identical state transition (params, opt reset, controller, log).
        Under active churn the membership advances FIRST: the schedule's
        round mask is stepped into ``state["membership"]`` (logging
        join/leave events) and every slot that joined this round warm-
        starts from the last synced shared model before any epoch runs.

        ``on_round_end(learner, state)``, when given, fires after the
        round's state transition lands — the publication hook for
        continuous operation (e.g. ``ModelBank.publish_from``). Its
        return value is ignored; the round's state is returned unchanged.
        """
        if self._churn_active:
            i = state["round"]
            new_live = self.churn.live_mask(i, self.cfg.n_participants)
            if not np.any(new_live):
                raise ValueError(
                    f"churn schedule {self.churn.name!r} leaves zero live "
                    f"participants at round {i}")
            state["membership"] = state["membership"].step(i, new_live)
            for k in state["membership"].joined(i):
                # warm join: restart local training from the last SYNCED
                # shared model (paper failure semantics, elastic form)
                self.restart_participant(state, k)
        state = self._runner.run_round(state, epoch_batches_fn)
        if on_round_end is not None:
            on_round_end(self, state)
        return state

    def _finish_round(self, state, i, T_i, rel, local_losses, lr_first,
                      lr_last, averaged, fresh_opt, new_avg, synced=True,
                      residual=None):
        """The one round state transition, shared verbatim by both engines.

        ``fresh_opt`` is the per-participant opt reset (opt state is
        intentionally NOT averaged: the paper restarts local training from
        the shared model each round). ``new_avg`` stays device-side — no
        full-model host transfer per round. On a round a gated sync policy
        skipped (``synced=False``) the runner passes the untouched local
        params/opt, the unchanged sync reference, and the divergence as
        ``rel`` — and the round bills zero wire bytes. ``residual`` is the
        error-feedback codec's post-round memory (None for stateless
        codecs or when the runner already stored it on ``state``).
        """
        state["params"], state["opt"] = averaged, fresh_opt
        state["prev_avg"] = new_avg
        if residual is not None:
            state["residual"] = residual
        if self._churn_active:
            mem = state["membership"]
            events, n_live = mem.round_events(i), mem.n_live
        else:
            events, n_live = (), self.cfg.n_participants
        state["ctrl"] = self.sync_policy.update(state["ctrl"], i, rel,
                                                synced, events=events)
        state["global_epoch"] += T_i
        # comm volume per participant, priced by the aggregator through the
        # codec (compressed upload + raw download; gossip pays wire both
        # ways); round-independent accounting (all built-in aggregators) is
        # computed once — flat-codec pricing rebuilds a host-side layout
        # table, which must stay off the per-round path. Under active churn
        # the live set changes the bill per round, so the cache is bypassed
        # and only live rows are billed.
        if not synced:
            comm = 0
        elif self._churn_active:
            comm = self.aggregator.comm_bytes(
                self.codec, state["params"], i,
                live=state["membership"].live_mask())
        elif self.aggregator.static_comm:
            if self._comm_cache is None:
                self._comm_cache = self.aggregator.comm_bytes(
                    self.codec, state["params"], i)
            comm = self._comm_cache
        else:
            comm = self.aggregator.comm_bytes(self.codec, state["params"], i)
        state["round"] = i + 1
        state["log"].append(RoundLog(i, T_i, lr_first, lr_last, rel,
                                     local_losses, comm, synced,
                                     live=n_live))
        return state

    # legacy handles used by tests/benchmarks to poke at the fused
    # executables' compilation caches
    def _fused_handle(self, attr):
        if not hasattr(self._runner, attr):
            raise AttributeError(
                f"_fused{attr} is only available with "
                f"round_engine=FusedEngine(); this learner runs "
                f"{self.round_engine.name!r}")
        return getattr(self._runner, attr)

    @property
    def _fused_round(self):
        return self._fused_handle("_round")

    @property
    def _fused_epochs(self):
        return self._fused_handle("_epochs")

    @property
    def _fused_finalize(self):
        return self._fused_handle("_finalize")

    def shared_model(self, state):
        # under churn the canonical slot is the first LIVE one — a dead
        # slot 0 holds the stale pre-crash model, not the shared average
        live = self._live_np(state)
        k0 = 0 if live is None else int(np.argmax(live))
        return averaging.unstack_participant(state["params"], k0)

    def _sync_ref(self, state):
        """The last synced shared model — the Eq. 4 / divergence reference
        both engines measure against. Before the first sync (round 0, when
        every slot still holds the init model) it is slot 0 of the entry
        params; afterwards ``prev_avg``, which gated runs advance only on
        synced rounds."""
        if state["prev_avg"] is not None:
            return state["prev_avg"]
        live = self._live_np(state)
        k0 = 0 if live is None else int(np.argmax(live))
        return averaging.unstack_participant(state["params"], k0)

    # -- failure handling (paper: restart the participant's local training) --
    def restart_participant(self, state, k):
        """Reset participant k's replica to the last SYNCED shared model.

        Both the parameters AND the optimizer state row are reset (a stale
        momentum/Adam moment would keep pushing the restarted replica along
        its pre-failure trajectory — the paper's failure semantics restart
        local training from the shared model outright).

        The reference is ``_sync_ref`` (``prev_avg``, i.e. the last synced
        average), NOT slot 0 of the current params: under ``RingGossip``
        the rows stay distinct, and after a quiet ``DivergenceTrigger``
        round slot 0 holds a locally-drifted model — resetting from either
        would hand the restarted participant some peer's private
        trajectory instead of the shared model the contract promises.
        """
        shared = self._sync_ref(state)
        k_dev = engine_mod.stage(k, np.int32)
        state["params"], state["opt"] = self._jit_restart(
            state["params"], state["opt"], shared, k_dev)
        if self._round_stateful and state.get("residual") is not None:
            # restart also forgets the round-state memory (quantization
            # error residual and/or D² correction): it tracked a
            # trajectory that no longer exists
            state["residual"] = self._jit_zero_row(state["residual"], k_dev)
        return state
