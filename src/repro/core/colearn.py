"""Algorithm 1 — the co-learning protocol.

The global-server logic (round state, Eq. 4 T_i control, failure restarts)
is plain Python; the heavy steps (K-participant local SGD epochs, Eq. 2
averaging) are jitted JAX. The same `CoLearner` drives both the simulation
path (K participants vmapped on one host — used by every paper-claims
experiment) and the production path (K = pods, `spmd_axis_name='pod'`).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import averaging
from repro.core.schedule import EpochController, relative_change, round_lr
from repro.optim.optimizers import apply_updates, get_optimizer


@dataclass
class RoundLog:
    round: int
    T: int
    lr_first: float
    lr_last: float
    rel_change: float
    local_losses: list
    comm_bytes: int


@dataclass
class CoLearner:
    """K-participant co-learning driver.

    loss_fn(params, batch) -> (loss, metrics) for ONE participant.
    data: per-participant iterables of epochs; see ``run_round``.
    """
    cfg: Any                                  # CoLearnConfig
    loss_fn: Callable
    optimizer_name: str = "sgd"
    compress_fn: Optional[Callable] = None    # stacked params -> stacked params

    def __post_init__(self):
        self.opt = get_optimizer(self.optimizer_name)
        self._jit_epoch = jax.jit(self._epoch, static_argnames=())
        self._jit_avg = jax.jit(averaging.average_pjit)

    # -- one SGD epoch for all K participants (vmapped) ---------------------
    def _epoch(self, stacked_params, opt_state, batches, lr):
        """batches: (K, n_batches, ...) pytree; one full local epoch."""
        def one_participant(params, ostate, pbatches):
            def step(carry, batch):
                params, ostate = carry
                (loss, _), grads = jax.value_and_grad(
                    self.loss_fn, has_aux=True)(params, batch)
                upd, ostate = self.opt.update(grads, ostate, params, lr)
                return (apply_updates(params, upd), ostate), loss
            (params, ostate), losses = jax.lax.scan(
                step, (params, ostate), pbatches)
            return params, ostate, losses.mean()
        return jax.vmap(one_participant)(stacked_params, opt_state, batches)

    # -- Algorithm 1 ---------------------------------------------------------
    def init(self, params):
        K = self.cfg.n_participants
        stacked = averaging.stack_participants(params, K)
        opt_state = jax.vmap(self.opt.init)(stacked)
        ctrl = EpochController(self.cfg.T0, self.cfg.epsilon,
                               self.cfg.epochs_rule)
        return {"params": stacked, "opt": opt_state, "ctrl": ctrl,
                "round": 0, "global_epoch": 0, "prev_avg": None, "log": []}

    def total_epochs_budget(self):
        # used by the ELR baseline's anneal denominator
        return max(self.cfg.T0 * self.cfg.max_rounds, 1)

    def param_bytes(self, state):
        one = averaging.unstack_participant(state["params"], 0)
        return sum(t.size * t.dtype.itemsize for t in jax.tree.leaves(one))

    def run_round(self, state, epoch_batches_fn):
        """One communication round.

        epoch_batches_fn(round, epoch) -> (K, n_batches, B, ...) pytree for
        that local epoch (each participant sees only its own disjoint shard —
        the data never crosses participants, only parameters do).
        """
        cfg = self.cfg
        i = state["round"]
        T_i = state["ctrl"].T
        lrs = []
        losses = []
        for j in range(T_i):
            lr = float(round_lr(cfg, i, j, T_i, state["global_epoch"],
                                self.total_epochs_budget()))
            lrs.append(lr)
            batches = epoch_batches_fn(i, j)
            params, opt, l = self._jit_epoch(
                state["params"], state["opt"], batches, lr)
            state["params"], state["opt"] = params, opt
            state["global_epoch"] += 1
            losses.append(jax.device_get(l))

        # -- upload + aggregate (Eq. 2); optional beyond-paper compression --
        uploaded = state["params"]
        if self.compress_fn is not None:
            uploaded = self.compress_fn(uploaded)
        averaged = self._jit_avg(uploaded)
        new_avg = averaging.unstack_participant(averaged, 0)

        rel = (float("inf") if state["prev_avg"] is None
               else relative_change(new_avg, state["prev_avg"]))
        state["prev_avg"] = jax.device_get(new_avg)
        state["ctrl"] = state["ctrl"].update(rel)
        state["params"] = averaged
        # opt state intentionally NOT averaged (each participant restarts
        # from the shared model; paper resets local training each round)
        state["opt"] = jax.vmap(self.opt.init)(averaged)

        # comm volume: each participant uploads + downloads the full model
        comm = 2 * self.param_bytes(state)
        state["round"] = i + 1
        state["log"].append(RoundLog(i, T_i, lrs[0], lrs[-1], rel,
                                     [float(x.mean()) for x in losses], comm))
        return state

    def shared_model(self, state):
        return averaging.unstack_participant(state["params"], 0)

    # -- failure handling (paper: restart the participant's local training) --
    def restart_participant(self, state, k):
        """Reset participant k's replica to the current shared model."""
        shared = self.shared_model(state)
        def put(t, s):
            return t.at[k].set(s)
        state["params"] = jax.tree.map(put, state["params"], shared)
        return state
