"""Fused round engine — one XLA executable per communication round.

The reference implementation of Algorithm 1 (``CoLearner.run_round`` with
``engine="python"``) drives the T_i local epochs from a host loop: one jit
dispatch + one blocking ``device_get`` per epoch, plus a host-side Eq. 4
``relative_change`` over the parameter leaves. Since the paper's protocol
spends nearly all wall-clock inside those local epochs, that dispatch
overhead sits directly on the hottest path.

``make_fused_round`` instead compiles the *whole* round into a single
donated jit:

    lax.scan over the T_i local epochs          (Eq. 3 CLR/ELR computed
        |                                        *traced* inside the scan
        |  each epoch: vmap over K participants, via ``schedule.clr_lr`` /
        |  inner lax.scan over that epoch's      ``schedule.elr_lr``)
        v  batches
    Eq. 2 averaging (``average_fn``)
    Eq. 4 relative_change, on-device            (``relative_change_traced``)

so a round costs one dispatch and exactly one host sync (the aux fetch at
the end). T_i is baked from the stacked batch shape — the executable is
recompiled only when the Eq. 4 controller doubles T_i, i.e. O(log T_max)
times per run.

Staging T_i epochs of batches on device costs memory linear in T_i, and
the ILE rule doubles T_i. For large rounds ``CoLearner`` therefore caps
the staged window at ``fused_chunk`` epochs and strings together
``make_fused_epochs`` executables (same in-scan schedule, j/T_i/epoch
offsets passed traced so chunks never recompile as T_i grows) followed by
one ``make_fused_finalize`` executable (Eq. 2 + Eq. 4 + opt reset). The
round is then ceil(T_i/chunk)+1 dispatches — still zero host syncs until
the final aux fetch.

Backend API — shared by the simulation and pod paths:

  * simulation (single host, K vmapped participants): the defaults.
  * pod (K = pods on a multi-pod mesh): pass ``spmd_axis_name="pod"`` so
    the participant vmap is pinned to the ``pod`` mesh axis, and an
    ``average_fn`` built by ``averaging.make_average_shard_map`` to pin
    Eq. 2 to an explicit shard_map psum over that axis
    (``launch/steps.make_fused_round_step`` wires this for the dry-run).

``CoLearner(round_engine=FusedEngine(chunk)|PythonEngine())`` (or the
legacy ``CoLearner.from_flags(engine=...)``) selects between this engine
and the reference loop; both produce the same ``RoundLog``/state
transitions and are asserted equivalent to <=1e-5 in
``tests/test_engine.py``. The aggregation step is supplied as
``aggregate_fn(stacked, weights)`` by a ``repro.core.api`` aggregator
(codec roundtrip + participant mixing; ``weights`` is the traced per-round
mixing matrix, None for statically-uniform Eq. 2).

The end-of-round Eq. 2 step has its own fast path:
``make_fused_compressed_average`` (owned by ``api.FlatFusedInt8`` as its
fused mean) replaces the leafwise int8 roundtrip + separate mean with the
flat-buffer wire codec (``core.flatbuf``) and one fused
quantize->average->dequantize kernel (``kernels.comm``) over one
contiguous buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import averaging, flatbuf
from repro.core.schedule import clr_lr, elr_lr, relative_change_traced
from repro.kernels import ops as kops
from repro.optim.optimizers import apply_updates


def stack_epoch_batches(per_epoch):
    """Stack a list of per-epoch (K, n_batches, ...) pytrees along a new
    leading epoch axis — the shape the fused epoch scan consumes."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_epoch)


def make_epoch_fn(loss_fn, opt, spmd_axis_name=None):
    """One local epoch for all K participants (vmapped).

    Returns epoch_fn(stacked_params, opt_state, batches, lr) ->
    (stacked_params, opt_state, per-participant mean loss). This is THE
    local-epoch body: the python reference loop jits it directly and the
    fused engine scans over it, so the SGD semantics cannot diverge.
    """
    def one_participant(params, ostate, pbatches, lr):
        def step(carry, batch):
            params, ostate = carry
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            upd, ostate = opt.update(grads, ostate, params, lr)
            return (apply_updates(params, upd), ostate), loss
        (params, ostate), losses = jax.lax.scan(step, (params, ostate),
                                                pbatches)
        return params, ostate, losses.mean()

    vmap_kw = {"spmd_axis_name": spmd_axis_name} if spmd_axis_name else {}
    return jax.vmap(one_participant, in_axes=(0, 0, 0, None), **vmap_kw)


def _make_epoch_scan(epoch_fn, cfg, total_epochs):
    """scan_epochs(params, opt, batches, j0, T_i, ge0): run the leading-dim
    epochs of ``batches`` with the Eq. 3 schedule computed traced in-scan.

    j0 (round-local offset of the first staged epoch), T_i (the round's
    CLR denominator) and ge0 (global epoch at round start, ELR) may all be
    traced, so a chunk executable is reused unchanged as T_i doubles.
    """
    def scan_epochs(stacked_params, opt_state, batches, j0, T_i,
                    global_epoch0):
        n = jax.tree.leaves(batches)[0].shape[0]

        def body(carry, xs):
            params, ostate = carry
            j, ebatches = xs
            if cfg.schedule == "clr":
                lr = clr_lr(cfg.eta0, cfg.decay_rate, j, T_i)
            else:
                lr = elr_lr(cfg.eta0, cfg.decay_rate, global_epoch0 + j,
                            total_epochs)
            params, ostate, loss = epoch_fn(params, ostate, ebatches, lr)
            return (params, ostate), (loss, lr)

        return jax.lax.scan(body, (stacked_params, opt_state),
                            (j0 + jnp.arange(n), batches))
    return scan_epochs


def make_fused_compressed_average(*, block=256, impl="ref", mesh=None,
                                  axis="pod"):
    """Eq. 2 fast path: int8 wire emulation + averaging as ONE buffer pass.

    Returns an ``average_fn`` (stacked tree -> stacked tree, every slot
    holding the mean) that replaces the leafwise pair ``compress_fn=
    make_compress_fn(...)`` + ``average_pjit``: the stacked params are
    flattened through the flat-buffer wire codec (``repro.core.flatbuf``)
    into one contiguous ``(K, N_pad)`` f32 buffer and a single
    ``quant_avg_dequant`` kernel (``repro.kernels.comm``) quantizes,
    averages, and dequantizes it blockwise — collapsing ~2 pallas launches
    + a pad/reshape per leaf + a separate whole-tree mean into one pass,
    with every leaf (however small) on the wire format.

    simulation path (``mesh=None``): the kernel sees all K rows at once.

    pod path (``mesh`` given): a ``shard_map`` over ``axis`` — each pod
    int8-roundtrips only its local row (its upload, exactly what the wire
    carries) and a single psum over the inter-pod axis aggregates the
    dequantized block payloads; only that one fused collective crosses the
    pod boundary, with ``flatbuf.wire_bytes`` giving the exact encoded
    size a production transport would move.

    The layout is recomputed per trace from static shapes only (free); the
    same tree structure always yields the same wire layout.
    """
    if mesh is None:
        def average(stacked):
            layout = flatbuf.make_layout(stacked, block=block)
            buf = flatbuf.flatten(stacked, layout)
            mean = kops.quant_avg_dequant(buf, block=block, impl=impl)
            return flatbuf.unflatten_mean(mean, layout)
        return average

    from repro.sharding import compat
    K = mesh.shape[axis]

    def average(stacked):
        layout = flatbuf.make_layout(stacked, block=block)
        buf = flatbuf.flatten(stacked, layout)         # (K, N_pad) over pod

        def local_avg(lbuf):                           # (1, N_pad) per pod
            q, scale, _ = kops.quantize_blockwise(lbuf, block=block,
                                                  impl=impl)
            dq = q.astype(jnp.int32).astype(jnp.float32) * scale[:, None]
            mean = jax.lax.psum(dq, axis) / K
            return mean.reshape(1, -1)[:, :layout.n_pad]

        avg = compat.shard_map(local_avg, mesh=mesh,
                               in_specs=(P(axis, None),),
                               out_specs=P(axis, None),
                               check_vma=False)(buf)
        return flatbuf.unflatten(avg, layout)
    return average


def as_aggregate_fn(aggregate_fn=None, compress_fn=None, average_fn=None):
    """Normalize the aggregation surface to ``aggregate(stacked, weights)``.

    New callers (``repro.core.api`` aggregators) pass ``aggregate_fn``
    directly — ``weights`` is the traced per-round mixing matrix (or None).
    Legacy callers keep the PR-2 pair: an optional stacked->stacked
    ``compress_fn`` upload transform followed by a one-argument
    ``average_fn`` (default ``averaging.average_pjit``); the pair is
    wrapped, ignoring weights. Passing both surfaces is an error.
    """
    if aggregate_fn is not None:
        if compress_fn is not None or average_fn is not None:
            raise ValueError(
                "pass aggregate_fn OR compress_fn/average_fn, not both")
        return aggregate_fn
    if average_fn is None:
        average_fn = averaging.average_pjit

    def aggregate(stacked, weights=None):
        del weights                     # legacy pair: statically uniform
        uploaded = compress_fn(stacked) if compress_fn is not None else stacked
        return average_fn(uploaded)
    return aggregate


def _make_finalize(opt, aggregate_fn):
    """Aggregation (Eq. 2 / mixing) + Eq. 4 metric + per-participant opt
    reset; ``agg_weights`` is the aggregator's traced mixing matrix."""
    def finalize(params, old_avg, agg_weights=None):
        averaged = aggregate_fn(params, agg_weights)
        new_avg = averaging.unstack_participant(averaged, 0)
        rel = relative_change_traced(new_avg, old_avg)
        # paper: local opt state is discarded; restart from the shared model
        fresh_opt = jax.vmap(opt.init)(averaged)
        return averaged, fresh_opt, rel, new_avg
    return finalize


def _resolve_epochs(cfg, total_epochs):
    if total_epochs is None:
        total_epochs = max(cfg.T0 * cfg.max_rounds, 1)
    return total_epochs


def make_fused_round(loss_fn, opt, cfg, *, compress_fn=None,
                     total_epochs=None, spmd_axis_name=None,
                     average_fn=None, aggregate_fn=None, donate=True):
    """Build the single-executable round: epoch scan + aggregation + Eq. 4.

    loss_fn(params, batch) -> (loss, aux) for ONE participant.
    opt: optimizer triple (init/update) from ``repro.optim.optimizers``.
    cfg: CoLearnConfig — supplies schedule kind, eta0, decay_rate.
    total_epochs: ELR anneal denominator (default T0 * max_rounds).
    spmd_axis_name: e.g. "pod" to pin the participant vmap to a mesh axis.
    aggregate_fn(stacked, weights): the round-strategy aggregation (codec
        roundtrip + mixing, see ``repro.core.api``), traced into the same
        executable. Legacy alternative: ``compress_fn`` (optional stacked->
        stacked upload transform) + ``average_fn`` (one-arg Eq. 2 over
        stacked params, default ``averaging.average_pjit``).

    Returns round_fn(stacked_params, opt_state, batches, global_epoch0,
    agg_weights=None) -> (aggregated_params, fresh_opt_state, aux) with
    aux = {losses (T,K), lrs (T,), rel (scalar), new_avg (unstacked slot-0
    model)}. ``batches`` is a (T_i, K, n_batches, ...) pytree;
    ``global_epoch0`` a traced int32 so ELR never retriggers compilation;
    ``agg_weights`` the aggregator's traced (K, K) mixing matrix (None for
    statically-known schemes like Eq. 2). stacked_params and opt_state are
    donated.
    """
    total_epochs = _resolve_epochs(cfg, total_epochs)
    scan_epochs = _make_epoch_scan(make_epoch_fn(loss_fn, opt,
                                                 spmd_axis_name),
                                   cfg, total_epochs)
    finalize = _make_finalize(opt, as_aggregate_fn(aggregate_fn, compress_fn,
                                                   average_fn))

    def round_fn(stacked_params, opt_state, batches, global_epoch0,
                 agg_weights=None):
        T_i = jax.tree.leaves(batches)[0].shape[0]
        # round entry: every slot holds the shared model w̄^{i-1}
        old_avg = averaging.unstack_participant(stacked_params, 0)
        (params, opt_out), (losses, lrs) = scan_epochs(
            stacked_params, opt_state, batches, 0, T_i, global_epoch0)
        del opt_out  # paper: local opt state is discarded at aggregation
        averaged, fresh_opt, rel, new_avg = finalize(params, old_avg,
                                                     agg_weights)
        return averaged, fresh_opt, {"losses": losses, "lrs": lrs,
                                     "rel": rel, "new_avg": new_avg}

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(round_fn, donate_argnums=donate_argnums)


def make_fused_epochs(loss_fn, opt, cfg, *, total_epochs=None,
                      spmd_axis_name=None, donate=True):
    """Memory-bounded building block: a scan over ONE CHUNK of epochs.

    Returns epochs_fn(stacked_params, opt_state, batches, j0, T_i, ge0)
      -> (stacked_params, opt_state, losses (C,K), lrs (C,)).
    j0/T_i/ge0 are traced, so the executable is shared across chunks and
    across T_i doublings; only a distinct chunk length C recompiles.
    """
    total_epochs = _resolve_epochs(cfg, total_epochs)
    scan_epochs = _make_epoch_scan(make_epoch_fn(loss_fn, opt,
                                                 spmd_axis_name),
                                   cfg, total_epochs)

    def epochs_fn(stacked_params, opt_state, batches, j0, T_i,
                  global_epoch0):
        (params, ostate), (losses, lrs) = scan_epochs(
            stacked_params, opt_state, batches, j0, T_i, global_epoch0)
        return params, ostate, losses, lrs

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(epochs_fn, donate_argnums=donate_argnums)


def make_fused_finalize(opt, *, compress_fn=None, average_fn=None,
                        aggregate_fn=None, donate=True):
    """End-of-round executable for the chunked path: aggregation + Eq. 4 +
    opt reset. finalize_fn(params, old_avg, agg_weights=None) ->
    (aggregated, fresh_opt, rel, new_avg); ``params`` is donated. The
    aggregation surface matches ``make_fused_round`` (aggregate_fn or the
    legacy compress_fn/average_fn pair)."""
    finalize = _make_finalize(opt, as_aggregate_fn(aggregate_fn, compress_fn,
                                                   average_fn))
    return jax.jit(finalize, donate_argnums=(0,) if donate else ())
