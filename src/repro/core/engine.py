"""Fused round engine — one XLA executable per communication round.

The reference implementation of Algorithm 1 (``CoLearner.run_round`` with
the python engine) drives the T_i local epochs from a host loop: one jit
dispatch + one blocking ``device_get`` per epoch, plus a host-side Eq. 4
``relative_change`` over the parameter leaves. Since the paper's protocol
spends nearly all wall-clock inside those local epochs, that dispatch
overhead sits directly on the hottest path.

``make_fused_round`` instead compiles the *whole* round into a single
donated jit:

    lax.scan over the T_i local epochs          (the Eq. 3-family schedule
        |                                        computed *traced* inside
        |  each epoch: vmap over K participants, the scan via ``lr_fn`` —
        |  inner lax.scan over that epoch's      default ``schedule.
        v  batches                               switch_lr``)
    Eq. 2 averaging / mixing (``aggregate_fn``)
    Eq. 4 relative_change, on-device            (``relative_change_traced``)

so a round costs one dispatch and exactly one host sync (the aux fetch at
the end). The schedule is pure *data* to the executable: ``lr_fn(sched, j,
T_i, ge, total)`` receives the per-round parameter pack ``sched`` (η_i,
decay, kind — built by ``api.LRSchedule.round_params``), the round length
``T_i``, the global-epoch offset and the run's epoch budget all as traced
arguments. Swapping between built-in schedules, a warmup ramping η^i per
round, a policy-aware budget update, or an ILE doubling of T_i therefore
reuse the compiled executables; only a changed *batch shape* recompiles
(the single-shot path bakes T_i from the staged-batch shape, i.e.
O(log T_max) compiles per run).

Staging T_i epochs of batches on device costs memory linear in T_i, and
the ILE rule doubles T_i. For large rounds ``CoLearner`` therefore caps
the staged window at the engine's ``chunk`` epochs and strings together
``make_fused_epochs`` executables (same in-scan schedule; j0/T_i/ge0/sched/
total passed traced so chunks never recompile as T_i grows) followed by
one ``make_fused_finalize`` executable (aggregation + Eq. 4 + opt reset).
The round is then ceil(T_i/chunk)+1 dispatches — still zero host syncs
until the final aux fetch.

``gated=True`` builds the divergence-triggered variants (Kamp et al.,
1807.03210, via ``api.DivergenceTrigger``): the executable additionally
takes the last *synced* shared model and a traced δ, computes the local-
model divergence on-device, and selects — still inside the one program —
between the aggregated state (sync) and the untouched local state (skip);
the sync decision comes back with the aux fetch so the host can bill the
wire only on synced rounds.

``masked=True`` builds the ragged-shard variants (heterogeneous data,
``repro.data`` scenario subsystem): the executable takes a traced
(K, n_batches) bool validity mask (``ParticipantData.batch_mask``) right
after the staged batches, and a masked batch slot is an identity carry —
params/opt pass through untouched and the slot is excluded from the epoch
loss mean — so participants with unequal shard sizes train on exactly
their own data inside one shape-stable executable (compile count stays
flat across mask values; asserted by ``round_latency.py --check-retrace``).

Backend API — shared by the simulation and pod paths:

  * simulation (single host, K vmapped participants): the defaults.
  * pod (K = pods on a multi-pod mesh): pass ``spmd_axis_name="pod"`` so
    the participant vmap is pinned to the ``pod`` mesh axis, and an
    aggregate fn built against the mesh (``api.Aggregator.
    make_aggregate_fn(codec, mesh=...)``) so the cross-pod traffic is the
    aggregator's actual wire pattern
    (``launch/steps.make_fused_round_step`` wires this for the dry-run).

``CoLearner(round_engine=FusedEngine(chunk)|PythonEngine())`` selects
between this engine and the reference loop; both produce the same
``RoundLog``/state transitions and are asserted equivalent to <=1e-5 in
``tests/test_engine.py``. The aggregation step is supplied as
``aggregate_fn(stacked, weights)`` by a ``repro.core.api`` aggregator
(codec roundtrip + participant mixing; ``weights`` is the traced per-round
mixing matrix, None for statically-uniform Eq. 2).

The end-of-round Eq. 2 step has its own fast path:
``make_fused_compressed_average`` (owned by ``api.FlatFusedInt8`` as its
fused mean) replaces the leafwise int8 roundtrip + separate mean with the
flat-buffer wire codec (``core.flatbuf``) and one fused
quantize->average->dequantize kernel (``kernels.comm``) over one
contiguous buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import averaging, flatbuf
from repro.core.schedule import (divergence_traced, relative_change_traced,
                                 switch_lr)
from repro.kernels import ops as kops
from repro.optim.optimizers import apply_updates


def stack_epoch_batches(per_epoch):
    """Stack a list of per-epoch (K, n_batches, ...) pytrees along a new
    leading epoch axis — the shape the fused epoch scan consumes."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_epoch)


def make_epoch_fn(loss_fn, opt, spmd_axis_name=None, masked=False):
    """One local epoch for all K participants (vmapped).

    Returns epoch_fn(stacked_params, opt_state, batches, lr) ->
    (stacked_params, opt_state, per-participant mean loss). This is THE
    local-epoch body: the python reference loop jits it directly and the
    fused engine scans over it, so the SGD semantics cannot diverge.

    ``masked=True`` is the ragged-shard variant: epoch_fn takes a trailing
    ``mask`` argument, a (K, n_batches) bool marking which batch slots hold
    a shard's real data (``ParticipantData.batch_mask``). A masked-out step
    is an identity carry — params and opt state pass through untouched and
    the slot's loss is excluded from the epoch mean — so shards with fewer
    batches than ``n_batches`` train on exactly their own data with no
    min-clamp. The mask is plain traced data: it never changes the compiled
    program, only which steps commit.
    """
    def one_participant(params, ostate, pbatches, lr, pmask=None):
        def step(carry, xs):
            params, ostate = carry
            if masked:
                batch, valid = xs
            else:
                batch = xs
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            upd, new_ostate = opt.update(grads, ostate, params, lr)
            new_params = apply_updates(params, upd)
            if masked:
                # identity carry on padding slots: nothing trains, nothing
                # counts — compute runs unconditionally so the executable
                # is shape-stable, the select commits only real steps
                keep = lambda new, old: jnp.where(valid, new, old)  # noqa: E731
                new_params = jax.tree.map(keep, new_params, params)
                new_ostate = jax.tree.map(keep, new_ostate, ostate)
                loss = jnp.where(valid, loss, 0.0)
            return (new_params, new_ostate), loss
        xs = (pbatches, pmask) if masked else pbatches
        (params, ostate), losses = jax.lax.scan(step, (params, ostate), xs)
        if masked:
            mean = losses.sum() / jnp.maximum(pmask.sum(), 1)
        else:
            mean = losses.mean()
        return params, ostate, mean

    vmap_kw = {"spmd_axis_name": spmd_axis_name} if spmd_axis_name else {}
    in_axes = (0, 0, 0, None) + ((0,) if masked else ())
    return jax.vmap(one_participant, in_axes=in_axes, **vmap_kw)


def _make_epoch_scan(epoch_fn, lr_fn, masked=False):
    """scan_epochs(params, opt, batches, j0, T_i, ge0, sched, total[, mask]):
    run the leading-dim epochs of ``batches`` with the schedule computed
    traced in-scan via ``lr_fn(sched, j, T_i, ge, total)``.

    j0 (round-local offset of the first staged epoch), T_i (the round's
    cycle denominator), ge0 (global epoch at round start), ``sched`` (the
    per-round schedule parameter pack) and ``total`` (the run's epoch
    budget) may all be traced, so one chunk executable is reused unchanged
    as T_i doubles, as the budget updates, and across built-in schedule
    swaps. ``masked=True``: a trailing (K, n_batches) bool ``mask``
    (ragged shards, also traced — see ``make_epoch_fn``) is applied every
    epoch.
    """
    def scan_epochs(stacked_params, opt_state, batches, j0, T_i,
                    global_epoch0, sched, total, mask=None):
        n = jax.tree.leaves(batches)[0].shape[0]

        def body(carry, xs):
            params, ostate = carry
            j, ebatches = xs
            lr = lr_fn(sched, j, T_i, global_epoch0 + j, total)
            if masked:
                params, ostate, loss = epoch_fn(params, ostate, ebatches,
                                                lr, mask)
            else:
                params, ostate, loss = epoch_fn(params, ostate, ebatches, lr)
            return (params, ostate), (loss, lr)

        return jax.lax.scan(body, (stacked_params, opt_state),
                            (j0 + jnp.arange(n), batches))
    return scan_epochs


def make_fused_compressed_average(*, block=256, impl="ref", mesh=None,
                                  axis="pod", weighted=False):
    """Eq. 2 fast path: int8 wire emulation + averaging as ONE buffer pass.

    Returns an ``average_fn`` (stacked tree -> stacked tree, every slot
    holding the mean) that replaces the leafwise pair ``compress_fn=
    make_compress_fn(...)`` + ``average_pjit``: the stacked params are
    flattened through the flat-buffer wire codec (``repro.core.flatbuf``)
    into one contiguous ``(K, N_pad)`` f32 buffer and a single
    ``quant_avg_dequant`` kernel (``repro.kernels.comm``) quantizes,
    averages, and dequantizes it blockwise — collapsing ~2 pallas launches
    + a pad/reshape per leaf + a separate whole-tree mean into one pass,
    with every leaf (however small) on the wire format.

    simulation path (``mesh=None``): the kernel sees all K rows at once.

    pod path (``mesh`` given): a ``shard_map`` over ``axis`` — each pod
    int8-roundtrips only its local row (its upload, exactly what the wire
    carries) and a single psum over the inter-pod axis aggregates the
    dequantized block payloads; only that one fused collective crosses the
    pod boundary, with ``flatbuf.wire_bytes`` giving the exact encoded
    size a production transport would move.

    ``weighted=True`` builds the example-count-weighted Eq. 2 variant
    (FedAvg's generalization for unequal shards): the returned fn takes a
    trailing traced length-K weight row (a normalized mixing-matrix row)
    and computes the weighted mean of the per-row dequantized payloads over
    the same single flat buffer — sim path via the quantize/dequantize
    kernels + one einsum, pod path still ONE psum of the weight-scaled
    local payload. Uniform weights reproduce the unweighted kernel's mean
    up to f32 summation order; the unweighted path itself is untouched
    (bit-compatible Eq. 2).

    The layout is recomputed per trace from static shapes only (free); the
    same tree structure always yields the same wire layout.
    """
    if mesh is None:
        if weighted:
            def average_w(stacked, wrow):
                layout = flatbuf.make_layout(stacked, block=block)
                buf = flatbuf.flatten(stacked, layout)
                q, scale, shape = kops.quantize_blockwise(buf, block=block,
                                                          impl=impl)
                dq = kops.dequantize_blockwise(q, scale, shape, impl=impl)
                mean = jnp.einsum("k,kn->n", wrow.astype(jnp.float32), dq)
                return flatbuf.unflatten_mean(mean, layout)
            return average_w

        def average(stacked):
            layout = flatbuf.make_layout(stacked, block=block)
            buf = flatbuf.flatten(stacked, layout)
            mean = kops.quant_avg_dequant(buf, block=block, impl=impl)
            return flatbuf.unflatten_mean(mean, layout)
        return average

    from repro.sharding import compat
    K = mesh.shape[axis]

    if weighted:
        def average_w(stacked, wrow):
            layout = flatbuf.make_layout(stacked, block=block)
            buf = flatbuf.flatten(stacked, layout)     # (K, N_pad) over pod

            def local_avg(lbuf, w):                    # (1, N_pad) per pod
                q, scale, _ = kops.quantize_blockwise(lbuf, block=block,
                                                      impl=impl)
                dq = q.astype(jnp.int32).astype(jnp.float32) * scale[:, None]
                k = jax.lax.axis_index(axis)
                s = jax.lax.psum(w[k].astype(jnp.float32) * dq, axis)
                return s.reshape(1, -1)[:, :layout.n_pad]

            avg = compat.shard_map(local_avg, mesh=mesh,
                                   in_specs=(P(axis, None), P()),
                                   out_specs=P(axis, None),
                                   check_vma=False)(buf, wrow)
            return flatbuf.unflatten(avg, layout)
        return average_w

    def average(stacked):
        layout = flatbuf.make_layout(stacked, block=block)
        buf = flatbuf.flatten(stacked, layout)         # (K, N_pad) over pod

        def local_avg(lbuf):                           # (1, N_pad) per pod
            q, scale, _ = kops.quantize_blockwise(lbuf, block=block,
                                                  impl=impl)
            dq = q.astype(jnp.int32).astype(jnp.float32) * scale[:, None]
            mean = jax.lax.psum(dq, axis) / K
            return mean.reshape(1, -1)[:, :layout.n_pad]

        avg = compat.shard_map(local_avg, mesh=mesh,
                               in_specs=(P(axis, None),),
                               out_specs=P(axis, None),
                               check_vma=False)(buf)
        return flatbuf.unflatten(avg, layout)
    return average


def as_aggregate_fn(aggregate_fn=None, compress_fn=None, average_fn=None):
    """Normalize the aggregation surface to ``aggregate(stacked, weights)``.

    New callers (``repro.core.api`` aggregators) pass ``aggregate_fn``
    directly — ``weights`` is the traced per-round mixing matrix (or None).
    Legacy callers keep the PR-2 pair: an optional stacked->stacked
    ``compress_fn`` upload transform followed by a one-argument
    ``average_fn`` (default ``averaging.average_pjit``); the pair is
    wrapped, ignoring weights. Passing both surfaces is an error.
    """
    if aggregate_fn is not None:
        if compress_fn is not None or average_fn is not None:
            raise ValueError(
                "pass aggregate_fn OR compress_fn/average_fn, not both")
        return aggregate_fn
    if average_fn is None:
        average_fn = averaging.average_pjit

    def aggregate(stacked, weights=None):
        del weights                     # legacy pair: statically uniform
        uploaded = compress_fn(stacked) if compress_fn is not None else stacked
        return average_fn(uploaded)
    return aggregate


def _make_finalize(opt, aggregate_fn):
    """Aggregation (Eq. 2 / mixing) + Eq. 4 metric + per-participant opt
    reset; ``agg_weights`` is the aggregator's traced mixing matrix."""
    def finalize(params, old_avg, agg_weights=None):
        averaged = aggregate_fn(params, agg_weights)
        new_avg = averaging.unstack_participant(averaged, 0)
        rel = relative_change_traced(new_avg, old_avg)
        # paper: local opt state is discarded; restart from the shared model
        fresh_opt = jax.vmap(opt.init)(averaged)
        return averaged, fresh_opt, rel, new_avg
    return finalize


def _default_gate(div, delta):
    """The default on-device sync gate (api.SyncPolicy.traced_should_sync)."""
    return div > delta


def _make_gated_finalize(opt, aggregate_fn, gate_fn=None):
    """Divergence-gated aggregation: compute the Kamp divergence of the
    locals from the last synced model, then branch — on-device, via a
    ``lax.cond`` on the traced ``do_sync`` from ``gate_fn(div, delta)``
    (the policy's ``traced_should_sync``, default ``div > delta``) —
    between the synced state (aggregated params, fresh opt, advanced
    reference) and the untouched local state (params/opt as trained,
    reference unchanged). The cond means a quiet round skips the
    aggregation COMPUTE (codec roundtrip, mean, opt re-init) too, not
    just the wire accounting; ``rel`` is the Eq. 4 metric on synced
    rounds and the divergence on quiet ones."""
    if gate_fn is None:
        gate_fn = _default_gate

    def gfinalize(params, opt_state, sync_ref, delta, agg_weights=None):
        div = divergence_traced(params, sync_ref)
        do_sync = gate_fn(div, delta)

        def sync_branch(operands):
            params, opt_state = operands
            averaged = aggregate_fn(params, agg_weights)
            new_avg = averaging.unstack_participant(averaged, 0)
            rel = relative_change_traced(new_avg, sync_ref)
            fresh_opt = jax.vmap(opt.init)(averaged)
            return averaged, fresh_opt, rel, new_avg

        def skip_branch(operands):
            params, opt_state = operands
            return params, opt_state, div, sync_ref

        out_p, out_o, rel, new_ref = jax.lax.cond(
            do_sync, sync_branch, skip_branch, (params, opt_state))
        return out_p, out_o, rel, div, do_sync, new_ref
    return gfinalize


def make_fused_round(loss_fn, opt, *, lr_fn=None, compress_fn=None,
                     spmd_axis_name=None, average_fn=None, aggregate_fn=None,
                     gated=False, gate_fn=None, masked=False, donate=True):
    """Build the single-executable round: epoch scan + aggregation + Eq. 4.

    loss_fn(params, batch) -> (loss, aux) for ONE participant.
    opt: optimizer triple (init/update) from ``repro.optim.optimizers``.
    lr_fn(sched, j, T_i, ge, total): the traced schedule (default
        ``schedule.switch_lr``, the lax.switch combinator every built-in
        ``api.LRSchedule`` shares).
    spmd_axis_name: e.g. "pod" to pin the participant vmap to a mesh axis.
    aggregate_fn(stacked, weights): the round-strategy aggregation (codec
        roundtrip + mixing, see ``repro.core.api``), traced into the same
        executable. Legacy alternative: ``compress_fn`` (optional stacked->
        stacked upload transform) + ``average_fn`` (one-arg Eq. 2 over
        stacked params, default ``averaging.average_pjit``).

    Returns round_fn(stacked_params, opt_state, batches, global_epoch0,
    sched, total, agg_weights=None) -> (aggregated_params, fresh_opt_state,
    aux) with aux = {losses (T,K), lrs (T,), rel (scalar), new_avg
    (unstacked slot-0 model)}. ``batches`` is a (T_i, K, n_batches, ...)
    pytree; ``global_epoch0``/``sched``/``total`` are traced (an int32
    offset, the schedule parameter pack, the int32 epoch budget) so
    neither an ELR step, a per-round η^i, a budget update, nor a built-in
    schedule swap ever retriggers compilation. ``agg_weights`` is the
    aggregator's traced (K, K) mixing matrix (None for statically-known
    schemes like Eq. 2). stacked_params and opt_state are donated.

    ``gated=True`` (divergence-triggered sync, ``api.DivergenceTrigger``):
    round_fn additionally takes ``(sync_ref, delta)`` after ``total`` —
    the last synced shared model and the traced threshold — and aux grows
    {div, synced}; on a quiet round (div <= delta) the returned state is
    the *local* post-epoch params/opt and ``new_avg`` stays ``sync_ref``.

    ``masked=True`` (ragged shards): round_fn takes a (K, n_batches) bool
    ``batch_mask`` right after ``batches`` — traced, so shard-size changes
    between runs never recompile — and the epoch scan applies the
    identity-carry masking of ``make_epoch_fn(masked=True)``.
    """
    if lr_fn is None:
        lr_fn = switch_lr
    scan_epochs = _make_epoch_scan(
        make_epoch_fn(loss_fn, opt, spmd_axis_name, masked=masked), lr_fn,
        masked=masked)
    agg = as_aggregate_fn(aggregate_fn, compress_fn, average_fn)

    if gated:
        gfinalize = _make_gated_finalize(opt, agg, gate_fn)

        def round_body(stacked_params, opt_state, batches, mask,
                       global_epoch0, sched, total, sync_ref, delta,
                       agg_weights=None):
            T_i = jax.tree.leaves(batches)[0].shape[0]
            (params, opt_out), (losses, lrs) = scan_epochs(
                stacked_params, opt_state, batches, 0, T_i, global_epoch0,
                sched, total, mask)
            out_p, out_o, rel, div, do_sync, new_ref = gfinalize(
                params, opt_out, sync_ref, delta, agg_weights)
            return out_p, out_o, {"losses": losses, "lrs": lrs, "rel": rel,
                                  "div": div, "synced": do_sync,
                                  "new_avg": new_ref}

        if masked:
            round_fn = round_body
        else:
            def round_fn(stacked_params, opt_state, batches, global_epoch0,
                         sched, total, sync_ref, delta, agg_weights=None):
                return round_body(stacked_params, opt_state, batches, None,
                                  global_epoch0, sched, total, sync_ref,
                                  delta, agg_weights)
    else:
        finalize = _make_finalize(opt, agg)

        def round_body(stacked_params, opt_state, batches, mask,
                       global_epoch0, sched, total, agg_weights=None):
            T_i = jax.tree.leaves(batches)[0].shape[0]
            # round entry: every slot holds the shared model w̄^{i-1}
            old_avg = averaging.unstack_participant(stacked_params, 0)
            (params, opt_out), (losses, lrs) = scan_epochs(
                stacked_params, opt_state, batches, 0, T_i, global_epoch0,
                sched, total, mask)
            del opt_out  # paper: local opt state is discarded at aggregation
            averaged, fresh_opt, rel, new_avg = finalize(params, old_avg,
                                                         agg_weights)
            return averaged, fresh_opt, {"losses": losses, "lrs": lrs,
                                         "rel": rel, "new_avg": new_avg}

        if masked:
            round_fn = round_body
        else:
            def round_fn(stacked_params, opt_state, batches, global_epoch0,
                         sched, total, agg_weights=None):
                return round_body(stacked_params, opt_state, batches, None,
                                  global_epoch0, sched, total, agg_weights)

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(round_fn, donate_argnums=donate_argnums)


def make_fused_epochs(loss_fn, opt, *, lr_fn=None, spmd_axis_name=None,
                      masked=False, donate=True):
    """Memory-bounded building block: a scan over ONE CHUNK of epochs.

    Returns epochs_fn(stacked_params, opt_state, batches, j0, T_i, ge0,
    sched, total) -> (stacked_params, opt_state, losses (C,K), lrs (C,)).
    j0/T_i/ge0/sched/total are traced, so the executable is shared across
    chunks, across T_i doublings, across budget updates, and across
    built-in schedule swaps; only a distinct chunk length C recompiles.
    ``masked=True``: epochs_fn takes a traced (K, n_batches) bool
    ``batch_mask`` right after ``batches`` (ragged shards, identity-carry
    masking — same contract as ``make_fused_round``).
    """
    if lr_fn is None:
        lr_fn = switch_lr
    scan_epochs = _make_epoch_scan(
        make_epoch_fn(loss_fn, opt, spmd_axis_name, masked=masked), lr_fn,
        masked=masked)

    def epochs_body(stacked_params, opt_state, batches, mask, j0, T_i,
                    global_epoch0, sched, total):
        (params, ostate), (losses, lrs) = scan_epochs(
            stacked_params, opt_state, batches, j0, T_i, global_epoch0,
            sched, total, mask)
        return params, ostate, losses, lrs

    if masked:
        epochs_fn = epochs_body
    else:
        def epochs_fn(stacked_params, opt_state, batches, j0, T_i,
                      global_epoch0, sched, total):
            return epochs_body(stacked_params, opt_state, batches, None,
                               j0, T_i, global_epoch0, sched, total)

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(epochs_fn, donate_argnums=donate_argnums)


def make_fused_finalize(opt, *, compress_fn=None, average_fn=None,
                        aggregate_fn=None, gated=False, gate_fn=None,
                        donate=True):
    """End-of-round executable for the chunked path: aggregation + Eq. 4 +
    opt reset. finalize_fn(params, old_avg, agg_weights=None) ->
    (aggregated, fresh_opt, rel, new_avg); ``params`` is donated. The
    aggregation surface matches ``make_fused_round`` (aggregate_fn or the
    legacy compress_fn/average_fn pair).

    ``gated=True``: finalize_fn(params, opt_state, sync_ref, delta,
    agg_weights=None) -> (params', opt', rel, div, synced, new_ref), the
    divergence-gated select of ``make_fused_round(gated=True)`` (params
    and opt_state donated)."""
    agg = as_aggregate_fn(aggregate_fn, compress_fn, average_fn)
    if gated:
        return jax.jit(_make_gated_finalize(opt, agg, gate_fn),
                       donate_argnums=(0, 1) if donate else ())
    return jax.jit(_make_finalize(opt, agg),
                   donate_argnums=(0,) if donate else ())
