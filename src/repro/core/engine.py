"""Fused round engine — one XLA executable per communication round.

The reference implementation of Algorithm 1 (``CoLearner.run_round`` with
the python engine) drives the T_i local epochs from a host loop: one jit
dispatch + one blocking ``device_get`` per epoch, plus a host-side Eq. 4
``relative_change`` over the parameter leaves. Since the paper's protocol
spends nearly all wall-clock inside those local epochs, that dispatch
overhead sits directly on the hottest path.

``make_fused_round`` instead compiles the *whole* round into a single
donated jit:

    lax.scan over the T_i local epochs          (the Eq. 3-family schedule
        |                                        computed *traced* inside
        |  each epoch: vmap over K participants, the scan via ``lr_fn`` —
        |  inner lax.scan over that epoch's      default ``schedule.
        v  batches                               switch_lr``)
    Eq. 2 averaging / mixing (``aggregate_fn``)
    Eq. 4 relative_change, on-device            (``relative_change_traced``)

so a round costs one dispatch and exactly one host sync (the aux fetch at
the end). The schedule is pure *data* to the executable: ``lr_fn(sched, j,
T_i, ge, total)`` receives the per-round parameter pack ``sched`` (η_i,
decay, kind — built by ``api.LRSchedule.round_params``), the round length
``T_i``, the global-epoch offset and the run's epoch budget all as traced
arguments. Swapping between built-in schedules, a warmup ramping η^i per
round, a policy-aware budget update, or an ILE doubling of T_i therefore
reuse the compiled executables; only a changed *batch shape* recompiles
(the single-shot path bakes T_i from the staged-batch shape, i.e.
O(log T_max) compiles per run).

Staging T_i epochs of batches on device costs memory linear in T_i, and
the ILE rule doubles T_i. For large rounds ``CoLearner`` therefore caps
the staged window at the engine's ``chunk`` epochs and strings together
``make_fused_epochs`` executables (same in-scan schedule; j0/T_i/ge0/sched/
total passed traced so chunks never recompile as T_i grows) followed by
one ``make_fused_finalize`` executable (aggregation + Eq. 4 + opt reset).
The round is then ceil(T_i/chunk)+1 dispatches — still zero host syncs
until the final aux fetch.

``gated=True`` builds the divergence-triggered variants (Kamp et al.,
1807.03210, via ``api.DivergenceTrigger``): the executable additionally
takes the last *synced* shared model and a traced δ, computes the local-
model divergence on-device, and selects — still inside the one program —
between the aggregated state (sync) and the untouched local state (skip);
the sync decision comes back with the aux fetch so the host can bill the
wire only on synced rounds.

``masked=True`` builds the ragged-shard variants (heterogeneous data,
``repro.data`` scenario subsystem): the executable takes a traced
(K, n_batches) bool validity mask (``ParticipantData.batch_mask``) right
after the staged batches, and a masked batch slot is an identity carry —
params/opt pass through untouched and the slot is excluded from the epoch
loss mean — so participants with unequal shard sizes train on exactly
their own data inside one shape-stable executable (compile count stays
flat across mask values; asserted by ``round_latency.py --check-retrace``).

``live=True`` builds the elastic-membership variants (``repro.core.
membership``): the executable takes a traced ``(K,)`` float 0/1 *liveness
row* right after the batch mask (or right after the batches when
unmasked). A dead participant slot is an identity carry through the WHOLE
round — the per-step commit gate is ``batch_mask & live`` so it trains
nothing, its loss is excluded from the epoch mean, and after aggregation
``select_live`` restores its own params/opt (it neither uploads nor
downloads; the aggregators renormalize the mixing matrix over the live
set host-side, so the mean never sees the dead rows either). The
shared-model slot is the FIRST LIVE row (``argmax`` of the traced row,
still on-device), not slot 0. Membership changes are pure traced data:
crash, rejoin, and flaky-slot rounds all reuse ONE compiled program
(asserted by ``round_latency.py --check-retrace`` scenario 4).

Backend API — shared by the simulation and pod paths:

  * simulation (single host, K vmapped participants): the defaults.
  * pod (K = pods on a multi-pod mesh): pass ``spmd_axis_name="pod"`` so
    the participant vmap is pinned to the ``pod`` mesh axis, and an
    aggregate fn built against the mesh (``api.Aggregator.
    make_aggregate_fn(codec, mesh=...)``) so the cross-pod traffic is the
    aggregator's actual wire pattern
    (``launch/steps.make_fused_round_step`` wires this for the dry-run).

``CoLearner(round_engine=FusedEngine(chunk)|PythonEngine())`` selects
between this engine and the reference loop; both produce the same
``RoundLog``/state transitions and are asserted equivalent to <=1e-5 in
``tests/test_engine.py``. The aggregation step is supplied as
``aggregate_fn(stacked, weights)`` by a ``repro.core.api`` aggregator
(codec roundtrip + participant mixing; ``weights`` is the traced per-round
mixing matrix, None for statically-uniform Eq. 2).

The end-of-round Eq. 2 step has its own fast path:
``make_fused_compressed_average`` (owned by ``api.FlatFusedInt8`` as its
fused mean) replaces the leafwise int8 roundtrip + separate mean with the
flat-buffer wire codec (``core.flatbuf``) and one fused
quantize->average->dequantize kernel (``kernels.comm``) over one
contiguous buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import averaging, flatbuf
from repro.core.schedule import (divergence_traced, relative_change_traced,
                                 switch_lr)
from repro.kernels import ops as kops
from repro.optim.optimizers import apply_updates


def stage(value, dtype=None):
    """Explicitly stage a host value (python scalar / numpy array) onto
    device — the one kind of H2D ``analysis.guards.no_transfer`` allows.
    Device arrays pass through untouched, so staging is idempotent."""
    if isinstance(value, jax.Array):
        return value
    return jax.device_put(np.asarray(value, dtype))


def stack_epoch_batches(per_epoch):
    """Stack a list of per-epoch (K, n_batches, ...) pytrees along a new
    leading epoch axis — the shape the fused epoch scan consumes.

    Host (numpy) leaves are stacked host-side and staged with ONE
    explicit ``jax.device_put`` per leaf — the round's designated staging
    transfer, legal under ``analysis.guards.no_transfer()``. A
    device-resident stack (``jnp.stack`` over numpy inputs) would instead
    issue an *implicit* transfer per epoch per leaf. Device-resident
    inputs stack on device untouched."""
    def stack(*xs):
        if all(isinstance(x, np.ndarray) for x in xs):
            return jax.device_put(np.stack(xs))
        return jnp.stack(xs)
    return jax.tree.map(stack, *per_epoch)


def select_live(live_row, new, old):
    """Per-slot identity carry over a stacked (K, ...) pytree pair: keep
    ``new`` on live rows, ``old`` on dead ones. ``live_row`` is the traced
    0/1 float (K,) liveness row."""
    alive = live_row.astype(bool)

    def sel(n, o):
        return jnp.where(alive.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
    return jax.tree.map(sel, new, old)


def first_live(live_row):
    """Traced index of the first live slot — the shared-model row under
    elastic membership (slot 0 may be dead)."""
    return jnp.argmax(live_row)


def unstack_first_live(stacked, live_row):
    """Unstack the first LIVE participant's model (traced dynamic index)."""
    idx = first_live(live_row)
    return jax.tree.map(lambda t: t[idx], stacked)


def make_epoch_fn(loss_fn, opt, spmd_axis_name=None, masked=False,
                  live=False):
    """One local epoch for all K participants (vmapped).

    Returns epoch_fn(stacked_params, opt_state, batches, lr) ->
    (stacked_params, opt_state, per-participant mean loss). This is THE
    local-epoch body: the python reference loop jits it directly and the
    fused engine scans over it, so the SGD semantics cannot diverge.

    ``masked=True`` is the ragged-shard variant: epoch_fn takes a trailing
    ``mask`` argument, a (K, n_batches) bool marking which batch slots hold
    a shard's real data (``ParticipantData.batch_mask``). A masked-out step
    is an identity carry — params and opt state pass through untouched and
    the slot's loss is excluded from the epoch mean — so shards with fewer
    batches than ``n_batches`` train on exactly their own data with no
    min-clamp. The mask is plain traced data: it never changes the compiled
    program, only which steps commit.

    ``live=True`` is the elastic-membership variant: epoch_fn takes a
    trailing traced (K,) 0/1 float ``live_row`` (after ``mask`` when both
    are on). A dead participant's commit gate is forced off for every step
    — identity carry on params/opt — and its epoch loss is 0 with a zero
    denominator weight, so it contributes nothing anywhere. Liveness is
    traced data, exactly like the batch mask: membership changes never
    recompile.
    """
    def participant_body(params, ostate, pbatches, lr, pmask, palive):
        alive = None if palive is None else palive.astype(bool)

        def step(carry, xs):
            params, ostate = carry
            if masked:
                batch, valid = xs
            else:
                batch = xs
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            upd, new_ostate = opt.update(grads, ostate, params, lr)
            new_params = apply_updates(params, upd)
            # identity carry on padding slots / dead participants: nothing
            # trains, nothing counts — compute runs unconditionally so the
            # executable is shape-stable, the select commits only real steps
            gate = None
            if masked:
                gate = valid
            if alive is not None:
                gate = alive if gate is None else (gate & alive)
            if gate is not None:
                keep = lambda new, old: jnp.where(gate, new, old)  # noqa: E731
                new_params = jax.tree.map(keep, new_params, params)
                new_ostate = jax.tree.map(keep, new_ostate, ostate)
                loss = jnp.where(gate, loss, 0.0)
            return (new_params, new_ostate), loss
        xs = (pbatches, pmask) if masked else pbatches
        (params, ostate), losses = jax.lax.scan(step, (params, ostate), xs)
        if masked or live:
            denom = pmask.sum() if masked else losses.size
            if live:
                denom = denom * palive
            mean = losses.sum() / jnp.maximum(denom, 1)
        else:
            mean = losses.mean()
        return params, ostate, mean

    # explicit signature per variant so vmap's positional in_axes line up
    if masked and live:
        def one_participant(params, ostate, pbatches, lr, pmask, palive):
            return participant_body(params, ostate, pbatches, lr, pmask,
                                    palive)
    elif masked:
        def one_participant(params, ostate, pbatches, lr, pmask):
            return participant_body(params, ostate, pbatches, lr, pmask, None)
    elif live:
        def one_participant(params, ostate, pbatches, lr, palive):
            return participant_body(params, ostate, pbatches, lr, None,
                                    palive)
    else:
        def one_participant(params, ostate, pbatches, lr):
            return participant_body(params, ostate, pbatches, lr, None, None)

    vmap_kw = {"spmd_axis_name": spmd_axis_name} if spmd_axis_name else {}
    in_axes = ((0, 0, 0, None) + ((0,) if masked else ())
               + ((0,) if live else ()))
    return jax.vmap(one_participant, in_axes=in_axes, **vmap_kw)


def _make_epoch_scan(epoch_fn, lr_fn, masked=False, live=False):
    """scan_epochs(params, opt, batches, j0, T_i, ge0, sched, total[, mask]
    [, live_row]): run the leading-dim epochs of ``batches`` with the
    schedule computed traced in-scan via ``lr_fn(sched, j, T_i, ge, total)``.

    j0 (round-local offset of the first staged epoch), T_i (the round's
    cycle denominator), ge0 (global epoch at round start), ``sched`` (the
    per-round schedule parameter pack) and ``total`` (the run's epoch
    budget) may all be traced, so one chunk executable is reused unchanged
    as T_i doubles, as the budget updates, and across built-in schedule
    swaps. ``masked=True``: a trailing (K, n_batches) bool ``mask``
    (ragged shards, also traced — see ``make_epoch_fn``) is applied every
    epoch. ``live=True``: a trailing traced (K,) liveness row is applied
    every epoch (elastic membership — dead rows are identity carries).
    """
    def scan_epochs(stacked_params, opt_state, batches, j0, T_i,
                    global_epoch0, sched, total, mask=None, live_row=None):
        n = jax.tree.leaves(batches)[0].shape[0]
        extra = (((mask,) if masked else ())
                 + ((live_row,) if live else ()))

        def body(carry, xs):
            params, ostate = carry
            j, ebatches = xs
            lr = lr_fn(sched, j, T_i, global_epoch0 + j, total)
            params, ostate, loss = epoch_fn(params, ostate, ebatches, lr,
                                            *extra)
            return (params, ostate), (loss, lr)

        return jax.lax.scan(body, (stacked_params, opt_state),
                            (j0 + jnp.arange(n), batches))
    return scan_epochs


def make_fused_compressed_average(*, block=256, impl="ref", bits=8,
                                  mesh=None, axis="pod", weighted=False,
                                  stateful=False):
    """Eq. 2 fast path: quantized wire emulation + averaging as ONE pass.

    Returns an ``average_fn`` (stacked tree -> stacked tree, every slot
    holding the mean) that replaces the leafwise pair ``compress_fn=
    make_compress_fn(...)`` + ``average_pjit``: the stacked params are
    flattened through the flat-buffer wire codec (``repro.core.flatbuf``)
    into one contiguous ``(K, N_pad)`` f32 buffer and a single
    ``quant_avg_dequant`` kernel (``repro.kernels.comm``) quantizes,
    averages, and dequantizes it blockwise — collapsing ~2 pallas launches
    + a pad/reshape per leaf + a separate whole-tree mean into one pass,
    with every leaf (however small) on the wire format.

    simulation path (``mesh=None``): the kernel sees all K rows at once.

    pod path (``mesh`` given): a ``shard_map`` over ``axis`` — each pod
    int8-roundtrips only its local row (its upload, exactly what the wire
    carries) and a single psum over the inter-pod axis aggregates the
    dequantized block payloads; only that one fused collective crosses the
    pod boundary, with ``flatbuf.wire_bytes`` giving the exact encoded
    size a production transport would move.

    ``weighted=True`` builds the example-count-weighted Eq. 2 variant
    (FedAvg's generalization for unequal shards): the returned fn takes a
    trailing traced length-K weight row (a normalized mixing-matrix row)
    and computes the weighted mean of the per-row dequantized payloads over
    the same single flat buffer — sim path via the quantize/dequantize
    kernels + one einsum, pod path still ONE psum of the weight-scaled
    local payload. Uniform weights reproduce the unweighted kernel's mean
    up to f32 summation order; the unweighted path itself is untouched
    (bit-compatible Eq. 2).

    ``bits`` ∈ {8, 4, 1} selects the wire precision (one code path —
    ``kernels.quantize.unpack_codes`` is the identity at 8 bits, so the
    int8 payloads stay bit-compatible). ``stateful=True`` builds the
    error-feedback variants: the returned fn takes the ``(K, N_pad)`` f32
    residual buffer as its LAST argument and returns ``(mean_tree,
    new_residual)`` — sim path via the fused ``quant_avg_dequant_ef``
    kernel (uniform) or the quantize/dequantize pair (weighted), pod path
    still ONE psum with each pod's residual staying resident on that pod.

    The layout is recomputed per trace from static shapes only (free); the
    same tree structure always yields the same wire layout.
    """
    if mesh is None:
        if stateful:
            if weighted:
                def average_w_ef(stacked, wrow, residual):
                    layout = flatbuf.make_layout(stacked, block=block)
                    buf = flatbuf.flatten(stacked, layout)
                    y = buf + residual
                    q, scale, shape = kops.quantize_blockwise(
                        y, block=block, bits=bits, impl=impl)
                    dq = kops.dequantize_blockwise(q, scale, shape,
                                                   bits=bits, impl=impl)
                    mean = jnp.einsum("k,kn->n", wrow.astype(jnp.float32),
                                      dq)
                    return flatbuf.unflatten_mean(mean, layout), y - dq
                return average_w_ef

            def average_ef(stacked, residual):
                layout = flatbuf.make_layout(stacked, block=block)
                buf = flatbuf.flatten(stacked, layout)
                mean, new_res = kops.quant_avg_dequant_ef(
                    buf, residual, block=block, bits=bits, impl=impl)
                return flatbuf.unflatten_mean(mean, layout), new_res
            return average_ef

        if weighted:
            def average_w(stacked, wrow):
                layout = flatbuf.make_layout(stacked, block=block)
                buf = flatbuf.flatten(stacked, layout)
                q, scale, shape = kops.quantize_blockwise(buf, block=block,
                                                          bits=bits,
                                                          impl=impl)
                dq = kops.dequantize_blockwise(q, scale, shape, bits=bits,
                                               impl=impl)
                mean = jnp.einsum("k,kn->n", wrow.astype(jnp.float32), dq)
                return flatbuf.unflatten_mean(mean, layout)
            return average_w

        def average(stacked):
            layout = flatbuf.make_layout(stacked, block=block)
            buf = flatbuf.flatten(stacked, layout)
            mean = kops.quant_avg_dequant(buf, block=block, bits=bits,
                                          impl=impl)
            return flatbuf.unflatten_mean(mean, layout)
        return average

    from repro.kernels.quantize import unpack_codes
    from repro.sharding import compat
    K = mesh.shape[axis]

    def _local_dequant(q, scale):
        # unpack_codes is the identity at bits=8, so this is the exact
        # expression the pre-bits pod path computed (bit-compatible)
        qq = unpack_codes(q, bits)
        return qq.astype(jnp.int32).astype(jnp.float32) * scale[:, None]

    if stateful:
        if weighted:
            def average_w_ef(stacked, wrow, residual):
                layout = flatbuf.make_layout(stacked, block=block)
                buf = flatbuf.flatten(stacked, layout)

                def local_avg(lbuf, w, lres):          # (1, N_pad) per pod
                    y = lbuf + lres
                    q, scale, _ = kops.quantize_blockwise(
                        y, block=block, bits=bits, impl=impl)
                    dq = _local_dequant(q, scale).reshape(
                        1, -1)[:, :layout.n_pad]
                    k = jax.lax.axis_index(axis)
                    s = jax.lax.psum(w[k].astype(jnp.float32) * dq, axis)
                    return s, y - dq

                avg, new_res = compat.shard_map(
                    local_avg, mesh=mesh,
                    in_specs=(P(axis, None), P(), P(axis, None)),
                    out_specs=(P(axis, None), P(axis, None)),
                    check_vma=False)(buf, wrow, residual)
                return flatbuf.unflatten(avg, layout), new_res
            return average_w_ef

        def average_ef(stacked, residual):
            layout = flatbuf.make_layout(stacked, block=block)
            buf = flatbuf.flatten(stacked, layout)

            def local_avg(lbuf, lres):                 # (1, N_pad) per pod
                y = lbuf + lres
                q, scale, _ = kops.quantize_blockwise(
                    y, block=block, bits=bits, impl=impl)
                dq = _local_dequant(q, scale).reshape(
                    1, -1)[:, :layout.n_pad]
                mean = jax.lax.psum(dq, axis) / K
                return mean, y - dq

            avg, new_res = compat.shard_map(
                local_avg, mesh=mesh,
                in_specs=(P(axis, None), P(axis, None)),
                out_specs=(P(axis, None), P(axis, None)),
                check_vma=False)(buf, residual)
            return flatbuf.unflatten(avg, layout), new_res
        return average_ef

    if weighted:
        def average_w(stacked, wrow):
            layout = flatbuf.make_layout(stacked, block=block)
            buf = flatbuf.flatten(stacked, layout)     # (K, N_pad) over pod

            def local_avg(lbuf, w):                    # (1, N_pad) per pod
                q, scale, _ = kops.quantize_blockwise(lbuf, block=block,
                                                      bits=bits, impl=impl)
                dq = _local_dequant(q, scale)
                k = jax.lax.axis_index(axis)
                s = jax.lax.psum(w[k].astype(jnp.float32) * dq, axis)
                return s.reshape(1, -1)[:, :layout.n_pad]

            avg = compat.shard_map(local_avg, mesh=mesh,
                                   in_specs=(P(axis, None), P()),
                                   out_specs=P(axis, None),
                                   check_vma=False)(buf, wrow)
            return flatbuf.unflatten(avg, layout)
        return average_w

    def average(stacked):
        layout = flatbuf.make_layout(stacked, block=block)
        buf = flatbuf.flatten(stacked, layout)         # (K, N_pad) over pod

        def local_avg(lbuf):                           # (1, N_pad) per pod
            q, scale, _ = kops.quantize_blockwise(lbuf, block=block,
                                                  bits=bits, impl=impl)
            dq = _local_dequant(q, scale)
            mean = jax.lax.psum(dq, axis) / K
            return mean.reshape(1, -1)[:, :layout.n_pad]

        avg = compat.shard_map(local_avg, mesh=mesh,
                               in_specs=(P(axis, None),),
                               out_specs=P(axis, None),
                               check_vma=False)(buf)
        return flatbuf.unflatten(avg, layout)
    return average


def as_aggregate_fn(aggregate_fn=None, compress_fn=None, average_fn=None):
    """Normalize the aggregation surface to ``aggregate(stacked, weights)``.

    New callers (``repro.core.api`` aggregators) pass ``aggregate_fn``
    directly — ``weights`` is the traced per-round mixing matrix (or None).
    Legacy callers keep the PR-2 pair: an optional stacked->stacked
    ``compress_fn`` upload transform followed by a one-argument
    ``average_fn`` (default ``averaging.average_pjit``); the pair is
    wrapped, ignoring weights. Passing both surfaces is an error.
    """
    if aggregate_fn is not None:
        if compress_fn is not None or average_fn is not None:
            raise ValueError(
                "pass aggregate_fn OR compress_fn/average_fn, not both")
        return aggregate_fn
    if average_fn is None:
        average_fn = averaging.average_pjit

    def aggregate(stacked, weights=None):
        del weights                     # legacy pair: statically uniform
        uploaded = compress_fn(stacked) if compress_fn is not None else stacked
        return average_fn(uploaded)
    return aggregate


def _make_finalize(opt, aggregate_fn, live=False, stateful=False):
    """Aggregation (Eq. 2 / mixing) + Eq. 4 metric + per-participant opt
    reset; ``agg_weights`` is the aggregator's traced mixing matrix.

    ``live=True`` (elastic membership): finalize takes ``(params,
    opt_state, old_avg, live_row, agg_weights)`` — after aggregating, dead
    rows are restored to their own params/opt (identity carry: a dead
    participant neither uploads nor downloads) and ``new_avg`` is read
    from the first LIVE row (the mixing matrix gives every live row the
    same mixed model for averaging schemes; gossip rows differ but the
    shared-model reference is by convention the first live row).

    ``stateful=True`` (error-feedback codec and/or stateful aggregator —
    the D² correction rides the same slot): the round state enters right
    after ``opt_state`` (right after ``params`` on the opt-free static
    variant, since the paper discards the local opt state there), the
    aggregate is ``aggregate_fn(params, agg_weights, residual) -> (mixed,
    new_residual)``, dead rows additionally FREEZE their state rows
    (they neither uploaded nor mixed), and the new state is appended to
    the outputs. Everything here is generic over the state PYTREE — the
    codec residual, the D² correction tree, or a dict of both.
    """
    if live:
        if stateful:
            def finalize_live_ef(params, opt_state, residual, old_avg,
                                 live_row, agg_weights=None):
                averaged, new_res = aggregate_fn(params, agg_weights,
                                                 residual)
                new_avg = unstack_first_live(averaged, live_row)
                rel = relative_change_traced(new_avg, old_avg)
                fresh_opt = jax.vmap(opt.init)(averaged)
                averaged = select_live(live_row, averaged, params)
                fresh_opt = select_live(live_row, fresh_opt, opt_state)
                new_res = select_live(live_row, new_res, residual)
                return averaged, fresh_opt, rel, new_avg, new_res
            return finalize_live_ef

        def finalize_live(params, opt_state, old_avg, live_row,
                          agg_weights=None):
            averaged = aggregate_fn(params, agg_weights)
            new_avg = unstack_first_live(averaged, live_row)
            rel = relative_change_traced(new_avg, old_avg)
            fresh_opt = jax.vmap(opt.init)(averaged)
            averaged = select_live(live_row, averaged, params)
            fresh_opt = select_live(live_row, fresh_opt, opt_state)
            return averaged, fresh_opt, rel, new_avg
        return finalize_live

    if stateful:
        def finalize_ef(params, residual, old_avg, agg_weights=None):
            averaged, new_res = aggregate_fn(params, agg_weights, residual)
            new_avg = averaging.unstack_participant(averaged, 0)
            rel = relative_change_traced(new_avg, old_avg)
            fresh_opt = jax.vmap(opt.init)(averaged)
            return averaged, fresh_opt, rel, new_avg, new_res
        return finalize_ef

    def finalize(params, old_avg, agg_weights=None):
        averaged = aggregate_fn(params, agg_weights)
        new_avg = averaging.unstack_participant(averaged, 0)
        rel = relative_change_traced(new_avg, old_avg)
        # paper: local opt state is discarded; restart from the shared model
        fresh_opt = jax.vmap(opt.init)(averaged)
        return averaged, fresh_opt, rel, new_avg
    return finalize


def _default_gate(div, delta):
    """The default on-device sync gate (api.SyncPolicy.traced_should_sync)."""
    return div > delta


def _make_gated_finalize(opt, aggregate_fn, gate_fn=None, live=False,
                         stateful=False):
    """Divergence-gated aggregation: compute the Kamp divergence of the
    locals from the last synced model, then branch — on-device, via a
    ``lax.cond`` on the traced ``do_sync`` from ``gate_fn(div, delta)``
    (the policy's ``traced_should_sync``, default ``div > delta``) —
    between the synced state (aggregated params, fresh opt, advanced
    reference) and the untouched local state (params/opt as trained,
    reference unchanged). The cond means a quiet round skips the
    aggregation COMPUTE (codec roundtrip, mean, opt re-init) too, not
    just the wire accounting; ``rel`` is the Eq. 4 metric on synced
    rounds and the divergence on quiet ones.

    ``live=True`` (elastic membership): gfinalize takes the traced
    ``live_row`` after ``delta``; the divergence is measured over live
    rows only, and in the sync branch dead rows keep their own params/opt
    (identity carry) while ``new_avg`` comes from the first LIVE row.

    ``stateful=True`` (error-feedback codec and/or stateful aggregator):
    gfinalize takes the round state right after ``opt_state``, the
    aggregate is ``aggregate_fn(params, agg_weights, residual) -> (mixed,
    new_residual)``, a quiet round carries the state UNCHANGED through
    the skip branch (nothing was quantized or mixed, so no memory moves),
    dead rows freeze theirs, and the new state is appended LAST to the
    outputs."""
    if gate_fn is None:
        gate_fn = _default_gate

    if live:
        if stateful:
            def gfinalize_live_ef(params, opt_state, residual, sync_ref,
                                  delta, live_row, agg_weights=None):
                div = divergence_traced(params, sync_ref, live_row)
                do_sync = gate_fn(div, delta)

                def sync_branch(operands):
                    params, opt_state, residual = operands
                    averaged, new_res = aggregate_fn(params, agg_weights,
                                                     residual)
                    new_avg = unstack_first_live(averaged, live_row)
                    rel = relative_change_traced(new_avg, sync_ref)
                    fresh_opt = jax.vmap(opt.init)(averaged)
                    averaged = select_live(live_row, averaged, params)
                    fresh_opt = select_live(live_row, fresh_opt, opt_state)
                    new_res = select_live(live_row, new_res, residual)
                    return averaged, fresh_opt, rel, new_avg, new_res

                def skip_branch(operands):
                    params, opt_state, residual = operands
                    return params, opt_state, div, sync_ref, residual

                out_p, out_o, rel, new_ref, out_res = jax.lax.cond(
                    do_sync, sync_branch, skip_branch,
                    (params, opt_state, residual))
                return out_p, out_o, rel, div, do_sync, new_ref, out_res
            return gfinalize_live_ef

        def gfinalize_live(params, opt_state, sync_ref, delta, live_row,
                           agg_weights=None):
            div = divergence_traced(params, sync_ref, live_row)
            do_sync = gate_fn(div, delta)

            def sync_branch(operands):
                params, opt_state = operands
                averaged = aggregate_fn(params, agg_weights)
                new_avg = unstack_first_live(averaged, live_row)
                rel = relative_change_traced(new_avg, sync_ref)
                fresh_opt = jax.vmap(opt.init)(averaged)
                averaged = select_live(live_row, averaged, params)
                fresh_opt = select_live(live_row, fresh_opt, opt_state)
                return averaged, fresh_opt, rel, new_avg

            def skip_branch(operands):
                params, opt_state = operands
                return params, opt_state, div, sync_ref

            out_p, out_o, rel, new_ref = jax.lax.cond(
                do_sync, sync_branch, skip_branch, (params, opt_state))
            return out_p, out_o, rel, div, do_sync, new_ref
        return gfinalize_live

    if stateful:
        def gfinalize_ef(params, opt_state, residual, sync_ref, delta,
                         agg_weights=None):
            div = divergence_traced(params, sync_ref)
            do_sync = gate_fn(div, delta)

            def sync_branch(operands):
                params, opt_state, residual = operands
                averaged, new_res = aggregate_fn(params, agg_weights,
                                                 residual)
                new_avg = averaging.unstack_participant(averaged, 0)
                rel = relative_change_traced(new_avg, sync_ref)
                fresh_opt = jax.vmap(opt.init)(averaged)
                return averaged, fresh_opt, rel, new_avg, new_res

            def skip_branch(operands):
                params, opt_state, residual = operands
                return params, opt_state, div, sync_ref, residual

            out_p, out_o, rel, new_ref, out_res = jax.lax.cond(
                do_sync, sync_branch, skip_branch,
                (params, opt_state, residual))
            return out_p, out_o, rel, div, do_sync, new_ref, out_res
        return gfinalize_ef

    def gfinalize(params, opt_state, sync_ref, delta, agg_weights=None):
        div = divergence_traced(params, sync_ref)
        do_sync = gate_fn(div, delta)

        def sync_branch(operands):
            params, opt_state = operands
            averaged = aggregate_fn(params, agg_weights)
            new_avg = averaging.unstack_participant(averaged, 0)
            rel = relative_change_traced(new_avg, sync_ref)
            fresh_opt = jax.vmap(opt.init)(averaged)
            return averaged, fresh_opt, rel, new_avg

        def skip_branch(operands):
            params, opt_state = operands
            return params, opt_state, div, sync_ref

        out_p, out_o, rel, new_ref = jax.lax.cond(
            do_sync, sync_branch, skip_branch, (params, opt_state))
        return out_p, out_o, rel, div, do_sync, new_ref
    return gfinalize


def _bind_mask_live(body, masked, live, stateful=False):
    """Adapt a ``body(params, opt, residual, batches, mask, live_row,
    *rest)`` to the public signature for the (masked, live, stateful)
    combination: the codec residual appears right after ``opt_state`` when
    ``stateful`` (bound to None otherwise), and enabled mask/live features
    appear as positional args right after ``batches`` (mask first, then
    live_row); disabled ones are bound to None."""
    if masked and live:
        bound = body
    elif masked:
        def bound(stacked_params, opt_state, residual, batches, mask,
                  *rest, **kw):
            return body(stacked_params, opt_state, residual, batches, mask,
                        None, *rest, **kw)
    elif live:
        def bound(stacked_params, opt_state, residual, batches, live_row,
                  *rest, **kw):
            return body(stacked_params, opt_state, residual, batches, None,
                        live_row, *rest, **kw)
    else:
        def bound(stacked_params, opt_state, residual, batches, *rest, **kw):
            return body(stacked_params, opt_state, residual, batches, None,
                        None, *rest, **kw)
    if stateful:
        return bound

    def fn(stacked_params, opt_state, batches, *rest, **kw):
        return bound(stacked_params, opt_state, None, batches, *rest, **kw)
    return fn


def make_fused_round(loss_fn, opt, *, lr_fn=None, compress_fn=None,
                     spmd_axis_name=None, average_fn=None, aggregate_fn=None,
                     gated=False, gate_fn=None, masked=False, live=False,
                     stateful=False, donate=True):
    """Build the single-executable round: epoch scan + aggregation + Eq. 4.

    loss_fn(params, batch) -> (loss, aux) for ONE participant.
    opt: optimizer triple (init/update) from ``repro.optim.optimizers``.
    lr_fn(sched, j, T_i, ge, total): the traced schedule (default
        ``schedule.switch_lr``, the lax.switch combinator every built-in
        ``api.LRSchedule`` shares).
    spmd_axis_name: e.g. "pod" to pin the participant vmap to a mesh axis.
    aggregate_fn(stacked, weights): the round-strategy aggregation (codec
        roundtrip + mixing, see ``repro.core.api``), traced into the same
        executable. Legacy alternative: ``compress_fn`` (optional stacked->
        stacked upload transform) + ``average_fn`` (one-arg Eq. 2 over
        stacked params, default ``averaging.average_pjit``).

    Returns round_fn(stacked_params, opt_state, batches, global_epoch0,
    sched, total, agg_weights=None) -> (aggregated_params, fresh_opt_state,
    aux) with aux = {losses (T,K), lrs (T,), rel (scalar), new_avg
    (unstacked slot-0 model)}. ``batches`` is a (T_i, K, n_batches, ...)
    pytree; ``global_epoch0``/``sched``/``total`` are traced (an int32
    offset, the schedule parameter pack, the int32 epoch budget) so
    neither an ELR step, a per-round η^i, a budget update, nor a built-in
    schedule swap ever retriggers compilation. ``agg_weights`` is the
    aggregator's traced (K, K) mixing matrix (None for statically-known
    schemes like Eq. 2). stacked_params and opt_state are donated.

    ``gated=True`` (divergence-triggered sync, ``api.DivergenceTrigger``):
    round_fn additionally takes ``(sync_ref, delta)`` after ``total`` —
    the last synced shared model and the traced threshold — and aux grows
    {div, synced}; on a quiet round (div <= delta) the returned state is
    the *local* post-epoch params/opt and ``new_avg`` stays ``sync_ref``.

    ``masked=True`` (ragged shards): round_fn takes a (K, n_batches) bool
    ``batch_mask`` right after ``batches`` — traced, so shard-size changes
    between runs never recompile — and the epoch scan applies the
    identity-carry masking of ``make_epoch_fn(masked=True)``.

    ``live=True`` (elastic membership): round_fn takes a traced (K,) 0/1
    float ``live_row`` right after ``batches`` (after ``batch_mask`` when
    both are on). Dead rows are identity carries end-to-end — no training,
    no upload, no download (own params/opt restored after aggregation) —
    the entry/exit shared model is read from the first LIVE row, and in
    the gated variant the divergence is live-masked. Membership changes
    are traced data: crash/rejoin/flaky rounds never recompile.

    ``stateful=True`` (error-feedback codec): round_fn takes the traced
    per-participant residual pytree right after ``opt_state`` —
    ``aggregate_fn`` must be the 3-arg stateful form ``(stacked, weights,
    residual) -> (mixed, new_residual)`` — the residual is donated with
    params/opt, and aux grows ``{"residual": new_residual}``. Dead rows
    freeze their residual; a gated quiet round carries it unchanged.
    """
    if lr_fn is None:
        lr_fn = switch_lr
    scan_epochs = _make_epoch_scan(
        make_epoch_fn(loss_fn, opt, spmd_axis_name, masked=masked,
                      live=live), lr_fn, masked=masked, live=live)
    agg = as_aggregate_fn(aggregate_fn, compress_fn, average_fn)

    if gated:
        gfinalize = _make_gated_finalize(opt, agg, gate_fn, live=live,
                                         stateful=stateful)

        def round_body(stacked_params, opt_state, residual, batches, mask,
                       live_row, global_epoch0, sched, total, sync_ref,
                       delta, agg_weights=None):
            T_i = jax.tree.leaves(batches)[0].shape[0]
            (params, opt_out), (losses, lrs) = scan_epochs(
                stacked_params, opt_state, batches, 0, T_i, global_epoch0,
                sched, total, mask, live_row)
            res_in = (residual,) if stateful else ()
            if live:
                out = gfinalize(params, opt_out, *res_in, sync_ref, delta,
                                live_row, agg_weights)
            else:
                out = gfinalize(params, opt_out, *res_in, sync_ref, delta,
                                agg_weights)
            if stateful:
                out_p, out_o, rel, div, do_sync, new_ref, out_res = out
            else:
                out_p, out_o, rel, div, do_sync, new_ref = out
            aux = {"losses": losses, "lrs": lrs, "rel": rel, "div": div,
                   "synced": do_sync, "new_avg": new_ref}
            if stateful:
                aux["residual"] = out_res
            return out_p, out_o, aux
    else:
        finalize = _make_finalize(opt, agg, live=live, stateful=stateful)

        def round_body(stacked_params, opt_state, residual, batches, mask,
                       live_row, global_epoch0, sched, total,
                       agg_weights=None):
            T_i = jax.tree.leaves(batches)[0].shape[0]
            if live:
                # round entry: every LIVE slot holds the shared model
                # w̄^{i-1} (warm-join restores joined slots host-side
                # before the round executes), so read the first live row
                old_avg = unstack_first_live(stacked_params, live_row)
            else:
                # round entry: every slot holds the shared model w̄^{i-1}
                old_avg = averaging.unstack_participant(stacked_params, 0)
            (params, opt_out), (losses, lrs) = scan_epochs(
                stacked_params, opt_state, batches, 0, T_i, global_epoch0,
                sched, total, mask, live_row)
            res_in = (residual,) if stateful else ()
            if live:
                # dead rows carry their opt state through the round
                out = finalize(params, opt_out, *res_in, old_avg, live_row,
                               agg_weights)
            else:
                del opt_out  # paper: local opt state is discarded at agg
                out = finalize(params, *res_in, old_avg, agg_weights)
            if stateful:
                averaged, fresh_opt, rel, new_avg, new_res = out
            else:
                averaged, fresh_opt, rel, new_avg = out
            aux = {"losses": losses, "lrs": lrs, "rel": rel,
                   "new_avg": new_avg}
            if stateful:
                aux["residual"] = new_res
            return averaged, fresh_opt, aux

    round_fn = _bind_mask_live(round_body, masked, live, stateful=stateful)
    donate_argnums = ((0, 1, 2) if stateful else (0, 1)) if donate else ()
    return jax.jit(round_fn, donate_argnums=donate_argnums)


def make_fused_epochs(loss_fn, opt, *, lr_fn=None, spmd_axis_name=None,
                      masked=False, live=False, donate=True):
    """Memory-bounded building block: a scan over ONE CHUNK of epochs.

    Returns epochs_fn(stacked_params, opt_state, batches, j0, T_i, ge0,
    sched, total) -> (stacked_params, opt_state, losses (C,K), lrs (C,)).
    j0/T_i/ge0/sched/total are traced, so the executable is shared across
    chunks, across T_i doublings, across budget updates, and across
    built-in schedule swaps; only a distinct chunk length C recompiles.
    ``masked=True``: epochs_fn takes a traced (K, n_batches) bool
    ``batch_mask`` right after ``batches`` (ragged shards, identity-carry
    masking — same contract as ``make_fused_round``). ``live=True``: a
    traced (K,) liveness row follows (dead rows are identity carries;
    membership changes never recompile).
    """
    if lr_fn is None:
        lr_fn = switch_lr
    scan_epochs = _make_epoch_scan(
        make_epoch_fn(loss_fn, opt, spmd_axis_name, masked=masked,
                      live=live), lr_fn, masked=masked, live=live)

    def epochs_body(stacked_params, opt_state, _residual, batches, mask,
                    live_row, j0, T_i, global_epoch0, sched, total):
        # epochs never touch the codec residual (it only moves at the
        # finalize); _bind_mask_live binds it to None here
        (params, ostate), (losses, lrs) = scan_epochs(
            stacked_params, opt_state, batches, j0, T_i, global_epoch0,
            sched, total, mask, live_row)
        return params, ostate, losses, lrs

    epochs_fn = _bind_mask_live(epochs_body, masked, live)
    donate_argnums = (0, 1) if donate else ()
    return jax.jit(epochs_fn, donate_argnums=donate_argnums)


def make_fused_finalize(opt, *, compress_fn=None, average_fn=None,
                        aggregate_fn=None, gated=False, gate_fn=None,
                        live=False, stateful=False, donate=True):
    """End-of-round executable for the chunked path: aggregation + Eq. 4 +
    opt reset. finalize_fn(params, old_avg, agg_weights=None) ->
    (aggregated, fresh_opt, rel, new_avg); ``params`` is donated. The
    aggregation surface matches ``make_fused_round`` (aggregate_fn or the
    legacy compress_fn/average_fn pair).

    ``gated=True``: finalize_fn(params, opt_state, sync_ref, delta,
    agg_weights=None) -> (params', opt', rel, div, synced, new_ref), the
    divergence-gated select of ``make_fused_round(gated=True)`` (params
    and opt_state donated).

    ``live=True`` (elastic membership): the ungated variant becomes
    finalize_fn(params, opt_state, old_avg, live_row, agg_weights=None)
    — opt_state rides along so dead rows keep theirs — and the gated one
    takes the traced ``live_row`` after ``delta``; dead rows are identity
    carries and ``new_avg``/divergence follow the live set (see
    ``make_fused_round``).

    ``stateful=True`` (error-feedback codec): the residual enters right
    after ``opt_state`` (right after ``params`` on the opt-free ungated
    static variant), is donated with it, ``aggregate_fn`` must be the
    3-arg stateful form, and the new residual is appended LAST to the
    returned tuple (see ``_make_finalize`` / ``_make_gated_finalize``)."""
    agg = as_aggregate_fn(aggregate_fn, compress_fn, average_fn)
    if gated:
        return jax.jit(
            _make_gated_finalize(opt, agg, gate_fn, live=live,
                                 stateful=stateful),
            donate_argnums=((0, 1, 2) if stateful else (0, 1))
            if donate else ())
    if live:
        return jax.jit(
            _make_finalize(opt, agg, live=True, stateful=stateful),
            donate_argnums=((0, 1, 2) if stateful else (0, 1))
            if donate else ())
    return jax.jit(
        _make_finalize(opt, agg, stateful=stateful),
        donate_argnums=((0, 1) if stateful else (0,)) if donate else ())
