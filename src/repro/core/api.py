"""Composable round-strategy API:
WireCodec x Aggregator x RoundEngine x LRSchedule x SyncPolicy.

The paper's Algorithm 1 is one point in a family of decentralized-averaging
protocols — FedAvg-style partial participation (McMahan et al., 1602.05629)
and dynamic/partial model averaging (Kamp et al., 1807.03210) differ from it
only in *who aggregates what, over which wire, with which engine, under
which local-training policy*. This module factors those five axes into
small protocols so a new aggregation scheme or local-training rule is a new
class, not another constructor flag plus an ``if`` in three files:

* :class:`WireCodec` — how one participant's stacked parameters travel:
  ``encode``/``decode`` (whose composition is the in-sim wire-roundtrip
  emulation) plus exact per-participant ``wire_bytes`` accounting.
  Instances: :class:`ExactF32` (the paper-faithful f32 wire),
  :class:`LeafwiseIntN` (per-leaf blockwise roundtrip at 8/4/1 bits,
  ``core.compression``; sub-block leaves bypass the codec and are billed
  at raw rates), :class:`FlatFusedIntN` (the flat-buffer wire format,
  ``core.flatbuf`` + ``kernels.comm`` — every element on the wire format,
  bytes exact by construction). Both take ``error_feedback=True`` for
  residual-memory compensation (a STATEFUL codec — the engines thread the
  residual through the round executables as traced data);
  :class:`LeafwiseInt8` / :class:`FlatFusedInt8` remain the bit-for-bit
  ``bits=8`` points.

* :class:`Aggregator` — who averages what. Each aggregator is a row-
  stochastic ``(K, K)`` *mixing matrix* per round applied over the
  participant axis of the codec-roundtripped params (the classic gossip-
  matrix formulation). Instances: :class:`FullAverage` (paper Eq. 2 —
  uniform matrix, routed through the codec's fused-mean kernel when it has
  one), :class:`PartialParticipation` (FedAvg-style: ``m <= K`` sampled
  participants per round, weighted by shard size, broadcast back to all),
  :class:`GraphGossip` (serverless gossip over any
  :mod:`repro.core.topology` graph — ring, torus, hypercube, time-varying
  one-peer exponential, Erdős–Rényi — the rows stay distinct),
  :class:`RingGossip` (the legacy fixed ring, now
  ``GraphGossip(RingTopology())``), :class:`D2Gossip` (graph gossip plus
  the D² variance-reduction correction for non-IID shards — a STATEFUL
  aggregator whose per-participant correction rides the same engine state
  slot as the codec error-feedback residual). Aggregators also own the
  per-round comm-byte accounting, priced through the codec.

* :class:`RoundEngine` — how the round executes. :class:`PythonEngine`
  (reference host loop, one jit dispatch per epoch) and
  :class:`FusedEngine` (one donated executable per round via
  ``repro.core.engine``, chunked past ``chunk`` staged epochs). Engines
  ``bind(learner)`` into runners holding the compiled artifacts.

* :class:`LRSchedule` — the Eq. 3 family: the per-epoch learning rate as a
  traced function of (round, epoch_j, T_i, global_epoch, total_budget),
  plus a per-round *host hook* (``round_params``) producing the scalar
  parameter pack (η^i, decay, ...) that rides into the round executable as
  a traced argument. Instances: :class:`CLR` (paper Eq. 3 — per-round
  exponential restart), :class:`ELR` (the non-cyclical anneal baseline),
  :class:`WarmupCLR` (η^i ramped over the first rounds — the host hook in
  action: the ramp never recompiles), :class:`CosineCyclical` (SGDR-style
  per-round cosine). All built-ins share ONE traced body
  (``schedule.switch_lr``), so swapping them reuses the fused executables.

* :class:`SyncPolicy` — Eq. 4 generalized: decides next round's T_i *and*
  whether the round communicates at all, owning the host-side
  :class:`SyncState` (T, (round, rel, T) history, skipped rounds).
  Instances: :class:`ILE` (paper Eq. 4 — double T_i once the shared model
  stabilizes), :class:`FLE` (fixed T_i), :class:`DivergenceTrigger`
  (Kamp et al., 1807.03210: sync only while the local models' divergence
  from the last synced model exceeds δ — quiet rounds skip the averaging
  step and bill zero wire bytes).

``CoLearner(codec=..., aggregator=..., round_engine=..., schedule=...,
sync_policy=...)`` composes the five; string registry names ("leafwise",
"partial", "fused", "clr", "divtrigger", ...) resolve through
:data:`CODECS` / :data:`AGGREGATORS` / :data:`ENGINES` / :data:`SCHEDULES`
/ :data:`SYNC_POLICIES`. The legacy flag surface lives on in
``CoLearner.from_flags`` and the ``CoLearnConfig.schedule``/``epochs_rule``
strings (see the migration table in ROADMAP.md §Round strategy API).
"""
from __future__ import annotations

import abc
import dataclasses
import inspect
import math
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import averaging, compression, engine as engine_mod, flatbuf
from repro.core import schedule as sched_mod
from repro.core.schedule import (LR_COS_ROUND, LR_EXP_GLOBAL, LR_EXP_ROUND,
                                 N_SCHED_PARAMS, clr_lr, cosine_lr, elr_lr,
                                 relative_change, switch_lr)
from repro.kernels import ops as kops
from repro.kernels.quantize import DEFAULT_BLOCK


def participant_bytes(stacked) -> int:
    """Raw per-participant bytes of a stacked ``(K, ...)`` params tree at
    its native dtypes — the f32/bf16 download side of the accounting."""
    total = 0
    for t in jax.tree.leaves(stacked):
        total += (t.size // t.shape[0]) * jnp.dtype(t.dtype).itemsize
    return total


def _one_participant_shapes(stacked):
    """ShapeDtypeStruct tree of ONE participant (leading K stripped)."""
    return jax.tree.map(
        lambda t: jax.ShapeDtypeStruct(t.shape[1:], t.dtype), stacked)


# ---------------------------------------------------------------------------
# WireCodec
# ---------------------------------------------------------------------------
class WireCodec(abc.ABC):
    """What one participant's upload looks like on the wire.

    ``decode(encode(stacked))`` is the in-sim wire emulation (identity for
    the exact codec, a blockwise quantization roundtrip otherwise);
    ``roundtrip`` is that composition and is what aggregators trace into
    the round executable. ``wire_bytes`` is the exact per-participant
    upload byte count, bypasses and padding included.

    A codec may carry per-participant STATE — error-feedback residual
    memory, the standard trick that keeps sub-int8 quantization convergent.
    ``stateful`` advertises it, ``init_state(stacked)`` builds the zero
    residual, and ``roundtrip_ef(stacked, residual)`` is the stateful wire
    emulation returning ``(roundtripped, new_residual)``. Aggregators then
    build ``aggregate(stacked, weights, residual) -> (mixed, new_residual)``
    and the engines thread the residual through the round executables as
    traced data (no retraces, see ``CoLearner``/``core.engine``).
    """

    name: str = "codec"

    @property
    def stateful(self) -> bool:
        """True when the codec carries per-participant residual memory."""
        return False

    def init_state(self, stacked):
        """Zero codec state for a stacked ``(K, ...)`` tree (accepts
        ``ShapeDtypeStruct`` trees too); None for stateless codecs."""
        return None

    def roundtrip_ef(self, stacked, residual):
        """Stateful wire emulation: quantize ``x + e``, return
        ``(roundtripped, new_residual)`` with ``e' = (x + e) - dequant``."""
        raise NotImplementedError(
            f"codec {self.name!r} is stateless (no error feedback)")

    @abc.abstractmethod
    def encode(self, stacked):
        """Stacked ``(K, ...)`` params tree -> wire representation."""

    @abc.abstractmethod
    def decode(self, wire):
        """Wire representation -> stacked params tree (original dtypes)."""

    def roundtrip(self, stacked):
        """The wire emulation the aggregator applies before mixing."""
        return self.decode(self.encode(stacked))

    @abc.abstractmethod
    def wire_bytes(self, stacked) -> int:
        """Exact bytes ONE participant uploads for this stacked tree."""

    def make_fused_mean(self, mesh=None, axis="pod", weighted=False,
                        stateful=False):
        """Optional codec-owned Eq. 2 fast path (wire roundtrip + mean as
        one fused pass). ``None`` means the aggregator composes
        ``roundtrip`` with a generic mean instead. ``FullAverage`` consults
        this so the flat-buffer kernel keeps owning its pod shard_map.
        ``weighted=True`` asks for the example-count-weighted variant —
        ``fn(stacked, wrow)`` with a traced normalized length-K weight row
        (FedAvg's unequal-shard generalization of Eq. 2). ``stateful=True``
        asks for the error-feedback variant, whose fn takes the residual
        as its last argument and returns ``(mean_tree, new_residual)``."""
        return None


@dataclasses.dataclass(frozen=True)
class ExactF32(WireCodec):
    """The paper-faithful wire: parameters travel at their raw dtypes."""

    name = "exact"

    def encode(self, stacked):
        return stacked

    def decode(self, wire):
        return wire

    def wire_bytes(self, stacked) -> int:
        return participant_bytes(stacked)


@dataclasses.dataclass(frozen=True)
class LeafwiseIntN(WireCodec):
    """Per-leaf blockwise quantization roundtrip at ``bits`` ∈ {8, 4, 1}
    (the tested reference wire path; int4 packs two codes per byte, 1-bit
    is sign + per-block mean-|x| scale — ``repro.kernels.quantize``).

    Leaves smaller than one quantization ``block`` (and scalars) bypass the
    codec and travel uncompressed; ``wire_bytes`` bills them at raw-dtype
    rates (``compression.compressed_bytes``). Note the emulation runs on
    the STACKED tree, so the bypass threshold sees ``K * size`` — see
    ``core.compression`` for the accounting caveat at small K.

    ``error_feedback=True`` makes the codec STATEFUL: each participant
    keeps an f32 residual mirror of the params, quantizes ``x + e`` and
    carries ``e' = (x + e) - dequant`` to the next round — the standard
    compensation that keeps int4/1-bit wires convergent. ``bits=8,
    error_feedback=False`` is bit-for-bit :class:`LeafwiseInt8`.
    """

    block: int = DEFAULT_BLOCK
    impl: str = "ref"
    bits: int = 8
    error_feedback: bool = False

    def __post_init__(self):
        from repro.kernels.quantize import check_bits
        check_bits(self.bits)

    @property
    def name(self):
        tag = "leafwise" if self.bits == 8 else f"leafwise-int{self.bits}"
        return tag + "+ef" if self.error_feedback else tag

    @property
    def stateful(self) -> bool:
        return self.error_feedback

    def init_state(self, stacked):
        if not self.error_feedback:
            return None
        # f32 mirror of every stacked leaf; bypassed leaves keep zero
        # residual forever (roundtrip_ef passes them through untouched)
        return jax.tree.map(
            lambda t: jnp.zeros(t.shape, jnp.float32), stacked)

    def roundtrip_ef(self, stacked, residual):
        return compression.quantize_roundtrip_ef(
            stacked, residual, block=self.block, impl=self.impl,
            bits=self.bits)

    def encode(self, stacked):
        leaves, treedef = jax.tree.flatten(stacked)
        enc = []
        for t in leaves:
            if t.ndim == 0 or t.size < self.block:
                enc.append(("raw", t, None))
            else:
                enc.append((f"q{self.bits}", kops.quantize_blockwise(
                    t, block=self.block, bits=self.bits, impl=self.impl),
                    t.dtype))
        return (treedef, tuple(enc))

    def decode(self, wire):
        treedef, enc = wire
        leaves = []
        for kind, payload, dtype in enc:
            if kind == "raw":
                leaves.append(payload)
            else:
                q, scale, shape = payload
                leaves.append(kops.dequantize_blockwise(
                    q, scale, shape, bits=self.bits,
                    impl=self.impl).astype(dtype))
        return jax.tree.unflatten(treedef, leaves)

    # roundtrip = decode(encode(x)) — the inherited default. It applies the
    # identical per-leaf branch + kernels as the PR-2 reference
    # ``compression.quantize_roundtrip``; tests/test_api.py pins the two
    # bitwise so the bypass threshold can never drift between them.

    def wire_bytes(self, stacked) -> int:
        return compression.compressed_bytes(_one_participant_shapes(stacked),
                                            block=self.block, bits=self.bits)


@dataclasses.dataclass(frozen=True)
class LeafwiseInt8(LeafwiseIntN):
    """The PR-2 int8 reference wire, now the ``bits=8`` point of
    :class:`LeafwiseIntN` (kept as a named class for the registry and the
    bit-for-bit compatibility pin in tests/test_api.py)."""

    name = "leafwise"


@dataclasses.dataclass(frozen=True)
class FlatFusedIntN(WireCodec):
    """The flat-buffer wire format at ``bits`` ∈ {8, 4, 1}: one contiguous
    ``(K, N_pad)`` buffer, every leaf on the packed-payload + per-block-
    scale format, bytes exact by construction (``core.flatbuf``). Under
    :class:`FullAverage` the whole quantize->average->dequantize pass runs
    as ONE kernel (``kernels.comm.quant_avg_dequant``), on the pod mesh as
    one shard_map psum of one buffer.

    ``error_feedback=True`` makes the codec STATEFUL: the residual is one
    ``(K, N_pad)`` f32 buffer riding the same flat layout, and the fused
    kernel becomes ``quant_avg_dequant_ef`` — mean AND new residual in one
    pass. ``bits=8, error_feedback=False`` is bit-for-bit
    :class:`FlatFusedInt8`."""

    block: int = DEFAULT_BLOCK
    impl: str = "ref"
    bits: int = 8
    error_feedback: bool = False

    def __post_init__(self):
        from repro.kernels.quantize import check_bits
        check_bits(self.bits)

    @property
    def name(self):
        tag = "fused" if self.bits == 8 else f"fused-int{self.bits}"
        return tag + "+ef" if self.error_feedback else tag

    @property
    def stateful(self) -> bool:
        return self.error_feedback

    def init_state(self, stacked):
        if not self.error_feedback:
            return None
        layout = flatbuf.make_layout(stacked, block=self.block)
        return jnp.zeros((layout.k, layout.n_pad), jnp.float32)

    def roundtrip_ef(self, stacked, residual):
        layout = flatbuf.make_layout(stacked, block=self.block)
        buf = flatbuf.flatten(stacked, layout)
        y = buf + residual
        q, scale, shape = kops.quantize_blockwise(y, block=self.block,
                                                  bits=self.bits,
                                                  impl=self.impl)
        dq = kops.dequantize_blockwise(q, scale, shape, bits=self.bits,
                                       impl=self.impl)
        return flatbuf.unflatten(dq, layout), y - dq

    def encode(self, stacked):
        layout = flatbuf.make_layout(stacked, block=self.block)
        buf = flatbuf.flatten(stacked, layout)
        q, scale, shape = kops.quantize_blockwise(buf, block=self.block,
                                                  bits=self.bits,
                                                  impl=self.impl)
        return (layout, q, scale, shape)

    def decode(self, wire):
        layout, q, scale, shape = wire
        buf = kops.dequantize_blockwise(q, scale, shape, bits=self.bits,
                                        impl=self.impl)
        return flatbuf.unflatten(buf, layout)

    def wire_bytes(self, stacked) -> int:
        return compression.flat_compressed_bytes(stacked, block=self.block,
                                                 bits=self.bits)

    def make_fused_mean(self, mesh=None, axis="pod", weighted=False,
                        stateful=False):
        if stateful and not self.error_feedback:
            raise ValueError("stateful fused mean requires error_feedback")
        return engine_mod.make_fused_compressed_average(
            block=self.block, impl=self.impl, bits=self.bits, mesh=mesh,
            axis=axis, weighted=weighted, stateful=stateful)


@dataclasses.dataclass(frozen=True)
class FlatFusedInt8(FlatFusedIntN):
    """The PR-3 flat-buffer int8 wire, now the ``bits=8`` point of
    :class:`FlatFusedIntN` (kept as a named class for the registry and the
    bit-for-bit compatibility pin in tests)."""

    name = "fused"


@dataclasses.dataclass(frozen=True)
class CustomFn(WireCodec):
    """Escape hatch wrapping an arbitrary stacked->stacked wire transform
    (the legacy ``CoLearner(compress_fn=...)``). The encoding is opaque, so
    ``wire_bytes`` conservatively bills raw-dtype bytes."""

    fn: Callable
    name = "custom"

    def encode(self, stacked):
        return self.fn(stacked)

    def decode(self, wire):
        return wire

    def wire_bytes(self, stacked) -> int:
        return participant_bytes(stacked)


# ---------------------------------------------------------------------------
# Aggregator
# ---------------------------------------------------------------------------
def mix_participants(stacked, weights):
    """Apply a row-stochastic ``(K, K)`` mixing matrix over the participant
    axis: slot k receives ``sum_j W[k, j] * w_j``. Uniform rows give Eq. 2;
    a circulant gives ring gossip; broadcast sampled rows give FedAvg-style
    partial participation."""
    W = weights.astype(jnp.float32)

    def one(t):
        mixed = jnp.einsum("kj,j...->k...", W, t.astype(jnp.float32))
        return mixed.astype(t.dtype)

    return jax.tree.map(one, stacked)


def _check_one_row_per_pod(aggregator, stacked, mesh, axis):
    """The weighted pod specializations permute/scale whole local blocks,
    so they are only correct with exactly one participant row per pod —
    fail loudly instead of silently mixing the wrong rows."""
    k_rows = jax.tree.leaves(stacked)[0].shape[0]
    k_pods = mesh.shape[axis]
    if k_rows != k_pods:
        raise ValueError(
            f"pod-path {aggregator.name!r} aggregation requires one "
            f"participant row per pod: params have K={k_rows}, mesh axis "
            f"{axis!r} has {k_pods} pods")


def _make_weighted_psum_aggregate(aggregator, codec, mesh, param_specs,
                                  axis):
    """Pod-path broadcast-weighted mean, shared by the aggregators whose
    mixing matrix has identical rows (weighted ``FullAverage``,
    ``PartialParticipation``): every pod downloads the same weighted mean,
    so the pod path psums each pod's weight-scaled, codec-roundtripped
    local row (one psum per leaf, f32 payloads, combinable by XLA) —
    O(model) cross-pod traffic and never a K-way gather; the single-buffer
    quantized collective remains the flat-codec weighted/uniform fast path.

    For a STATEFUL codec the local row's roundtrip is the error-feedback
    one (``roundtrip_ef``) — each pod's residual stays resident on that
    pod (it never crosses the wire) and the aggregate returns it alongside
    the mean: ``aggregate(stacked, weights, residual) -> (mixed, new_res)``
    with the residual sharded like the params (leafwise mirror tree)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import compat

    if getattr(codec, "stateful", False):
        def aggregate_ef(stacked, weights, residual):
            _check_one_row_per_pod(aggregator, stacked, mesh, axis)

            def local_mix(local, wrow, lres):
                rt, new_res = codec.roundtrip_ef(local, lres)
                k = jax.lax.axis_index(axis)

                def one(t):
                    s = jax.lax.psum(wrow[k] * t.astype(jnp.float32), axis)
                    return s.astype(t.dtype)
                return jax.tree.map(one, rt), new_res

            return compat.shard_map(
                local_mix, mesh=mesh, in_specs=(param_specs, P(),
                                                param_specs),
                out_specs=(param_specs, param_specs),
                check_vma=False)(stacked, weights[0], residual)
        return aggregate_ef

    def aggregate(stacked, weights):
        _check_one_row_per_pod(aggregator, stacked, mesh, axis)

        def local_mix(local, wrow):
            rt = codec.roundtrip(local)         # local row only: the upload
            k = jax.lax.axis_index(axis)

            def one(t):
                s = jax.lax.psum(wrow[k] * t.astype(jnp.float32), axis)
                return s.astype(t.dtype)
            return jax.tree.map(one, rt)

        return compat.shard_map(
            local_mix, mesh=mesh, in_specs=(param_specs, P()),
            out_specs=param_specs, check_vma=False)(stacked, weights[0])
    return aggregate


def normalized_weights(weights, K: int) -> np.ndarray:
    """Validate per-participant averaging weights (e.g. shard example
    counts) and return them normalized to sum 1 as a length-K f64 array."""
    w = np.asarray(weights, np.float64)
    if w.shape != (K,):
        raise ValueError(f"weights must have length K={K}; got {w.shape}")
    if not np.isfinite(w).all() or (w < 0).any():
        raise ValueError(f"weights must be finite and >= 0; got {w}")
    if not w.sum() > 0:
        raise ValueError("weights must not all be zero")
    return w / w.sum()


class Aggregator(abc.ABC):
    """Who aggregates what: a per-round mixing matrix + byte accounting.

    ``make_aggregate_fn(codec, ...)`` returns ``aggregate(stacked, weights)``
    — traced into the round executable; ``weights`` is the ``(K, K)``
    matrix from ``mixing_matrix`` (or ``None`` when ``uses_weights`` is
    False and the matrix is statically known, e.g. Eq. 2's uniform mean).
    ``comm_bytes`` prices the round per participant through the codec.
    """

    name: str = "aggregator"
    #: False => the aggregate fn ignores the weights argument (statically
    #: known matrix); the driver then passes None and avoids the transfer.
    uses_weights: bool = True
    #: True => ``comm_bytes`` is round-independent for fixed param shapes,
    #: so the driver computes it once per learner instead of per round.
    #: Aggregators whose accounting varies per round must set this False.
    #: (Under elastic membership the driver bypasses the cache anyway —
    #: the live set changes the bill per round.)
    static_comm: bool = True

    @abc.abstractmethod
    def mixing_matrix(self, round_index: int, K: int,
                      live=None) -> np.ndarray:
        """Row-stochastic (K, K) f32 matrix for this round (host-side).

        ``live`` (elastic membership): a bool (K,) liveness row. The
        matrix must then mix over LIVE columns only — renormalized
        averaging rows, live-sampled participants, routed gossip edges —
        and dead rows may be anything row-stochastic (the engine restores
        dead rows to their own params after mixing, so by convention they
        get identity or broadcast rows). ``None`` is the static-K matrix.
        """

    def make_aggregate_fn(self, codec: WireCodec, *, mesh=None,
                          param_specs=None, axis="pod", dynamic=False):
        """Build ``aggregate(stacked, weights)``. Dispatches to the pod-path
        specialization hook when a mesh is given; subclasses customize via
        ``_make_mesh_aggregate_fn`` / ``_make_host_aggregate_fn`` so the
        mesh dispatch cannot be accidentally bypassed.

        ``dynamic=True`` (elastic membership): the mixing matrix changes
        per round (live-set renormalization), so the built fn must honor
        the traced ``weights`` argument every call — specializations that
        bake a static matrix (uniform fused means, static gossip permutes)
        are skipped in favor of the weighted paths."""
        if mesh is not None and param_specs is not None:
            fn = self._make_mesh_aggregate_fn(codec, mesh, param_specs, axis,
                                              dynamic=dynamic)
            if fn is not None:
                return fn
        return self._make_host_aggregate_fn(codec)

    def _make_host_aggregate_fn(self, codec):
        """Simulation-path aggregation (single host, all K rows visible).

        Stateful codecs (error feedback) change the signature to
        ``aggregate(stacked, weights, residual) -> (mixed, new_residual)``
        — the residual is traced data alongside the params."""
        if getattr(codec, "stateful", False):
            def aggregate_ef(stacked, weights, residual):
                rt, new_res = codec.roundtrip_ef(stacked, residual)
                return mix_participants(rt, weights), new_res
            return aggregate_ef

        def aggregate(stacked, weights):
            return mix_participants(codec.roundtrip(stacked), weights)
        return aggregate

    def _make_mesh_aggregate_fn(self, codec, mesh, param_specs, axis,
                                dynamic=False):
        """Pod-path specialization hook: return an aggregate fn whose only
        cross-pod traffic is the aggregator's actual wire pattern (a psum,
        a permute, ...). None falls back to the dense mixing einsum — which
        under GSPMD gathers every pod's replica across ``axis``, so any
        aggregator meant for the pod path should override this.
        ``dynamic=True``: the per-round matrix varies (elastic membership);
        return None unless the specialization honors ``weights``."""
        return None

    @abc.abstractmethod
    def comm_bytes(self, codec: WireCodec, stacked, round_index: int,
                   live=None) -> int:
        """Per-participant wire bytes for this round (upload + download).

        ``live`` (elastic membership): a bool (K,) liveness row — only
        live rows upload/download, so the per-live-participant bill
        changes with the live set."""

    @property
    def stateful(self) -> bool:
        """True when the AGGREGATOR carries per-participant round state
        (e.g. :class:`D2Gossip`'s variance-reduction correction). The
        engines thread ONE state slot — ``state["residual"]`` — through
        the donated round executables; it holds the codec's
        error-feedback memory, the aggregator's state, or both (see
        ``init_round_state``), and the aggregate fn takes the 3-arg
        stateful form ``aggregate(stacked, weights, state) ->
        (mixed, new_state)`` whenever either side is stateful."""
        return False

    def init_round_state(self, codec: WireCodec, stacked):
        """Zero per-participant round state for this (codec, aggregator)
        pair — the pytree the engines thread through the round
        executables, or None when neither side is stateful. Whatever
        structure this returns is persisted by ``checkpoint/io.py``,
        carried unchanged through quiet sync-policy rounds, frozen for
        dead slots via ``select_live``, and zeroed per-row on
        ``restart_participant`` — all generically over the pytree."""
        if getattr(codec, "stateful", False):
            return codec.init_state(stacked)
        return None


@dataclasses.dataclass(frozen=True)
class FullAverage(Aggregator):
    """Paper Eq. 2: every participant uploads, the server averages, everyone
    downloads the shared model.

    ``weights=None`` (the default) is the paper's uniform mean, routed
    through the codec's fused-mean kernel when it has one (flat-buffer
    path: one quant->avg->dequant pass; on a pod mesh one shard_map psum of
    one buffer), else through ``averaging.average_pjit`` /
    ``make_average_shard_map`` over the codec-roundtripped params —
    bit-for-bit the PR-2 behavior.

    ``weights=(n_1, ..., n_K)`` — per-participant example counts (any
    nonnegative weights; normalized internally) — is FedAvg's
    generalization of Eq. 2 to unequal shards (McMahan et al., 1602.05629):
    w̄ = Σ_k (n_k/n) w_k. The weight row rides into the round executables
    as a traced mixing-matrix row (``mix_participants`` plumbing), the
    flat-buffer codec keeps a fused weighted-mean pass
    (``make_fused_compressed_average(weighted=True)``), and the pod path
    psums the weight-scaled local rows.
    """

    weights: tuple | None = None
    name = "full"

    @property
    def uses_weights(self):
        # uniform Eq. 2 is statically known (no weight transfer, fused
        # kernel fast path); explicit weights ride in traced per round
        return self.weights is not None

    def mixing_matrix(self, round_index, K, live=None):
        if live is None:
            if self.weights is None:
                return np.full((K, K), 1.0 / K, np.float32)
            w = normalized_weights(self.weights, K)
            # every row identical: all K download the same weighted mean
            return np.broadcast_to(w, (K, K)).astype(np.float32)
        # elastic membership: renormalize the (possibly weighted) averaging
        # row over the LIVE participants — a dead row's stale model must
        # not drag the mean (the benchmarks/churn.py ablation measures
        # exactly this against the naive static row)
        base = (np.ones(K, np.float64) if self.weights is None
                else np.asarray(self.weights, np.float64))
        if base.shape != (K,):
            raise ValueError(f"weights must have length K={K}")
        if not np.isfinite(base).all() or (base < 0).any():
            raise ValueError(f"weights must be finite and >= 0; got {base}")
        w = base * np.asarray(live, bool)
        if not w.sum() > 0:
            raise ValueError(
                "no live participant carries averaging weight at round "
                f"{round_index} (live={np.asarray(live, bool)})")
        w /= w.sum()
        # every row identical: all LIVE rows download the same mean (the
        # engine restores dead rows to their own params after mixing)
        return np.broadcast_to(w, (K, K)).astype(np.float32)

    def make_aggregate_fn(self, codec, *, mesh=None, param_specs=None,
                          axis="pod", dynamic=False):
        stateful = getattr(codec, "stateful", False)
        if self.weights is not None or dynamic:
            # per-round weight row (explicit weights and/or live-set
            # renormalization) — always the weighted paths
            fused = codec.make_fused_mean(mesh=mesh, axis=axis,
                                          weighted=True, stateful=stateful)
            if fused is not None:
                if stateful:
                    return lambda stacked, weights, residual: fused(
                        stacked, weights[0], residual)
                return lambda stacked, weights: fused(stacked, weights[0])
            if mesh is not None and param_specs is not None:
                return _make_weighted_psum_aggregate(
                    self, codec, mesh, param_specs, axis)
            return self._make_host_aggregate_fn(codec)
        fused = codec.make_fused_mean(mesh=mesh, axis=axis,
                                      stateful=stateful)
        if fused is not None:
            if stateful:
                return lambda stacked, weights, residual: fused(stacked,
                                                                residual)
            return lambda stacked, weights=None: fused(stacked)
        if mesh is not None and param_specs is not None:
            if stateful:
                # EF uniform mean on the pod mesh without a fused kernel:
                # the broadcast-weighted psum with a baked uniform row —
                # each pod's residual stays resident (never on the wire)
                psum = _make_weighted_psum_aggregate(
                    self, codec, mesh, param_specs, axis)
                K = mesh.shape[axis]
                uni = jnp.full((K, K), 1.0 / K, jnp.float32)
                return lambda stacked, weights, residual: psum(
                    stacked, uni, residual)
            sm = averaging.make_average_shard_map(mesh, param_specs, axis)
            return lambda stacked, weights=None: sm(codec.roundtrip(stacked))
        if stateful:
            def aggregate_ef(stacked, weights, residual):
                rt, new_res = codec.roundtrip_ef(stacked, residual)
                return averaging.average_pjit(rt), new_res
            return aggregate_ef
        return lambda stacked, weights=None: averaging.average_pjit(
            codec.roundtrip(stacked))

    def comm_bytes(self, codec, stacked, round_index, live=None):
        # upload on the codec's wire + f32/raw download of the shared
        # model; under elastic membership only live rows touch the wire,
        # so the PER-LIVE-PARTICIPANT bill is the same expression
        return codec.wire_bytes(stacked) + participant_bytes(stacked)


@dataclasses.dataclass(frozen=True)
class PartialParticipation(Aggregator):
    """FedAvg-style partial participation (McMahan et al., 1602.05629):
    each round samples ``m <= K`` participants without replacement and the
    new shared model is their weighted average, broadcast back to every
    participant (all K keep training locally; only the sampled uploads
    cross the WAN).

    ``weights``: optional length-K per-participant weights — pass the shard
    example counts for FedAvg's shard-size-weighted average. When omitted
    the average falls back to UNIFORM over the sampled participants (the
    equal-shard special case); ``CoLearner(shard_sizes=...)`` auto-wires
    the shard sizes in, so a learner that knows its data never silently
    uses the uniform fallback on unequal shards. Sampling is deterministic
    in (seed, round) so the python and fused engines see identical rounds.
    """

    m: int = 2
    weights: tuple | None = None
    seed: int = 0
    name = "partial"

    def mixing_matrix(self, round_index, K, live=None):
        if not 1 <= self.m <= K:
            raise ValueError(f"need 1 <= m <= K, got m={self.m} K={K}")
        base = (np.asarray(self.weights, np.float64) if self.weights
                is not None else np.ones(K))
        if base.shape != (K,):
            raise ValueError(f"weights must have length K={K}")
        if not np.isfinite(base).all() or (base < 0).any():
            raise ValueError(f"weights must be finite and >= 0; got {base}")
        if live is not None:
            # elastic membership: only live participants can be sampled;
            # a shrunken live set shrinks the draw (m_eff = min(m, live))
            # rather than erroring — error only when NOTHING is live
            base = base * np.asarray(live, bool)
            if not (base > 0).any():
                raise ValueError(
                    "partial participation has zero live participants "
                    f"with positive weight at round {round_index} "
                    f"(live={np.asarray(live, bool)})")
        # only participants with weight can be sampled — a zero-weight-only
        # sample would otherwise normalize 0/0 into a NaN mixing matrix
        eligible = np.nonzero(base > 0)[0]
        m_eff = min(self.m, len(eligible)) if live is not None else self.m
        if len(eligible) < m_eff:
            raise ValueError(
                f"need m={m_eff} participants with positive weight; "
                f"only {len(eligible)} of K={K} have one")
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, round_index]))
        sel = rng.choice(eligible, size=m_eff, replace=False)
        w = np.zeros(K, np.float64)
        w[sel] = base[sel]
        w /= w.sum()
        # every row identical: all K download the same new shared model
        return np.broadcast_to(w, (K, K)).astype(np.float32)

    def _make_mesh_aggregate_fn(self, codec, mesh, param_specs, axis,
                                dynamic=False):
        # rows of the mixing matrix are identical (everyone downloads the
        # same weighted mean), so the broadcast-weighted psum specialization
        # applies — shared with weighted FullAverage; the weight row is
        # honored per call, so it serves the dynamic (live-set) case too
        return _make_weighted_psum_aggregate(self, codec, mesh, param_specs,
                                             axis)

    def comm_bytes(self, codec, stacked, round_index, live=None):
        K = jax.tree.leaves(stacked)[0].shape[0]
        up = codec.wire_bytes(stacked)          # only m of K pay the upload
        if live is not None:
            n_live = max(int(np.asarray(live, bool).sum()), 1)
            m_eff = min(self.m, n_live)
            # only the n_live rows touch the wire; the sampled-upload cost
            # amortizes over them, every live row pays the download
            return (math.ceil(m_eff * up / n_live)
                    + participant_bytes(stacked))
        return math.ceil(self.m * up / K) + participant_bytes(stacked)


@dataclasses.dataclass(frozen=True)
class GraphGossip(Aggregator):
    """One gossip exchange per round over an arbitrary sparse topology
    (consensus SGD, Jiang et al., 1706.07880): no server — participant k
    mixes its model with its graph neighbors' through the topology's
    row-stochastic (all-live: doubly stochastic) mixing matrix, so
    repeated rounds contract toward consensus at the graph's
    spectral-gap rate while models stay distinct within a round.

    ``topology`` is a :mod:`repro.core.topology` instance or registry
    name (``"ring"`` | ``"grid2d"`` | ``"hypercube"`` | ``"exponential"``
    | ``"erdos_renyi"`` | ``"complete"``); None is the ring. Time-varying
    graphs ride the per-round matrix into the unchanged donated
    executables as traced data — a graph change is never a recompile.
    Disconnected topologies are rejected at learner construction
    (``validate``). Liveness renormalizes over the live subgraph: the
    topology routes around dead nodes or drops their edges, a sole
    survivor keeps its own model, and if churn splits the graph, mixing
    proceeds component-wise with a logged warning. Per-round matrices
    are memoized per (round-key, K, live-set), so a static all-live
    graph builds its matrix exactly once.

    Pod path: the wire pattern is one ``jax.lax.ppermute`` per neighbor
    permutation (``topology.edge_perms``) — O(degree) cross-pod traffic,
    never the dense-einsum K-way gather; irregular graphs (erdos_renyi)
    fall back to the dense traced mixing."""

    topology: Any = None

    def __post_init__(self):
        from repro.core import topology as topo_mod
        object.__setattr__(self, "topology",
                           topo_mod.get_topology(self.topology))
        object.__setattr__(self, "_mix_cache", {})

    @property
    def name(self):  # noqa: D401 — shadowed by subclass class attrs
        return f"graph[{self.topology.name}]"

    @property
    def static_comm(self):
        # a time-varying graph's live edge count (and so its bill) can
        # change per round even with every participant up
        return not self.topology.time_varying

    def validate(self, K: int) -> "GraphGossip":
        """Connectivity guard — raises ValueError when the topology can
        never reach consensus at this K (CoLearner calls this once at
        construction)."""
        self.topology.validate(K)
        return self

    def _round_key(self, round_index, K):
        topo = self.topology
        return (round_index % topo.period(K)) if topo.time_varying else 0

    def mixing_matrix(self, round_index, K, live=None):
        lkey = (None if live is None
                else tuple(bool(x) for x in np.asarray(live, bool)))
        key = (self._round_key(round_index, K), K, lkey)
        W = self._mix_cache.get(key)
        if W is None:
            W = self.topology.mixing_matrix(round_index, K, live=live)
            W.flags.writeable = False           # cached: nobody may edit
            if len(self._mix_cache) >= 512:     # random churn could grow
                self._mix_cache.clear()         # the live-key space: bound
            self._mix_cache[key] = W
        return W

    def _make_host_aggregate_fn(self, codec):
        # serverless: a participant's OWN model never crosses the wire, so
        # only the received (off-diagonal) leg goes through the codec —
        # quantizing the diagonal too would overstate compression error
        def _mix(stacked, rt, weights):
            W = weights.astype(jnp.float32)
            d = jnp.diagonal(W)
            off = W - jnp.diag(d)

            def one(t, q):
                local = d.reshape((-1,) + (1,) * (t.ndim - 1)) \
                    * t.astype(jnp.float32)
                recv = jnp.einsum("kj,j...->k...", off,
                                  q.astype(jnp.float32))
                return (local + recv).astype(t.dtype)

            return jax.tree.map(one, stacked, rt)

        if getattr(codec, "stateful", False):
            def aggregate_ef(stacked, weights, residual):
                rt, new_res = codec.roundtrip_ef(stacked, residual)
                return _mix(stacked, rt, weights), new_res
            return aggregate_ef

        def aggregate(stacked, weights):
            return _mix(stacked, codec.roundtrip(stacked), weights)
        return aggregate

    def _mesh_perm_setup(self, mesh, axis, dynamic):
        """The sparse pod wire pattern: the graph's edge permutations and,
        per permutation, the (K,) "k receives from src[k]" gather map used
        to pick each leg's weight out of the traced matrix. None — dense
        fallback — when the graph is irregular (no circulant/regular perm
        decomposition), time-varying (per-round wire pattern), or elastic
        membership may route edges outside the baked pattern."""
        if dynamic:
            return None
        topo = self.topology
        if topo.time_varying:
            return None
        K = mesh.shape[axis]
        perms = topo.edge_perms(0, K)
        if not perms:
            return None
        srcs = []
        for perm in perms:
            if len(perm) != K or len({d for _, d in perm}) != K:
                return None         # partial permute: some pod gets zeros
            src = np.zeros(K, np.int64)
            for s, d in perm:
                src[d] = s
            srcs.append(jnp.asarray(src))
        return tuple(tuple(p) for p in perms), tuple(srcs)

    def _make_mesh_aggregate_fn(self, codec, mesh, param_specs, axis,
                                dynamic=False):
        if getattr(codec, "stateful", False):
            # the permute pattern has no residual plumbing; the host path
            # carries the error-feedback state correctly
            return None
        setup = self._mesh_perm_setup(mesh, axis, dynamic)
        if setup is None:
            return None
        perms, srcs = setup
        # the graph's wire pattern is one collective permute per neighbor
        # permutation: each pod codec-roundtrips its own row (the send
        # leg) and receives exactly degree rows (per-leaf ppermutes, f32
        # payloads, combinable by XLA) — O(degree) point-to-point traffic,
        # no all-gather, the local half stays exact, and the per-leg
        # weights are gathered from the traced matrix at the pod's index
        from jax.sharding import PartitionSpec as P
        from repro.sharding import compat

        def aggregate(stacked, weights):
            _check_one_row_per_pod(self, stacked, mesh, axis)

            def local_mix(local, W):
                rt = codec.roundtrip(local)
                k = jax.lax.axis_index(axis)
                Wf = W.astype(jnp.float32)
                w_self = Wf[k, k]
                w_recv = [Wf[k, src[k]] for src in srcs]

                def one(t, q):
                    acc = w_self * t.astype(jnp.float32)
                    qf = q.astype(jnp.float32)
                    for perm, w in zip(perms, w_recv):
                        acc = acc + w * jax.lax.ppermute(qf, axis,
                                                         list(perm))
                    return acc.astype(t.dtype)
                return jax.tree.map(one, local, rt)

            return compat.shard_map(
                local_mix, mesh=mesh, in_specs=(param_specs, P()),
                out_specs=param_specs, check_vma=False)(stacked, weights)
        return aggregate

    def comm_bytes(self, codec, stacked, round_index, live=None):
        # serverless: every directed live edge moves one encoded model
        # across the wire, and each participant pays for its send AND
        # receive legs — amortized per live participant that is
        # 2 * live_edges / n_live encoded models, O(degree), never O(K)
        K = jax.tree.leaves(stacked)[0].shape[0]
        n = K
        if live is not None:
            n = int(np.asarray(live, bool).sum())
            if n <= 1:
                return 0             # a sole survivor has nobody to gossip
        W = self.mixing_matrix(round_index, K, live=live)
        n_edges = (int(np.count_nonzero(W))
                   - int(np.count_nonzero(np.diagonal(W))))
        if n_edges == 0:
            return 0
        return math.ceil(2 * n_edges * codec.wire_bytes(stacked) / n)


@dataclasses.dataclass(frozen=True)
class RingGossip(GraphGossip):
    """One neighbor-exchange step over a fixed ring (decentralized, no
    server): participant k averages its model with its ring predecessor's,
    ``w_k' = (w_k + w_{(k-1) mod K}) / 2``. The mixing matrix is doubly
    stochastic, so repeated rounds contract toward consensus while models
    stay distinct within a round (``shared_model`` tracks slot 0).

    Since the topology subsystem this IS ``GraphGossip(RingTopology())``
    — the named class survives for the ``"ring"`` registry name and to
    pin the legacy behavior: the all-live and routed live matrices, host
    mixing, comm bill, and the static per-leaf ppermute pod fast path
    below are bit-identical to the original hand-rolled aggregator
    (asserted in tests/test_topology.py)."""

    name = "ring"

    def __post_init__(self):
        super().__post_init__()
        from repro.core.topology import RingTopology
        if not isinstance(self.topology, RingTopology):
            raise ValueError(
                "RingGossip is fixed to the ring topology; use "
                f"GraphGossip(topology={self.topology.name!r}) instead")

    def _make_mesh_aggregate_fn(self, codec, mesh, param_specs, axis,
                                dynamic=False):
        if getattr(codec, "stateful", False):
            # the static ppermute has no residual plumbing; the host path
            # carries the error-feedback state correctly
            return None
        if dynamic:
            # the static ppermute bakes the all-live ring; under elastic
            # membership the routed matrix must be honored per round, so
            # fall back to the dense host mixing (correctness over the
            # specialized wire pattern — revisit with a traced permute)
            return None
        # the ring's wire pattern is a collective permute: each pod codec-
        # roundtrips its own row (the send leg) and receives exactly one
        # neighbor row (one ppermute per leaf, f32 payloads, combinable by
        # XLA) — O(model) point-to-point traffic, no all-gather, and the
        # local half stays exact
        from repro.sharding import compat
        K = mesh.shape[axis]
        perm = [(j, (j + 1) % K) for j in range(K)]

        def aggregate(stacked, weights):
            del weights                         # the ring matrix is static
            _check_one_row_per_pod(self, stacked, mesh, axis)

            def local_mix(local):
                rt = codec.roundtrip(local)

                def one(t, q):
                    recv = jax.lax.ppermute(q.astype(jnp.float32), axis,
                                            perm)
                    return (0.5 * t.astype(jnp.float32)
                            + 0.5 * recv).astype(t.dtype)
                return jax.tree.map(one, local, rt)

            return compat.shard_map(
                local_mix, mesh=mesh, in_specs=(param_specs,),
                out_specs=param_specs, check_vma=False)(stacked)
        return aggregate

    def comm_bytes(self, codec, stacked, round_index, live=None):
        # each participant sends its encoded model to one neighbor and
        # receives one encoded model back — both legs on the wire format
        # (kept verbatim from the pre-topology aggregator: the general
        # per-live-edge bill reduces to this for every ring live set)
        if live is not None and int(np.asarray(live, bool).sum()) <= 1:
            return 0                 # a sole survivor has nobody to gossip
        return 2 * codec.wire_bytes(stacked)


@dataclasses.dataclass(frozen=True)
class D2Gossip(GraphGossip):
    """:class:`GraphGossip` plus the D² variance-reduction correction
    (Tang et al., 1803.07068) in round form: plain gossip over non-IID
    shards drags each participant toward its local optimum between
    exchanges, leaving a bias sparse mixing never clears (the Dirichlet
    α=0.1 collapse measured in benchmarks/ablation.py). D² cancels it
    with one extra model-shaped memory per participant and ZERO extra
    wire traffic:

        v_k   = y_k + c_k        post-training model + correction
        x_k'  = Σ_j W[k,j] v_j   the usual gossip mix (v on the wire)
        c_k'  = x_k' - y_k       next round's correction

    With c = x - y_prev this telescopes to x' = W (x + y - y_prev) —
    D²'s update ``W (2 X_t - X_{t-1} - γ (G_t - G_{t-1}))`` generalized
    from one SGD step to a local training round. On identical shards the
    correction stays exactly zero and D² IS plain gossip (pinned in
    tests); on non-IID shards it removes the across-shard drift so
    sparse gossip recovers full-averaging accuracy
    (benchmarks/topology.py).

    The correction is AGGREGATOR round state riding the same engine slot
    as the codec error-feedback residual (``stateful`` /
    ``init_round_state``): threaded traced through round/chunk/finalize
    executables, persisted by ``checkpoint/io.py``, carried unchanged
    through quiet ``DivergenceTrigger`` rounds, frozen for dead slots
    via ``select_live``, and zeroed per-row on ``restart_participant``.
    With an error-feedback codec both memories ride together as
    ``{"corr": ..., "res": ...}``."""

    @property
    def name(self):
        return f"d2[{self.topology.name}]"

    @property
    def stateful(self):
        return True

    def init_round_state(self, codec, stacked):
        corr = jax.tree.map(
            lambda t: jnp.zeros(t.shape, jnp.float32), stacked)
        if getattr(codec, "stateful", False):
            return {"corr": corr, "res": codec.init_state(stacked)}
        return corr

    def _make_host_aggregate_fn(self, codec):
        codec_ef = getattr(codec, "stateful", False)

        def aggregate(stacked, weights, state):
            corr = state["corr"] if codec_ef else state
            # corrected value v = y + c, carried in f32; v replaces the
            # raw model on the wire, and as in plain gossip only the
            # received (off-diagonal) leg goes through the codec
            vf = jax.tree.map(lambda t, c: t.astype(jnp.float32) + c,
                              stacked, corr)
            vw = jax.tree.map(lambda t, v: v.astype(t.dtype), stacked, vf)
            if codec_ef:
                rt, new_res = codec.roundtrip_ef(vw, state["res"])
            else:
                rt = codec.roundtrip(vw)
            W = weights.astype(jnp.float32)
            d = jnp.diagonal(W)
            off = W - jnp.diag(d)

            def one(v, q):
                local = d.reshape((-1,) + (1,) * (v.ndim - 1)) * v
                recv = jnp.einsum("kj,j...->k...", off,
                                  q.astype(jnp.float32))
                return local + recv

            mixed_f = jax.tree.map(one, vf, rt)
            mixed = jax.tree.map(lambda t, m: m.astype(t.dtype),
                                 stacked, mixed_f)
            new_corr = jax.tree.map(
                lambda m, t: m - t.astype(jnp.float32), mixed_f, stacked)
            return mixed, ({"corr": new_corr, "res": new_res}
                           if codec_ef else new_corr)
        return aggregate

    def _make_mesh_aggregate_fn(self, codec, mesh, param_specs, axis,
                                dynamic=False):
        if getattr(codec, "stateful", False):
            # composing the EF residual with the correction on the pod
            # path needs codec state plumbing the permutes don't have;
            # the host path carries both correctly
            return None
        setup = self._mesh_perm_setup(mesh, axis, dynamic)
        if setup is None:
            return None
        perms, srcs = setup
        from jax.sharding import PartitionSpec as P
        from repro.sharding import compat

        def aggregate(stacked, weights, corr):
            _check_one_row_per_pod(self, stacked, mesh, axis)

            def local_mix(local, W, lcorr):
                vf = jax.tree.map(lambda t, c: t.astype(jnp.float32) + c,
                                  local, lcorr)
                vw = jax.tree.map(lambda t, v: v.astype(t.dtype),
                                  local, vf)
                rt = codec.roundtrip(vw)
                k = jax.lax.axis_index(axis)
                Wf = W.astype(jnp.float32)
                w_self = Wf[k, k]
                w_recv = [Wf[k, src[k]] for src in srcs]

                def one(v, q):
                    acc = w_self * v
                    qf = q.astype(jnp.float32)
                    for perm, w in zip(perms, w_recv):
                        acc = acc + w * jax.lax.ppermute(qf, axis,
                                                         list(perm))
                    return acc

                mixed_f = jax.tree.map(one, vf, rt)
                mixed = jax.tree.map(lambda t, m: m.astype(t.dtype),
                                     local, mixed_f)
                new_c = jax.tree.map(
                    lambda m, t: m - t.astype(jnp.float32),
                    mixed_f, local)
                return mixed, new_c

            return compat.shard_map(
                local_mix, mesh=mesh,
                in_specs=(param_specs, P(), param_specs),
                out_specs=(param_specs, param_specs),
                check_vma=False)(stacked, weights, corr)
        return aggregate


# ---------------------------------------------------------------------------
# LRSchedule (Eq. 3 family)
# ---------------------------------------------------------------------------
class LRSchedule(abc.ABC):
    """The per-epoch learning rate policy (the Eq. 3 axis).

    Two surfaces, one semantics:

    * ``lr(round_i, epoch_j, T_i, global_epoch, total_budget)`` — the
      reference rate, host-evaluable with plain scalars (the python engine
      calls it once per epoch). Implementations keep the math compatible
      with traced inputs where the formula allows.
    * ``round_params(round_i)`` — the per-round HOST hook: returns
      ``(kind, p)``, the branch index and scalar pack that
      ``schedule.switch_lr`` (the shared traced body, :attr:`traced_lr`)
      consumes *as traced arguments* inside the fused round executable. A
      schedule whose parameters move per round (a warmup ramping η^i, a
      policy-aware budget) therefore never retriggers compilation, and
      swapping between built-ins reuses the same executable outright.

    Custom subclasses may override :attr:`traced_lr` with their own traced
    function — at the cost of one retrace when swapping to/from it
    (``CoLearner.set_schedule`` rebinds the engine in that case).
    """

    name: str = "schedule"
    #: the traced body the fused engine embeds; shared by every built-in
    #: (one lax.switch over the ``schedule.LR_*`` branch family)
    traced_lr = staticmethod(switch_lr)

    @abc.abstractmethod
    def lr(self, round_i, epoch_j, T_i, global_epoch, total_budget):
        """The epoch's learning rate (reference/host form)."""

    @abc.abstractmethod
    def round_params(self, round_i):
        """Host hook: ``(kind, (p0, p1, p2, p3))`` for ``switch_lr``."""

    def device_round_params(self, round_i):
        """``round_params`` as the traced argument pack the engine takes
        (staged explicitly — it lands on the no_transfer round path)."""
        kind, p = self.round_params(round_i)
        p = tuple(p) + (0.0,) * (N_SCHED_PARAMS - len(p))
        return {"kind": engine_mod.stage(kind, np.int32),
                "p": engine_mod.stage(p, np.float32)}


def traced_body(schedule: LRSchedule):
    """The schedule's traced lr function as a plain callable.

    Unwraps the bound-method descriptor a subclass gets when it overrides
    ``traced_lr`` with a plain function instead of a ``staticmethod`` —
    both so identity comparison (the hot-swap check) works and so the
    engine calls it as ``lr_fn(sched, j, T_i, ge, total)`` without the
    instance sneaking in as the first argument."""
    fn = schedule.traced_lr
    return getattr(fn, "__func__", fn)


@dataclasses.dataclass(frozen=True)
class CLR(LRSchedule):
    """Paper Eq. 3: η_j^i = η^i · r^(j/T_i), restarting at η^i every round
    (the cycle period is the communication round itself)."""

    eta0: float = 0.01
    decay_rate: float = 0.25
    name = "clr"

    def round_eta(self, round_i) -> float:
        """The round's shared base rate η^i (constant for plain CLR)."""
        return self.eta0

    def lr(self, round_i, epoch_j, T_i, global_epoch, total_budget):
        return clr_lr(self.round_eta(round_i), self.decay_rate, epoch_j, T_i)

    def round_params(self, round_i):
        return LR_EXP_ROUND, (self.round_eta(round_i), self.decay_rate)


@dataclasses.dataclass(frozen=True)
class ELR(LRSchedule):
    """The non-cyclical ablation baseline: one exponential anneal over the
    run's whole epoch budget, never restarting. The budget arrives traced
    each round (``SyncPolicy.epochs_budget``), so ILE doublings of T_i
    stretch the anneal correctly instead of stranding it short."""

    eta0: float = 0.01
    decay_rate: float = 0.25
    name = "elr"

    def lr(self, round_i, epoch_j, T_i, global_epoch, total_budget):
        return elr_lr(self.eta0, self.decay_rate, global_epoch,
                      max(total_budget, 1))

    def round_params(self, round_i):
        return LR_EXP_GLOBAL, (self.eta0, self.decay_rate)


@dataclasses.dataclass(frozen=True)
class WarmupCLR(CLR):
    """CLR with η^i linearly ramped over the first ``warmup_rounds``
    communication rounds: η^i = η0 · min(1, (i+1)/warmup_rounds). The ramp
    lives entirely in the per-round host hook — the fused executable sees
    only a different traced η^i each round, so warmup costs zero retraces.
    """

    warmup_rounds: int = 3
    name = "warmup_clr"

    def round_eta(self, round_i) -> float:
        ramp = min(1.0, (round_i + 1) / max(self.warmup_rounds, 1))
        return self.eta0 * ramp


@dataclasses.dataclass(frozen=True)
class CosineCyclical(LRSchedule):
    """SGDR-style cyclical cosine: within round i the rate anneals from
    η^i to ``eta_min`` on a half-cosine over the round's T_i epochs and
    restarts at η^i at the next round boundary (same cycle structure as
    Eq. 3, smoother tail)."""

    eta0: float = 0.01
    eta_min: float = 0.0
    name = "cosine"

    def lr(self, round_i, epoch_j, T_i, global_epoch, total_budget):
        return cosine_lr(self.eta0, self.eta_min, epoch_j, T_i)

    def round_params(self, round_i):
        return LR_COS_ROUND, (self.eta0, 0.0, self.eta_min)


# ---------------------------------------------------------------------------
# SyncPolicy (Eq. 4 generalized)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SyncState:
    """Host-side per-run state owned by a :class:`SyncPolicy`.

    ``history`` logs one ``(round, rel_change, next_T)`` triple per
    completed round; ``skipped`` lists the rounds a divergence-gated
    policy decided not to communicate.
    """

    T: int
    history: tuple = ()
    skipped: tuple = ()


class SyncPolicy(abc.ABC):
    """Who syncs when: next round's T_i + the communicate-at-all decision.

    Absorbs the legacy ``EpochController``: the policy owns a
    :class:`SyncState` (created by ``init_state``, advanced by ``update``
    after every round) and, for divergence-gated policies, the per-round
    ``should_sync`` decision plus the traced threshold ``delta`` the fused
    engine embeds. ``epochs_budget`` is the policy-aware total-epoch
    estimate the ELR anneal divides by — it rides into the executables as
    a traced argument, so the per-round re-estimate (after an ILE
    doubling) is free.
    """

    name: str = "sync"
    #: True => the round executable is built with the divergence gate and
    #: quiet rounds skip the aggregation/wire step (Kamp et al.).
    divergence_gated: bool = False
    #: the traced divergence threshold (gated policies only)
    delta: float = float("inf")

    def init_state(self, T0: int) -> SyncState:
        return SyncState(T=int(T0))

    @abc.abstractmethod
    def update(self, state: SyncState, round_i: int, rel_change: float,
               synced: bool = True, events: tuple = ()) -> SyncState:
        """Post-round host hook: fold the round's Eq. 4 metric (or, on a
        skipped round, the divergence) into the state; returns the state
        whose ``T`` drives round ``round_i + 1``.

        ``events`` (elastic membership): the round's ``(round, slot,
        "join"|"leave")`` membership events. On a churn round the Eq. 4
        metric jumps because the LIVE SET moved, not because training
        converged — policies reading rel_change as a convergence signal
        (ILE's doubling, the trigger's optional ε) should hold their
        decision on such rounds."""

    def should_sync(self, div: float, round_i: int, delta=None) -> bool:
        """Host-side gate decision (python engine). Must implement the
        same decision as :meth:`traced_should_sync`; ``delta`` overrides
        the policy's static threshold when :meth:`round_delta` moved it
        for this round (membership-forced syncs)."""
        return True

    def round_delta(self, events: tuple = ()):
        """The round's divergence threshold as the engines consume it —
        traced into the fused gate, passed to :meth:`should_sync` by the
        python engine. The base is the static ``delta``; gated policies
        may move it per round (e.g. force a sync when the membership
        changed). Host hook: never retraces."""
        return self.delta

    def traced_should_sync(self, div, delta):
        """The gate as the fused engine embeds it on-device: ``div`` is
        the traced divergence, ``delta`` the traced threshold. Override
        together with :meth:`should_sync` (the engines' equivalence
        depends on the two agreeing); swaps between policies with
        different traced gates go through ``CoLearner.set_sync_policy``
        so the engine can rebind."""
        return div > delta

    def epochs_budget(self, T: int, round_i: int, global_epoch: int,
                      max_rounds: int) -> int:
        """Policy-aware total-epoch estimate at the start of ``round_i``:
        epochs already run plus the current T_i extrapolated over the
        remaining rounds. Exact for fixed-T policies (= T0·max_rounds);
        re-estimated after every ILE doubling — which the old static
        ``T0 * max_rounds`` budget ignored, stranding the ELR anneal far
        from its floor."""
        return max(global_epoch + T * max(max_rounds - round_i, 1), 1)


@dataclasses.dataclass(frozen=True)
class ILE(SyncPolicy):
    """Paper Eq. 4: double T_i when the relative change of the averaged
    model falls to <= ε; always communicates."""

    epsilon: float = 0.01
    name = "ile"

    def update(self, state, round_i, rel_change, synced=True, events=()):
        # hold the doubling on membership-change rounds: the Eq. 4 metric
        # moved because the live set did, not because training stabilized
        T = (2 * state.T if rel_change <= self.epsilon and not events
             else state.T)
        return dataclasses.replace(
            state, T=T, history=state.history + ((round_i, rel_change, T),))


@dataclasses.dataclass(frozen=True)
class FLE(SyncPolicy):
    """Fixed local epochs (the FedAvg-style ablation baseline): T_i = T0
    forever; always communicates."""

    name = "fle"

    def update(self, state, round_i, rel_change, synced=True, events=()):
        return dataclasses.replace(
            state,
            history=state.history + ((round_i, rel_change, state.T),))


@dataclasses.dataclass(frozen=True)
class DivergenceTrigger(SyncPolicy):
    """Dynamic model averaging (Kamp et al., 1807.03210): communicate only
    while the local models diverge.

    After the round's local epochs, the engines compute the participants'
    RMS relative drift from the last *synced* shared model
    (``schedule.divergence_traced``). While that stays <= δ the round is
    *quiet*: the averaging/wire step is skipped outright, the participants
    keep their local params and optimizer state, and the round bills ZERO
    comm bytes. Once accumulated drift exceeds δ the next round syncs as
    usual. ``epsilon`` optionally adds the Eq. 4 doubling on synced rounds
    (None = keep T fixed, the equal-budget baseline).
    """

    delta: float = 0.05
    epsilon: float | None = None
    name = "divtrigger"
    divergence_gated = True

    def should_sync(self, div, round_i, delta=None):
        return div > (self.delta if delta is None else delta)

    def round_delta(self, events=()):
        # a membership change forces the sync: a rejoining participant
        # needs the current shared model on the wire, and a leave shifts
        # the live average — the divergence (>= 0) always exceeds -1, so
        # the round communicates regardless of how quiet the locals are.
        # Pure traced data: the forced round reuses the compiled gate.
        if events:
            return -1.0
        return self.delta

    def update(self, state, round_i, rel_change, synced=True, events=()):
        T = state.T
        if (synced and not events and self.epsilon is not None
                and rel_change <= self.epsilon):
            T = 2 * state.T
        skipped = state.skipped if synced else state.skipped + (round_i,)
        return dataclasses.replace(
            state, T=T, skipped=skipped,
            history=state.history + ((round_i, rel_change, T),))


# ---------------------------------------------------------------------------
# RoundEngine
# ---------------------------------------------------------------------------
class RoundEngine(abc.ABC):
    """How a round executes. ``bind(learner)`` compiles the engine's
    artifacts against the learner's loss/opt/aggregate and returns a runner
    with ``run_round(state, epoch_batches_fn) -> state``. Both engines
    apply the identical state transition (``CoLearner._finish_round``)."""

    name: str = "engine"

    @abc.abstractmethod
    def bind(self, learner):
        """Return a runner object for this learner."""


@dataclasses.dataclass(frozen=True)
class PythonEngine(RoundEngine):
    """Reference path: a host loop dispatching one jitted epoch at a time,
    host-side Eq. 3 learning rates and Eq. 4 metric."""

    name = "python"

    def bind(self, learner):
        return _PythonRunner(learner)


@dataclasses.dataclass(frozen=True)
class FusedEngine(RoundEngine):
    """One donated XLA executable per round (``repro.core.engine``): T_i-
    epoch scan with the Eq. 3 schedule traced in-scan, aggregation, and the
    on-device Eq. 4 metric, one host sync. Rounds longer than ``chunk``
    epochs chain traced-offset chunk executables + a finalize executable to
    bound staged-batch memory (still one final sync)."""

    chunk: int = 32
    name = "fused"

    def bind(self, learner):
        return _FusedRunner(learner, self.chunk)


def _live_loss_means(losses, live_np):
    """Per-epoch mean loss over the LIVE participants (all K when
    ``live_np`` is None — the static path, bit-compatible)."""
    if live_np is None:
        return [float(np.asarray(x).mean()) for x in losses]
    w = np.asarray(live_np, np.float32)
    n_live = max(float(w.sum()), 1.0)
    return [float((np.asarray(x) * w).sum() / n_live) for x in losses]


def _gate_accepts_delta(policy) -> bool:
    """Whether the policy's host gate takes the per-round ``delta``
    override. Subclasses written before elastic membership override
    ``should_sync(self, div, round_i)`` without it; they still gate on
    the static threshold, so call them with the legacy signature."""
    try:
        params = inspect.signature(type(policy).should_sync).parameters
    except (TypeError, ValueError):
        return True
    return "delta" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


class _PythonRunner:
    def __init__(self, learner):
        self.learner = learner
        self._stateful = getattr(learner, "_round_stateful",
                                 getattr(learner.codec, "stateful", False))
        self._jit_agg = jax.jit(learner._aggregate_fn)

    def run_round(self, state, epoch_batches_fn):
        learner = self.learner
        policy = learner.sync_policy
        i = state["round"]
        T_i = state["ctrl"].T
        ge0 = state["global_epoch"]
        total = learner.epochs_budget(state)
        sync_ref = learner._sync_ref(state)
        mask = learner.batch_mask
        # elastic membership: the liveness row rides into the jitted epoch
        # as traced data (None on the static path — bit-identical)
        live_np = learner._live_np(state)
        live_row = (None if live_np is None
                    else engine_mod.stage(live_np, np.float32))
        lrs, losses = [], []
        for j in range(T_i):
            lr = float(learner.schedule.lr(i, j, T_i, ge0 + j, total))
            lrs.append(lr)
            batches = epoch_batches_fn(i, j)
            args = (batches, lr)
            if mask is not None:
                args += (mask,)
            if live_row is not None:
                args += (live_row,)
            params, opt, l = learner._jit_epoch(
                state["params"], state["opt"], *args)
            state["params"], state["opt"] = params, opt
            losses.append(jax.device_get(l))

        if policy.divergence_gated:
            div = sched_mod.divergence(state["params"], sync_ref, live_np)
            if _gate_accepts_delta(policy):
                synced = bool(policy.should_sync(
                    div, i, delta=learner._round_delta(state)))
            else:
                # legacy SyncPolicy subclass: should_sync(div, round_i)
                # predates the membership delta override — honor it as-is
                synced = bool(policy.should_sync(div, i))
        else:
            div, synced = None, True
        if synced:
            # aggregate (Eq. 2 / partial / gossip) over the codec's wire;
            # a stateful codec (error feedback) threads the residual in
            # and out of the same jitted aggregate
            if self._stateful:
                averaged, new_res = self._jit_agg(
                    state["params"], learner.round_weights(i, state),
                    state["residual"])
            else:
                averaged = self._jit_agg(state["params"],
                                         learner.round_weights(i, state))
                new_res = None
            k0 = 0 if live_np is None else int(np.argmax(live_np))
            new_avg = averaging.unstack_participant(averaged, k0)
            rel = (float("inf") if state["prev_avg"] is None
                   else relative_change(new_avg, state["prev_avg"]))
            fresh_opt = jax.vmap(learner.opt.init)(averaged)
            if live_row is not None:
                # dead rows: identity carry — no download, own opt kept,
                # and (stateful) their residual memory is frozen too
                averaged = engine_mod.select_live(live_row, averaged,
                                                  state["params"])
                fresh_opt = engine_mod.select_live(live_row, fresh_opt,
                                                   state["opt"])
                if self._stateful:
                    new_res = engine_mod.select_live(live_row, new_res,
                                                     state["residual"])
        else:
            # quiet round (Kamp): keep local params AND optimizer state,
            # reference unchanged, nothing crosses the wire (the residual
            # memory is untouched — nothing was quantized)
            averaged, fresh_opt = state["params"], state["opt"]
            new_avg, rel = sync_ref, div
            new_res = state.get("residual")
        return learner._finish_round(state, i, T_i, rel,
                                     _live_loss_means(losses, live_np),
                                     lrs[0], lrs[-1], averaged, fresh_opt,
                                     new_avg, synced=synced,
                                     residual=new_res)


class _FusedRunner:
    def __init__(self, learner, chunk):
        self.learner = learner
        self.chunk = chunk
        self._gated = learner.sync_policy.divergence_gated
        self._masked = learner.batch_mask is not None
        # the traced schedule body / sync gate the executables were
        # compiled against; every built-in LRSchedule shares
        # schedule.switch_lr (and built-in policies the default gate), so
        # CoLearner.set_schedule/set_sync_policy hot-swap without
        # touching the caches
        self._traced_lr = traced_body(learner.schedule)
        self._traced_gate = type(learner.sync_policy).traced_should_sync
        gate_fn = learner.sync_policy.traced_should_sync
        # elastic membership: build the live-row variants once; membership
        # changes then ride in as traced data (zero retraces)
        self._live = learner._churn_active
        # stateful round (codec error feedback and/or aggregator state,
        # e.g. the D² correction): the state rides through the round/
        # finalize executables as traced data right after opt_state (the
        # chunk executables never touch it — it is consumed at finalize)
        self._stateful = getattr(learner, "_round_stateful",
                                 getattr(learner.codec, "stateful", False))
        self._round = engine_mod.make_fused_round(
            learner.loss_fn, learner.opt, lr_fn=self._traced_lr,
            aggregate_fn=learner._aggregate_fn, gated=self._gated,
            gate_fn=gate_fn, masked=self._masked, live=self._live,
            stateful=self._stateful)
        self._epochs = engine_mod.make_fused_epochs(
            learner.loss_fn, learner.opt, lr_fn=self._traced_lr,
            masked=self._masked, live=self._live)
        self._finalize = engine_mod.make_fused_finalize(
            learner.opt, aggregate_fn=learner._aggregate_fn,
            gated=self._gated, gate_fn=gate_fn, live=self._live,
            stateful=self._stateful)

    def run_round(self, state, epoch_batches_fn):
        """One round as one (or, past ``chunk`` epochs, a few chained)
        donated executables — zero host syncs until the final aux fetch."""
        learner = self.learner
        if traced_body(learner.schedule) is not self._traced_lr:
            raise RuntimeError(
                "the learner's schedule carries a different traced_lr than "
                "the compiled round executables; swap schedules with "
                "CoLearner.set_schedule(...) so the engine can rebind")
        if (learner.sync_policy.divergence_gated != self._gated
                or type(learner.sync_policy).traced_should_sync
                is not self._traced_gate):
            raise RuntimeError(
                "the learner's sync policy gating does not match the "
                "compiled round executables; swap policies with "
                "CoLearner.set_sync_policy(...) so the engine can rebind")
        gated = self._gated
        i = state["round"]
        T_i = state["ctrl"].T
        # per-round host quantities are staged EXPLICITLY (device_put via
        # engine_mod.stage): an implicit transfer here — jnp.int32 on a
        # python scalar, numpy riding into the donated call — is exactly
        # what guards.no_transfer() pins the round loop against
        ge0 = engine_mod.stage(state["global_epoch"], np.int32)
        sched = learner.schedule.device_round_params(i)
        total = engine_mod.stage(learner.epochs_budget(state), np.int32)
        agg_w = learner.round_weights(i, state)
        if gated:
            sync_ref = learner._sync_ref(state)
            delta = engine_mod.stage(learner._round_delta(state),
                                     np.float32)
        div_dev, sync_dev = None, True
        # the ragged-shard validity mask rides in traced right after the
        # staged batches (absent entirely on the unmasked executables);
        # the liveness row (elastic membership) follows the same way
        mask_args = (learner.batch_mask,) if self._masked else ()
        live_np = learner._live_np(state)
        if self._live:
            live_row = engine_mod.stage(live_np, np.float32)
            mask_args = mask_args + (live_row,)
        # state["params"]/["opt"] are reassigned immediately after every
        # donating call below, so an exception mid-round (e.g. from
        # epoch_batches_fn) can never leave state holding deleted buffers.
        if T_i <= self.chunk:
            batches = engine_mod.stack_epoch_batches(
                [epoch_batches_fn(i, j) for j in range(T_i)])
            # stateful codec: the residual rides in right after opt_state
            # and comes back in the aux dict (device-side, like new_avg)
            lead = ((state["params"], state["opt"], state["residual"])
                    if self._stateful else (state["params"], state["opt"]))
            if gated:
                out_p, out_o, aux = self._round(
                    *lead, batches, *mask_args,
                    ge0, sched, total, sync_ref, delta, agg_w)
            else:
                out_p, out_o, aux = self._round(
                    *lead, batches, *mask_args,
                    ge0, sched, total, agg_w)
            state["params"], state["opt"] = out_p, out_o
            if self._stateful:
                state["residual"] = aux["residual"]
            new_avg = aux["new_avg"]
            # the round's single host sync (scalars/loss curves only — the
            # aggregated model itself stays on device)
            losses, lrs, rel_dev = jax.device_get(
                (aux["losses"], aux["lrs"], aux["rel"]))
            if gated:
                div_dev, sync_dev = jax.device_get(
                    (aux["div"], aux["synced"]))
        else:
            # staging all T_i epochs at once would cost device memory linear
            # in T_i (which ILE doubles); chain chunk executables instead.
            # j0/T_i/ge0/sched/total are traced, so chunks reuse one
            # compiled program across doublings AND schedule swaps.
            if not gated:
                # the entry shared model sits in the first LIVE slot (slot
                # 0 on the static path)
                k0 = 0 if live_np is None else int(np.argmax(live_np))
                old_avg = averaging.unstack_participant(state["params"], k0)
            lparts, rparts, j0 = [], [], 0
            while j0 < T_i:
                C = min(self.chunk, T_i - j0)
                batches = engine_mod.stack_epoch_batches(
                    [epoch_batches_fn(i, j) for j in range(j0, j0 + C)])
                params, opt_st, l, r = self._epochs(
                    state["params"], state["opt"], batches, *mask_args,
                    engine_mod.stage(j0, np.int32),
                    engine_mod.stage(T_i, np.int32), ge0, sched, total)
                state["params"], state["opt"] = params, opt_st
                lparts.append(l)
                rparts.append(r)
                j0 += C
            # stateful codec: the residual enters finalize right after
            # opt_state (after params on the opt-free static variant) and
            # a new residual is appended to the outputs
            res_in = (state["residual"],) if self._stateful else ()
            if gated:
                fin_args = ((sync_ref, delta, live_row, agg_w) if self._live
                            else (sync_ref, delta, agg_w))
                out = self._finalize(state["params"], state["opt"],
                                     *res_in, *fin_args)
                if self._stateful:
                    (out_p, out_o, rel_t, div_t, sync_t, new_avg,
                     out_res) = out
                    state["residual"] = out_res
                else:
                    out_p, out_o, rel_t, div_t, sync_t, new_avg = out
                state["params"], state["opt"] = out_p, out_o
                lparts, rparts, rel_dev, div_dev, sync_dev = jax.device_get(
                    (lparts, rparts, rel_t, div_t, sync_t))
            else:
                if self._live:
                    # live variant threads opt_state so dead rows keep it
                    out = self._finalize(
                        state["params"], state["opt"], *res_in, old_avg,
                        live_row, agg_w)
                else:
                    out = self._finalize(
                        state["params"], *res_in, old_avg, agg_w)
                if self._stateful:
                    out_p, out_o, rel_t, new_avg, out_res = out
                    state["residual"] = out_res
                else:
                    out_p, out_o, rel_t, new_avg = out
                state["params"], state["opt"] = out_p, out_o
                lparts, rparts, rel_dev = jax.device_get(
                    (lparts, rparts, rel_t))
            losses = np.concatenate(lparts)
            lrs = np.concatenate(rparts)
        synced = bool(sync_dev)
        if not synced:
            rel = float(div_dev)
        elif state["prev_avg"] is None:
            rel = float("inf")
        else:
            rel = float(rel_dev)
        return learner._finish_round(state, i, T_i, rel,
                                     _live_loss_means(losses, live_np),
                                     float(lrs[0]), float(lrs[-1]),
                                     out_p, out_o, new_avg, synced=synced)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------
#: name -> factory(**kw) -> WireCodec. Codec factories accept block=/impl=.
CODECS: dict = {}
#: name -> factory(**kw) -> Aggregator.
AGGREGATORS: dict = {}
#: name -> factory(**kw) -> RoundEngine. Engine factories accept chunk=.
ENGINES: dict = {}
#: name -> factory(**kw) -> LRSchedule. Factories accept eta0=/decay_rate=.
SCHEDULES: dict = {}
#: name -> factory(**kw) -> SyncPolicy. Factories accept epsilon=/delta=.
SYNC_POLICIES: dict = {}


def register_codec(name, factory):
    CODECS[name] = factory
    return factory


def register_aggregator(name, factory):
    AGGREGATORS[name] = factory
    return factory


def register_engine(name, factory):
    ENGINES[name] = factory
    return factory


def register_schedule(name, factory):
    SCHEDULES[name] = factory
    return factory


def register_sync_policy(name, factory):
    SYNC_POLICIES[name] = factory
    return factory


def _leafwise_codec(block=DEFAULT_BLOCK, impl="ref", bits=8,
                    error_feedback=False):
    """``bits=8`` without error feedback resolves to the LeafwiseInt8
    class so registry/back-compat isinstance pins keep holding."""
    if bits == 8 and not error_feedback:
        return LeafwiseInt8(block=block, impl=impl)
    return LeafwiseIntN(block=block, impl=impl, bits=bits,
                        error_feedback=error_feedback)


def _flat_codec(block=DEFAULT_BLOCK, impl="ref", bits=8,
                error_feedback=False):
    if bits == 8 and not error_feedback:
        return FlatFusedInt8(block=block, impl=impl)
    return FlatFusedIntN(block=block, impl=impl, bits=bits,
                         error_feedback=error_feedback)


register_codec("exact", lambda block=DEFAULT_BLOCK, impl="ref", bits=8,
               error_feedback=False: ExactF32())
register_codec("none", CODECS["exact"])
register_codec("leafwise", _leafwise_codec)
register_codec("int8", _leafwise_codec)        # legacy CLI alias
register_codec("fused", _flat_codec)
register_codec("flat", _flat_codec)            # alias
register_aggregator("full", FullAverage)
register_aggregator("partial", PartialParticipation)
register_aggregator("ring", RingGossip)
register_aggregator("graph", GraphGossip)
register_aggregator("d2", D2Gossip)
register_engine("python", lambda chunk=32: PythonEngine())
register_engine("fused", FusedEngine)
register_schedule("clr", lambda eta0=0.01, decay_rate=0.25:
                  CLR(eta0, decay_rate))
register_schedule("elr", lambda eta0=0.01, decay_rate=0.25:
                  ELR(eta0, decay_rate))
register_schedule("warmup_clr", lambda eta0=0.01, decay_rate=0.25:
                  WarmupCLR(eta0, decay_rate))
register_schedule("warmup", SCHEDULES["warmup_clr"])       # alias
register_schedule("cosine", lambda eta0=0.01, decay_rate=0.25:
                  CosineCyclical(eta0))
# Sync-policy factories take (epsilon, delta, cfg_epsilon): ``epsilon`` is
# an EXPLICIT caller value, ``cfg_epsilon`` the CoLearnConfig fallback —
# split so divtrigger's optional Eq. 4 doubling engages only when asked
# for (the cfg's ε parameterizes ILE, not the trigger).
register_sync_policy("ile", lambda epsilon=None, delta=None,
                     cfg_epsilon=None:
                     ILE(epsilon=next(e for e in (epsilon, cfg_epsilon,
                                                  0.01) if e is not None)))
register_sync_policy("fle", lambda epsilon=None, delta=None,
                     cfg_epsilon=None: FLE())
register_sync_policy("divtrigger", lambda epsilon=None, delta=None,
                     cfg_epsilon=None:
                     DivergenceTrigger(
                         delta=0.05 if delta is None else delta,
                         epsilon=epsilon))
register_sync_policy("divergence", SYNC_POLICIES["divtrigger"])  # alias


def _resolve(spec, registry, default, proto, kind, **kw):
    if spec is None:
        return default()
    if isinstance(spec, proto):
        return spec
    if isinstance(spec, str):
        try:
            factory = registry[spec]
        except KeyError:
            raise KeyError(f"unknown {kind} {spec!r}; registered: "
                           f"{sorted(registry)}") from None
        return factory(**kw)
    raise TypeError(f"{kind} must be None, a registry name, or a "
                    f"{proto.__name__}; got {spec!r}")


def get_codec(spec=None, *, block=DEFAULT_BLOCK, impl="ref", bits=8,
              error_feedback=False) -> WireCodec:
    """None | registry name | WireCodec instance -> WireCodec.

    ``bits`` (8 | 4 | 1) and ``error_feedback`` parameterize the
    quantizing registry names ("leafwise"/"int8", "fused"/"flat"); the
    exact codecs ignore them and instances pass through unchanged."""
    return _resolve(spec, CODECS, ExactF32, WireCodec, "codec",
                    block=block, impl=impl, bits=bits,
                    error_feedback=error_feedback)


def get_aggregator(spec=None, **kw) -> Aggregator:
    """None | registry name | Aggregator instance -> Aggregator.

    Registered names: ``"full"`` (Eq. 2 / example-count-weighted FedAvg),
    ``"partial"`` (FedAvg-style sampled participation), ``"ring"`` (the
    legacy directed-ring gossip — ``GraphGossip`` over ``RingTopology``),
    ``"graph"`` (gossip over any :mod:`repro.core.topology` graph; pass
    ``topology="grid2d" | "hypercube" | "exponential" | "erdos_renyi" |
    "complete"`` or a Topology instance), ``"d2"`` (``GraphGossip`` plus
    the D² variance-reduction correction for non-IID shards)."""
    return _resolve(spec, AGGREGATORS, FullAverage, Aggregator,
                    "aggregator", **kw)


def get_engine(spec=None, *, chunk=32) -> RoundEngine:
    """None | registry name | RoundEngine instance -> RoundEngine."""
    return _resolve(spec, ENGINES, PythonEngine, RoundEngine, "engine",
                    chunk=chunk)


def get_schedule(spec=None, cfg=None, *, eta0=None,
                 decay_rate=None) -> LRSchedule:
    """None | registry name | LRSchedule instance -> LRSchedule.

    ``None`` resolves the legacy ``cfg.schedule`` string ("clr" | "elr");
    registry names take η0/decay from ``cfg`` (or the explicit keywords),
    so ``CoLearner(schedule="clr")`` is the flag surface, object-shaped.
    """
    if spec is None:
        spec = cfg.schedule if cfg is not None else "clr"
    if eta0 is None:
        eta0 = cfg.eta0 if cfg is not None else 0.01
    if decay_rate is None:
        decay_rate = cfg.decay_rate if cfg is not None else 0.25
    return _resolve(spec, SCHEDULES, CLR, LRSchedule, "schedule",
                    eta0=eta0, decay_rate=decay_rate)


def get_sync_policy(spec=None, cfg=None, *, epsilon=None,
                    delta=None) -> SyncPolicy:
    """None | registry name | SyncPolicy instance -> SyncPolicy.

    ``None`` resolves the legacy ``cfg.epochs_rule`` string ("ile" |
    "fle"). "ile" takes ε from the explicit keyword, else from ``cfg``;
    "divtrigger" takes ``delta`` plus an optional EXPLICIT ``epsilon`` to
    enable Eq. 4 doubling on synced rounds (the cfg's ε does NOT leak into
    the trigger — its default is fixed-T, the equal-budget baseline).
    """
    if spec is None:
        spec = cfg.epochs_rule if cfg is not None else "ile"
    return _resolve(spec, SYNC_POLICIES, ILE, SyncPolicy, "sync policy",
                    epsilon=epsilon, delta=delta,
                    cfg_epsilon=cfg.epsilon if cfg is not None else None)
