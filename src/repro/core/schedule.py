"""Learning-rate math (Eq. 3 family) and Eq. 4 metrics, as pure functions.

CLR — the paper's "modified cyclical learning rate": within round *i* the
rate decays exponentially from the shared η^i over the round's T_i epochs,
``η_j^i = η^i · r^(j/T_i)`` (r = 1/4), and *restarts* at η^i when the next
round begins — the cycle period is the communication round itself.

ELR — the non-cyclical ablation baseline: the same exponential anneal but
over *global* epochs, never restarting.

The *policy* layer — which formula a run uses, the per-round η^i, and the
Eq. 4 local-epoch control — lives in ``repro.core.api`` as the
:class:`~repro.core.api.LRSchedule` / :class:`~repro.core.api.SyncPolicy`
protocols. This module keeps the formulas themselves plus the shared traced
combinator (:func:`switch_lr`) the fused engine embeds: every built-in
schedule lowers to the same ``lax.switch`` over the branch family below
with its scalars riding in as traced arguments, so swapping schedules or
re-parameterizing one mid-run reuses the compiled round executables.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


def clr_lr(eta_i: float, decay_rate: float, epoch_j, T_i):
    """Eq. 3: η_j^i = η^i · r^(j/T_i). Any argument may be traced."""
    return eta_i * decay_rate ** (epoch_j / T_i)


def elr_lr(eta_0: float, decay_rate: float, global_epoch, total_epochs):
    """Non-cyclical baseline: one long anneal over the whole run."""
    return eta_0 * decay_rate ** (global_epoch / total_epochs)


def cosine_lr(eta_i: float, eta_min: float, epoch_j, T_i):
    """Cosine anneal within the round, restarting at η^i each round (the
    SGDR-style cyclical variant of Eq. 3)."""
    phase = jnp.cos(jnp.pi * (epoch_j / T_i))
    return eta_min + 0.5 * (eta_i - eta_min) * (1.0 + phase)


# --- the shared traced combinator ------------------------------------------
# Branch indices of ``switch_lr``. Every built-in LRSchedule compiles to the
# SAME jaxpr — a lax.switch over these branches with (kind, p) traced — so
# the fused round executables are reused across schedule swaps and per-round
# re-parameterizations (e.g. a warmup ramping η^i).
LR_EXP_ROUND = 0      # η · r^(j/T_i)            — CLR / WarmupCLR (Eq. 3)
LR_EXP_GLOBAL = 1     # η · r^(ge/total)         — ELR
LR_COS_ROUND = 2      # cosine anneal within the round, per-round restart
N_SCHED_PARAMS = 4    # fixed length of the traced parameter vector ``p``


def switch_lr(sched, epoch_j, T_i, global_epoch, total_epochs):
    """The traced per-epoch learning rate shared by all built-in schedules.

    ``sched`` is ``{"kind": int32, "p": float32[N_SCHED_PARAMS]}`` — the
    device form of ``LRSchedule.round_params`` — with
    ``p = [eta_i, decay_rate, aux0, aux1]``. All other arguments may be
    traced; nothing here retriggers compilation.
    """
    p = sched["p"]

    def exp_round():
        return clr_lr(p[0], p[1], epoch_j, T_i)

    def exp_global():
        return elr_lr(p[0], p[1], global_epoch,
                      jnp.maximum(total_epochs, 1))

    def cos_round():
        return cosine_lr(p[0], p[2], epoch_j, T_i)

    return jax.lax.switch(sched["kind"],
                          (exp_round, exp_global, cos_round))


def round_lr(colearn_cfg, round_i: int, epoch_j, T_i: int, global_epoch,
             total_epochs: int):
    """Legacy flag-surface helper: the per-epoch rate under the config's
    ``schedule`` string ("clr" | "elr"). Kept for the pre-PR-4 callers and
    tests; new code goes through ``api.get_schedule(...).lr(...)``."""
    if colearn_cfg.schedule == "clr":
        return clr_lr(colearn_cfg.eta0, colearn_cfg.decay_rate, epoch_j, T_i)
    return elr_lr(colearn_cfg.eta0, colearn_cfg.decay_rate, global_epoch,
                  max(total_epochs, 1))


# ---------------------------------------------------------------------------
# Eq. 4 controller (legacy shim — see api.SyncPolicy for the protocol form)
# ---------------------------------------------------------------------------
@dataclass
class EpochController:
    """Server-side state deciding T_i each round (Eq. 4).

    Legacy flag-driven controller; the composable replacement is
    ``api.ILE`` / ``api.FLE`` / ``api.DivergenceTrigger`` operating on an
    ``api.SyncState``. Kept for direct users of the old surface.
    """
    T: int
    epsilon: float
    rule: str = "ile"                 # ile | fle
    history: tuple = ()               # (round, rel_change, T) triples

    def update(self, rel_change: float) -> "EpochController":
        """Called after round i computed w̄^i; returns controller for i+1.

        The stored round index is the number of completed updates — one
        ``update`` per round, starting at round 0.
        """
        T = self.T
        if self.rule == "ile" and rel_change <= self.epsilon:
            T = 2 * self.T
        entry = (len(self.history), rel_change, T)
        return dataclasses.replace(self, T=T, history=self.history + (entry,))


def relative_change_traced(new_avg, old_avg):
    """Eq. 4 metric as a traced scalar — usable inside jit/scan.

    ‖w̄^i − w̄^{i−1}‖ / ‖w̄^{i−1}‖ over the flattened parameter pytree,
    accumulated on-device in float32. The fused round engine embeds this
    right after Eq. 2 averaging so the whole round has one host sync.
    """
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    for a, b in zip(jax.tree.leaves(new_avg), jax.tree.leaves(old_avg)):
        d = a.astype(jnp.float32) - b.astype(jnp.float32)
        num += jnp.sum(d * d)
        den += jnp.sum(b.astype(jnp.float32) ** 2)
    return jnp.sqrt(num) / jnp.maximum(jnp.sqrt(den), 1e-12)


@jax.jit
def _relative_change_jit(new_avg, old_avg):
    return relative_change_traced(new_avg, old_avg)


def relative_change(new_avg, old_avg) -> float:
    """Host-facing Eq. 4 metric: one jitted reduction, one device_get.

    (The previous implementation pulled two scalars to the host per
    parameter leaf — 2·n_leaves blocking transfers per round.)
    """
    return float(jax.device_get(_relative_change_jit(new_avg, old_avg)))


def divergence_traced(stacked, ref, live=None):
    """Kamp-style (1807.03210) local-model divergence, traced.

    RMS over the K participants of the drift from the last *synced* shared
    model, relative to that model's norm:
    ``sqrt(mean_k ‖w_k − w_ref‖²) / ‖w_ref‖``. A
    :class:`~repro.core.api.DivergenceTrigger` sync policy communicates
    only while this exceeds its δ — quiet rounds skip the wire entirely.

    ``live`` (elastic membership): a traced 0/1 float (K,) liveness row;
    the RMS then runs over the LIVE participants only — a dead slot's
    stale parameters neither inflate nor dilute the drift signal. ``None``
    keeps the exact static-K reduction (bit-compatible).
    """
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    K = jax.tree.leaves(stacked)[0].shape[0]
    if live is None:
        for t, r in zip(jax.tree.leaves(stacked), jax.tree.leaves(ref)):
            d = t.astype(jnp.float32) - r.astype(jnp.float32)[None]
            num += jnp.sum(d * d)
            den += jnp.sum(r.astype(jnp.float32) ** 2)
        return jnp.sqrt(num / K) / jnp.maximum(jnp.sqrt(den), 1e-12)

    w = live.astype(jnp.float32)
    for t, r in zip(jax.tree.leaves(stacked), jax.tree.leaves(ref)):
        d = t.astype(jnp.float32) - r.astype(jnp.float32)[None]
        per_k = jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
        num += jnp.sum(w * per_k)
        den += jnp.sum(r.astype(jnp.float32) ** 2)
    n_live = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.sqrt(num / n_live) / jnp.maximum(jnp.sqrt(den), 1e-12)


@jax.jit
def _divergence_jit(stacked, ref):
    return divergence_traced(stacked, ref)


@jax.jit
def _divergence_live_jit(stacked, ref, live):
    return divergence_traced(stacked, ref, live)


def divergence(stacked, ref, live=None) -> float:
    """Host-facing divergence: one jitted reduction, one device_get."""
    if live is None:
        return float(jax.device_get(_divergence_jit(stacked, ref)))
    return float(jax.device_get(_divergence_live_jit(
        stacked, ref, jnp.asarray(live, jnp.float32))))
