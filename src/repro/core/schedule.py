"""Learning-rate schedules (Eq. 3) and local-epoch controllers (Eq. 4).

CLR — the paper's "modified cyclical learning rate": within round *i* the
rate decays exponentially from the shared η^i over the round's T_i epochs,
``η_j^i = η^i · r^(j/T_i)`` (r = 1/4), and *restarts* at η^i when the next
round begins — the cycle period is the communication round itself.

ELR — the non-cyclical ablation baseline: the same exponential anneal but
over *global* epochs, never restarting.

ILE — Eq. 4: double T_i when the relative change of the averaged model
falls to ≤ ε; FLE keeps T_i = T_0 (the FedAvg-style ablation baseline).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


def clr_lr(eta_i: float, decay_rate: float, epoch_j, T_i):
    """Eq. 3: η_j^i = η^i · r^(j/T_i). epoch_j may be traced."""
    return eta_i * decay_rate ** (epoch_j / T_i)


def elr_lr(eta_0: float, decay_rate: float, global_epoch, total_epochs):
    """Non-cyclical baseline: one long anneal over the whole run."""
    return eta_0 * decay_rate ** (global_epoch / total_epochs)


def round_lr(colearn_cfg, round_i: int, epoch_j, T_i: int, global_epoch,
             total_epochs: int):
    """The per-epoch learning rate under the configured schedule."""
    if colearn_cfg.schedule == "clr":
        return clr_lr(colearn_cfg.eta0, colearn_cfg.decay_rate, epoch_j, T_i)
    return elr_lr(colearn_cfg.eta0, colearn_cfg.decay_rate, global_epoch,
                  max(total_epochs, 1))


# ---------------------------------------------------------------------------
# Eq. 4 controller
# ---------------------------------------------------------------------------
@dataclass
class EpochController:
    """Server-side state deciding T_i each round (Eq. 4)."""
    T: int
    epsilon: float
    rule: str = "ile"                 # ile | fle
    history: tuple = ()               # (round, rel_change, T) log

    def update(self, rel_change: float) -> "EpochController":
        """Called after round i computed w̄^i; returns controller for i+1."""
        T = self.T
        if self.rule == "ile" and rel_change <= self.epsilon:
            T = 2 * self.T
        return dataclasses.replace(
            self, T=T, history=self.history + ((rel_change, T),))


def relative_change_traced(new_avg, old_avg):
    """Eq. 4 metric as a traced scalar — usable inside jit/scan.

    ‖w̄^i − w̄^{i−1}‖ / ‖w̄^{i−1}‖ over the flattened parameter pytree,
    accumulated on-device in float32. The fused round engine embeds this
    right after Eq. 2 averaging so the whole round has one host sync.
    """
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    for a, b in zip(jax.tree.leaves(new_avg), jax.tree.leaves(old_avg)):
        d = a.astype(jnp.float32) - b.astype(jnp.float32)
        num += jnp.sum(d * d)
        den += jnp.sum(b.astype(jnp.float32) ** 2)
    return jnp.sqrt(num) / jnp.maximum(jnp.sqrt(den), 1e-12)


@jax.jit
def _relative_change_jit(new_avg, old_avg):
    return relative_change_traced(new_avg, old_avg)


def relative_change(new_avg, old_avg) -> float:
    """Host-facing Eq. 4 metric: one jitted reduction, one device_get.

    (The previous implementation pulled two scalars to the host per
    parameter leaf — 2·n_leaves blocking transfers per round.)
    """
    return float(jax.device_get(_relative_change_jit(new_avg, old_avg)))
