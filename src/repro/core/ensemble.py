"""Ensemble-learning baseline (paper Table 2).

Each participant trains independently on its disjoint shard (no parameter
exchange); at inference the *outputs* (post-softmax probabilities) are
averaged. The paper shows this loses ~10 accuracy points vs co-learning.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ensemble_logits(predict_fn, stacked_params, batch):
    """predict_fn(params, batch) -> logits. Averages probabilities over K."""
    probs = jax.vmap(lambda p: jax.nn.softmax(
        predict_fn(p, batch).astype(jnp.float32), -1))(stacked_params)
    return jnp.log(jnp.maximum(probs.mean(0), 1e-9))


def ensemble_accuracy(predict_fn, stacked_params, batch, labels):
    lp = ensemble_logits(predict_fn, stacked_params, batch)
    return jnp.mean((jnp.argmax(lp, -1) == labels).astype(jnp.float32))
