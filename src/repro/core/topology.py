"""Communication topologies for decentralized gossip (who talks to whom).

The paper's Eq. 2 is all-to-all averaging, but a multi-datacenter WAN is a
sparse graph: CDSGD (Jiang et al., 1706.07880) runs consensus SGD over any
fixed connected topology through a doubly-stochastic mixing matrix, and
D² (Tang et al., 1803.07068) corrects the variance so decentralized
non-IID shards still converge. This module turns "the graph" into a
first-class strategy object, consumed by ``api.GraphGossip(topology)`` /
``api.D2Gossip(topology)``:

  * ``Topology.adjacency(round, K)`` — bool (K, K), ``A[k, j]`` = "k
    receives from j" (symmetric for undirected graphs);
  * ``Topology.mixing_matrix(round, K, live=)`` — the row-stochastic
    (doubly stochastic when all-live) mixing weights. Undirected graphs
    get Metropolis–Hastings weights (symmetric, doubly stochastic for
    ANY degree profile); directed circulants (ring, one-peer
    exponential) use W = (I + P)/2. Liveness restricts to the live
    subgraph: dead rows become identity carries, a sole survivor keeps
    its own model, and when churn disconnects the live subgraph the
    mixing proceeds component-wise (block-diagonal — never across
    components) with a logged warning;
  * ``Topology.offsets`` / ``edge_perms`` — the neighbor-offset list for
    circulant graphs and its generalization, a decomposition of the
    directed edge set into whole permutations. The pod path issues one
    ``jax.lax.ppermute`` per permutation: O(degree) cross-pod traffic,
    never the dense-einsum K-way gather;
  * ``Topology.spectral_gap(K)`` — ``1 - |λ₂|`` of the (period-averaged,
    for time-varying graphs) mixing matrix: the consensus
    contraction-rate diagnostic;
  * ``Topology.validate(K)`` — the connectivity guard: BFS over the
    union graph of one period, rejecting disconnected topologies at
    learner construction instead of silently never reaching consensus.

Topologies may be time-varying (``adjacency(round, K)`` depends on the
round): the per-round matrix rides into the unchanged donated round
executables as traced data, so graph changes never recompile
(``benchmarks/round_latency.py --check-retrace`` pins this).

Registry: ``ring`` (directed cycle — the legacy ``RingGossip`` graph),
``grid2d``/``torus`` (2-D torus), ``hypercube`` (K a power of two),
``exponential`` (time-varying one-peer exponential graph),
``erdos_renyi(p, seed)`` (deterministic G(K, p) sample), ``complete``
(MH weights reduce to Eq. 2's uniform 1/K matrix). Resolve with
``get_topology(name | Topology | None)``.
"""
from __future__ import annotations

import abc
import dataclasses
import math
import warnings

import numpy as np

__all__ = [
    "Topology", "RingTopology", "Grid2DTopology", "HypercubeTopology",
    "ExponentialTopology", "ErdosRenyiTopology", "CompleteTopology",
    "TOPOLOGIES", "register_topology", "get_topology",
    "metropolis_weights", "component_labels", "is_connected",
]


# ---------------------------------------------------------------------------
# Graph helpers (host-side numpy — matrices are built once per
# (round-key, K, live-set) and cached by the aggregator)
# ---------------------------------------------------------------------------
def component_labels(adj) -> np.ndarray:
    """Connected-component label per node over the UNDIRECTED support of
    ``adj`` (labels are 0..n_components-1 in first-seen order)."""
    A = np.asarray(adj, bool)
    K = A.shape[0]
    und = A | A.T
    labels = np.full(K, -1, np.int64)
    n = 0
    for s in range(K):
        if labels[s] >= 0:
            continue
        stack = [s]
        labels[s] = n
        while stack:
            u = stack.pop()
            for v in np.nonzero(und[u])[0]:
                if labels[v] < 0:
                    labels[v] = n
                    stack.append(int(v))
        n += 1
    return labels


def is_connected(adj) -> bool:
    """True when every node reaches every other over the undirected
    support of ``adj`` (K <= 1 is trivially connected)."""
    A = np.asarray(adj, bool)
    if A.shape[0] <= 1:
        return True
    return int(component_labels(A).max()) == 0


def metropolis_weights(adj) -> np.ndarray:
    """Metropolis–Hastings mixing weights for an undirected graph:
    ``W[k, j] = 1 / (1 + max(deg_k, deg_j))`` on edges, diagonal takes the
    remainder. Symmetric and doubly stochastic for ANY degree profile —
    isolated nodes (and every node of a dead/live-masked row) get an
    identity row, so the same formula serves the live-subgraph case."""
    A = np.asarray(adj, bool).copy()
    np.fill_diagonal(A, False)
    K = A.shape[0]
    deg = A.sum(1)
    W = np.zeros((K, K), np.float64)
    ii, jj = np.nonzero(A)
    W[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    np.fill_diagonal(W, 1.0 - W.sum(1))
    return W.astype(np.float32)


def _check_live(live, K, name, round_index):
    live = np.asarray(live, bool)
    if live.shape != (K,):
        raise ValueError(f"live mask must have shape ({K},); got "
                         f"{live.shape}")
    if not live.any():
        raise ValueError(f"{name} gossip has zero live participants at "
                         f"round {round_index}")
    return live


# ---------------------------------------------------------------------------
# The Topology protocol
# ---------------------------------------------------------------------------
class Topology(abc.ABC):
    """A communication graph over K participants (possibly per-round).

    Subclasses implement ``adjacency``; the base class derives MH mixing
    weights, liveness handling (live-subgraph renormalization with a
    component-wise fallback), the circulant neighbor-offset list and its
    permutation decomposition for the sparse pod path, the spectral-gap
    diagnostic, and the construction-time connectivity guard. Directed
    topologies (``symmetric = False``) override ``mixing_matrix``.
    """

    name: str = "topology"
    #: True when ``adjacency(round, K)`` depends on the round; the graph
    #: repeats with period ``period(K)``.
    time_varying: bool = False
    #: True when the adjacency (and hence the MH matrix) is symmetric.
    symmetric: bool = True
    #: appended to the connectivity-guard error (e.g. a reseed hint).
    _disconnected_hint: str = ""

    @abc.abstractmethod
    def adjacency(self, round_index: int, K: int) -> np.ndarray:
        """Bool (K, K) adjacency for this round; ``A[k, j]`` means k
        RECEIVES from j. No self loops."""

    def period(self, K: int) -> int:
        """Number of rounds after which a time-varying graph repeats
        (1 for static graphs)."""
        return 1

    def union_adjacency(self, K: int) -> np.ndarray:
        """OR of the adjacency over one period — the graph whose
        connectivity decides whether consensus can ever be reached."""
        A = np.zeros((K, K), bool)
        for t in range(self.period(K)):
            A |= self.adjacency(t, K)
        return A

    def validate(self, K: int) -> "Topology":
        """Connectivity guard: reject a disconnected topology outright
        (BFS over the period-union graph). Called by ``CoLearner`` at
        construction via ``Aggregator.validate``."""
        if K < 1:
            raise ValueError(f"topology {self.name!r} needs K >= 1; "
                             f"got K={K}")
        if not is_connected(self.union_adjacency(K)):
            raise ValueError(
                f"topology {self.name!r} is disconnected at K={K}: gossip "
                f"over it can never reach consensus"
                f"{self._disconnected_hint}")
        return self

    def degree(self, round_index: int, K: int) -> int:
        """Max in-degree of this round's graph (the O(degree) comm
        factor)."""
        if K <= 1:
            return 0
        return int(self.adjacency(round_index, K).sum(1).max())

    def mixing_matrix(self, round_index: int, K: int,
                      live=None) -> np.ndarray:
        """Row-stochastic (K, K) f32 mixing weights for this round.

        All-live: Metropolis–Hastings on the round's graph — symmetric
        and doubly stochastic. ``live`` (elastic membership): MH on the
        LIVE SUBGRAPH (edges between live nodes only) — dead rows and
        isolated live nodes degrade to identity (sole survivor keeps its
        own model), and a live subgraph churn has split into components
        mixes block-diagonally (component-wise, never across), with a
        warning logged."""
        adj = self.adjacency(round_index, K)
        if live is None:
            return metropolis_weights(adj)
        live = _check_live(live, K, self.name, round_index)
        sub = adj & live[:, None] & live[None, :]
        self._warn_if_split(sub, live, round_index)
        return metropolis_weights(sub)

    def _warn_if_split(self, sub, live, round_index):
        idx = np.nonzero(live)[0]
        if len(idx) > 1:
            labels = component_labels(sub)
            if len(set(labels[idx])) > 1:
                warnings.warn(
                    f"churn disconnected the {self.name!r} gossip graph at "
                    f"round {round_index} (live={live.astype(int)}): "
                    f"mixing proceeds component-wise until peers rejoin",
                    RuntimeWarning, stacklevel=3)

    def offsets(self, round_index: int, K: int):
        """The neighbor-offset list when this round's graph is circulant
        (``A[k, (k + d) % K]`` for every k): a tuple of receive-offsets
        d, else None. The ring is ``(K - 1,)`` (receive from the
        predecessor), the static exponential graph ``(1, 2, 4, ...)``."""
        A = self.adjacency(round_index, K)
        k = np.arange(K)
        ds = []
        for d in range(1, K):
            col = A[k, (k + d) % K]
            if col.all():
                ds.append(d)
            elif col.any():
                return None
        return tuple(ds)

    def edge_perms(self, round_index: int, K: int):
        """Decompose this round's directed edge set into whole
        permutations of {0..K-1} — each a tuple of ``(src, dst)`` pairs,
        one ``jax.lax.ppermute`` each on the pod path. None when the
        graph admits no such decomposition (irregular graphs fall back
        to the dense traced mixing). Default: circulant offsets."""
        ds = self.offsets(round_index, K)
        if ds is None or K <= 1:
            return None
        # k receives from (k + d) % K, so source j sends to (j - d) % K
        return tuple(tuple((j, (j - d) % K) for j in range(K)) for d in ds)

    def in_neighbors(self, round_index: int, K: int):
        """Tuple (per node) of tuples of in-neighbor indices — the
        host-side "who do I receive from" view for diagnostics."""
        A = self.adjacency(round_index, K)
        return tuple(tuple(int(j) for j in np.nonzero(A[k])[0])
                     for k in range(K))

    def spectral_gap(self, K: int, round_index=None) -> float:
        """``1 - |λ₂|`` of the mixing matrix — the consensus
        contraction-rate diagnostic (0: disconnected / no mixing; 1:
        one-shot consensus, e.g. ``complete``). ``round_index=None``
        uses the period-AVERAGED matrix, since a single one-peer round
        of a time-varying graph is not connected on its own."""
        if K <= 1:
            return 1.0
        if round_index is None:
            W = np.mean([np.asarray(self.mixing_matrix(t, K), np.float64)
                         for t in range(self.period(K))], axis=0)
        else:
            W = np.asarray(self.mixing_matrix(round_index, K), np.float64)
        ev = np.sort(np.abs(np.linalg.eigvals(W)))[::-1]
        return float(1.0 - ev[1])


def _directed_pair_matrix(K, peer_of, live, name, round_index):
    """W = (I + P)/2 for a directed one-in-neighbor graph given
    ``peer_of[k]`` (k's in-neighbor, or k itself for "no peer"). Under
    liveness a live row whose peer is dead keeps its own model this
    round; dead rows are identity carries."""
    W = np.zeros((K, K), np.float32)
    if live is None:
        for k in range(K):
            W[k, k] += 0.5
            W[k, peer_of(k)] += 0.5
        return W
    live = _check_live(live, K, name, round_index)
    for k in range(K):
        p = peer_of(k)
        if not live[k] or p == k or not live[p]:
            W[k, k] = 1.0
        else:
            W[k, k] += 0.5
            W[k, p] += 0.5
    return W


# ---------------------------------------------------------------------------
# Concrete topologies
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RingTopology(Topology):
    """Directed cycle — the legacy ``RingGossip`` graph: participant k
    receives its ring predecessor's model, ``W = (I + P)/2`` (doubly
    stochastic, not symmetric). Liveness ROUTES to the nearest live
    predecessor (the graph heals around dead nodes instead of dropping
    their edges), matching the legacy matrix bit-for-bit."""

    name = "ring"
    symmetric = False

    def adjacency(self, round_index, K):
        A = np.zeros((K, K), bool)
        if K > 1:
            k = np.arange(K)
            A[k, (k - 1) % K] = True
        return A

    def mixing_matrix(self, round_index, K, live=None):
        if live is None:
            W = np.zeros((K, K), np.float32)
            for k in range(K):
                W[k, k] += 0.5
                W[k, (k - 1) % K] += 0.5
            return W
        # elastic membership: route around dead neighbors — each live
        # participant averages with its nearest LIVE ring predecessor; a
        # sole survivor (or a dead row, which the engine identity-carries
        # anyway) keeps its own model
        live = np.asarray(live, bool)
        if not live.any():
            raise ValueError(
                f"ring gossip has zero live participants at round "
                f"{round_index}")
        W = np.zeros((K, K), np.float32)
        for k in range(K):
            if not live[k]:
                W[k, k] = 1.0
                continue
            prev = (k - 1) % K
            while prev != k and not live[prev]:
                prev = (prev - 1) % K
            if prev == k:                       # sole live participant
                W[k, k] = 1.0
            else:
                W[k, k] += 0.5
                W[k, prev] += 0.5
        return W


@dataclasses.dataclass(frozen=True)
class Grid2DTopology(Topology):
    """2-D torus: K factors into the most-square R x C grid and each node
    links to its four wrap-around neighbors (fewer when an axis has
    length <= 2 — coincident neighbors collapse to one edge). A prime K
    degenerates to the undirected cycle (R=1)."""

    name = "grid2d"

    @staticmethod
    def shape(K):
        r = int(math.isqrt(K))
        while K % r:
            r -= 1
        return r, K // r

    def adjacency(self, round_index, K):
        R, C = self.shape(K)
        A = np.zeros((K, K), bool)
        for k in range(K):
            r, c = divmod(k, C)
            for rr, cc in ((r - 1, c), (r + 1, c), (r, c - 1), (r, c + 1)):
                n = (rr % R) * C + (cc % C)
                if n != k:
                    A[k, n] = A[n, k] = True
        return A

    def edge_perms(self, round_index, K):
        R, C = self.shape(K)
        out, seen = [], set()
        for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
            pairs, ok = [], True
            for k in range(K):
                r, c = divmod(k, C)
                src = ((r + dr) % R) * C + ((c + dc) % C)
                if src == k:                    # axis of length 1: no move
                    ok = False
                    break
                pairs.append((src, k))
            if not ok:
                continue
            key = tuple(sorted(pairs))
            if key in seen:                     # axis of length 2: the two
                continue                        # shifts are the same edge
            seen.add(key)
            out.append(tuple(pairs))
        return tuple(out) or None


@dataclasses.dataclass(frozen=True)
class HypercubeTopology(Topology):
    """log2(K)-dimensional hypercube (K must be a power of two): node k
    links to ``k XOR 2^i`` per dimension — diameter log2(K), degree
    log2(K)."""

    name = "hypercube"

    @staticmethod
    def _dims(K):
        if K < 1 or K & (K - 1):
            raise ValueError(
                f"hypercube topology needs K a power of two; got K={K}")
        return K.bit_length() - 1

    def adjacency(self, round_index, K):
        dims = self._dims(K)
        A = np.zeros((K, K), bool)
        for i in range(dims):
            k = np.arange(K)
            A[k, k ^ (1 << i)] = True
        return A

    def edge_perms(self, round_index, K):
        dims = self._dims(K)
        if dims == 0:
            return None
        return tuple(tuple((j ^ (1 << i), j) for j in range(K))
                     for i in range(dims))


@dataclasses.dataclass(frozen=True)
class ExponentialTopology(Topology):
    """Time-varying one-peer exponential graph (Assran et al.,
    1811.10792): at round t every participant receives from the peer
    ``2^(t mod ceil(log2 K))`` slots behind it, ``W_t = (I + P_d)/2`` —
    O(1) wire traffic per node per round, and the UNION over one period
    is the exponential graph, so consensus contracts at near-complete
    rate per period. The per-round matrix rides into the executables as
    traced data: the changing graph never recompiles."""

    name = "exponential"
    time_varying = True
    symmetric = False

    def period(self, K):
        return max(1, (max(K, 1) - 1).bit_length())

    def _offset(self, round_index, K):
        if K <= 1:
            return 0
        return (1 << (round_index % self.period(K))) % K

    def adjacency(self, round_index, K):
        A = np.zeros((K, K), bool)
        d = self._offset(round_index, K)
        if d:
            k = np.arange(K)
            A[k, (k - d) % K] = True
        return A

    def mixing_matrix(self, round_index, K, live=None):
        d = self._offset(round_index, K)
        return _directed_pair_matrix(
            K, lambda k: (k - d) % K if d else k, live, self.name,
            round_index)


@dataclasses.dataclass(frozen=True)
class ErdosRenyiTopology(Topology):
    """Deterministic G(K, p) sample: each undirected edge is present with
    probability ``p``, drawn from ``SeedSequence([seed, K])`` so the
    graph is a pure function of (p, seed, K). The connectivity guard
    rejects unlucky draws at construction — reseed or raise p."""

    p: float = 0.5
    seed: int = 0
    name = "erdos_renyi"
    _disconnected_hint = " (try a different seed or a larger p)"

    def adjacency(self, round_index, K):
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"erdos_renyi needs 0 <= p <= 1; got "
                             f"p={self.p}")
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, K]))
        U = np.triu(rng.random((K, K)) < self.p, 1)
        return U | U.T


@dataclasses.dataclass(frozen=True)
class CompleteTopology(Topology):
    """All-to-all: MH weights reduce to the uniform 1/K matrix — Eq. 2 as
    a (degenerate, O(K)-comm) member of the topology family, kept for
    sanity baselines."""

    name = "complete"

    def adjacency(self, round_index, K):
        A = np.ones((K, K), bool)
        np.fill_diagonal(A, False)
        return A


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
#: name -> factory(**kw) -> Topology (erdos_renyi takes p=/seed=).
TOPOLOGIES: dict = {}


def register_topology(name, factory):
    TOPOLOGIES[name] = factory
    return factory


register_topology("ring", RingTopology)
register_topology("grid2d", Grid2DTopology)
register_topology("torus", Grid2DTopology)              # alias
register_topology("hypercube", HypercubeTopology)
register_topology("exponential", ExponentialTopology)
register_topology("erdos_renyi",
                  lambda p=0.5, seed=0: ErdosRenyiTopology(p=p, seed=seed))
register_topology("er", TOPOLOGIES["erdos_renyi"])      # alias
register_topology("complete", CompleteTopology)


def get_topology(spec=None, **kw) -> Topology:
    """None | registry name | Topology instance -> Topology (None is the
    ring, the legacy gossip default). ``erdos_renyi`` accepts ``p=`` and
    ``seed=``."""
    if spec is None:
        return RingTopology()
    if isinstance(spec, Topology):
        return spec
    if isinstance(spec, str):
        try:
            factory = TOPOLOGIES[spec]
        except KeyError:
            raise KeyError(f"unknown topology {spec!r}; registered: "
                           f"{sorted(TOPOLOGIES)}") from None
        return factory(**kw)
    raise TypeError(f"topology must be None, a registry name, or a "
                    f"Topology; got {spec!r}")
