"""Elastic membership: per-round participant liveness over ``K_max`` slots.

The paper assumes a static set of K participants and a one-sentence failure
story (restart the failed participant's local training from the shared
model). The production north-star — millions of users across unreliable
data centers — makes churn the steady state, not the exception: FedAvg
(McMahan et al., 1602.05629) already treats per-round participation as
dynamic, and Kamp et al. (1807.03210) shows averaging protocols survive
peers going quiet. This module factors that into two small pieces:

* :class:`Membership` — the host-side state: which of the ``K_max``
  participant *slots* are live right now, plus the join/leave event log.
  It lives in the learner's round state (``state["membership"]``), is
  persisted by ``checkpoint.io`` (legacy checkpoints restore as all-live),
  and advances once per round via :meth:`Membership.step`.

* :class:`ChurnSchedule` — WHO is live each round, as a pure function
  ``live_mask(round_i, K) -> bool (K,)`` so the python and fused engines
  (and a resumed run) see identical membership traces. Built-ins:
  :class:`NoChurn` (the static-K paper path, bit-identical — the learner
  bypasses the membership machinery entirely), :class:`ScriptedChurn`
  (deterministic fault-injection traces: crash at round r, rejoin at
  round r', flaky slots), :class:`RandomChurn` (i.i.d. per-round failures
  and rejoins, deterministic in ``(seed, round)``).

A dead slot is NOT removed from the stacked ``(K, ...)`` arrays — shapes
are a compile-time invariant. Instead the liveness mask rides into the
round executables as a traced ``(K,)`` row (``repro.core.engine``),
composed with the ragged-shard ``batch_mask``: a dead row is an identity
carry through the local epochs AND through the aggregation (it neither
uploads, nor downloads, nor counts in the mean — the aggregators
renormalize their mixing matrices over the live set, see
``repro.core.api``). Membership changes therefore never retrace; a rejoin
warm-starts through ``CoLearner.restart_participant`` from the last
*synced* shared model.

Schedules whose :attr:`~ChurnSchedule.is_static` is True (``NoChurn``, an
event-free ``ScriptedChurn``, a ``RandomChurn`` that can never kill a
slot) keep the learner on the exact pre-membership static-K code path, so
"all-live" reduces bit-for-bit, by construction.
"""
from __future__ import annotations

import abc
import dataclasses

import numpy as np

#: membership event kinds as logged by :meth:`Membership.step`
JOIN = "join"
LEAVE = "leave"


@dataclasses.dataclass(frozen=True)
class Membership:
    """Live mask over the ``K_max`` participant slots + join/leave log.

    ``live`` is the CURRENT per-slot liveness (a tuple of bools, length
    ``K_max``); ``events`` logs every transition as ``(round, slot, kind)``
    triples with kind ``"join"`` | ``"leave"`` (slots live at round 0 log
    no synthetic join). The dataclass is immutable — :meth:`step` returns
    the advanced copy — so checkpoints and the round log can hold
    references safely.
    """

    live: tuple
    events: tuple = ()

    @classmethod
    def all_live(cls, K: int) -> "Membership":
        return cls(live=(True,) * K)

    @property
    def k_max(self) -> int:
        return len(self.live)

    @property
    def n_live(self) -> int:
        return sum(self.live)

    def live_mask(self) -> np.ndarray:
        """The current liveness as a bool ``(K_max,)`` numpy row."""
        return np.asarray(self.live, bool)

    def live_slots(self) -> tuple:
        return tuple(k for k, a in enumerate(self.live) if a)

    def step(self, round_i: int, new_live) -> "Membership":
        """Advance to ``new_live`` for round ``round_i``, logging every
        slot that flipped. Returns the new Membership; the joins/leaves of
        a specific round are recoverable via :meth:`round_events`."""
        new_live = tuple(bool(a) for a in np.asarray(new_live).reshape(-1))
        if len(new_live) != self.k_max:
            raise ValueError(
                f"live mask has {len(new_live)} slots; membership tracks "
                f"K_max={self.k_max}")
        ev = []
        for k, (was, now) in enumerate(zip(self.live, new_live)):
            if was != now:
                ev.append((round_i, k, JOIN if now else LEAVE))
        return dataclasses.replace(self, live=new_live,
                                   events=self.events + tuple(ev))

    def round_events(self, round_i: int) -> tuple:
        """The ``(round, slot, kind)`` events logged at ``round_i``."""
        return tuple(e for e in self.events if e[0] == round_i)

    def joined(self, round_i: int) -> tuple:
        return tuple(e[1] for e in self.round_events(round_i)
                     if e[2] == JOIN)


# ---------------------------------------------------------------------------
# ChurnSchedule
# ---------------------------------------------------------------------------
class ChurnSchedule(abc.ABC):
    """Per-round liveness as a pure function of ``(round, K)``.

    Implementations MUST be deterministic in their constructor arguments
    and ``(round_i, K)`` alone (no hidden mutable state): the python and
    fused engines — and a checkpoint-resumed run — replay the identical
    membership trace. At least one slot must be live every round (a round
    with zero live participants trains nothing and has no average);
    schedules guarantee it by construction and the learner re-checks.
    """

    name: str = "churn"

    @property
    def is_static(self) -> bool:
        """True when every round is all-live, i.e. the schedule is the
        static-K paper path. The learner then bypasses the membership
        machinery entirely, so the reduction is bit-for-bit."""
        return False

    @abc.abstractmethod
    def live_mask(self, round_i: int, K: int) -> np.ndarray:
        """bool ``(K,)``: which slots are live during round ``round_i``."""


@dataclasses.dataclass(frozen=True)
class NoChurn(ChurnSchedule):
    """Every slot live every round — the paper's static-K assumption,
    spelled as a schedule. ``is_static`` keeps the learner on the exact
    pre-membership code path (bit-identical, no traced live row)."""

    name = "none"

    @property
    def is_static(self):
        return True

    def live_mask(self, round_i, K):
        return np.ones(K, bool)


def _canon_events(events):
    """Normalize scripted events to sorted ``(kind, round, slot)`` tuples
    and validate kinds/ordering per slot."""
    out = []
    for e in events:
        kind, r, k = e
        if kind not in ("crash", "rejoin"):
            raise ValueError(f"unknown scripted-churn event kind {kind!r} "
                             f"(want 'crash' or 'rejoin'): {e}")
        out.append((str(kind), int(r), int(k)))
    return tuple(sorted(out, key=lambda e: (e[1], e[2])))


@dataclasses.dataclass(frozen=True)
class ScriptedChurn(ChurnSchedule):
    """Deterministic fault-injection traces.

    ``events``: ``("crash", round, slot)`` kills the slot from that round
    on; ``("rejoin", round, slot)`` revives it from that round on (events
    apply in round order; the latest event at or before the current round
    wins per slot). ``flaky``: ``(slot, period)`` pairs — the slot is
    additionally down on every round ``r`` with ``r % period == period-1``
    (an intermittently-failing peer). ``initial_live``: number of slots
    live at round 0 (slots ``initial_live..K-1`` start dead — standby
    capacity that only a rejoin event brings up); None = all live.

    Example — slot 1 crashes in round 2 and warm-rejoins in round 4,
    while slot 3 flakes every third round::

        ScriptedChurn(events=(("crash", 2, 1), ("rejoin", 4, 1)),
                      flaky=((3, 3),))
    """

    events: tuple = ()
    flaky: tuple = ()
    initial_live: int | None = None
    name = "scripted"

    def __post_init__(self):
        object.__setattr__(self, "events", _canon_events(self.events))
        object.__setattr__(self, "flaky", tuple(
            (int(k), int(p)) for k, p in self.flaky))
        for k, p in self.flaky:
            if p < 2:
                raise ValueError(f"flaky period must be >= 2; got {p} "
                                 f"for slot {k}")

    @property
    def is_static(self):
        return (not self.events and not self.flaky
                and self.initial_live is None)

    def live_mask(self, round_i, K):
        live = np.ones(K, bool)
        if self.initial_live is not None:
            if not 1 <= self.initial_live <= K:
                raise ValueError(f"initial_live={self.initial_live} "
                                 f"outside 1..K={K}")
            live[self.initial_live:] = False
        for kind, r, k in self.events:    # sorted by round: latest wins
            if k >= K:
                raise ValueError(f"scripted event {kind, r, k} names slot "
                                 f"{k} but K={K}")
            if r <= round_i:
                live[k] = kind == "rejoin"
        for k, p in self.flaky:
            if round_i % p == p - 1:
                live[k] = False
        if not live.any():
            raise ValueError(
                f"scripted churn leaves zero live slots at round {round_i}")
        return live


@dataclasses.dataclass(frozen=True)
class RandomChurn(ChurnSchedule):
    """I.i.d. per-round churn, deterministic in ``(seed, round)``.

    Each round, every live slot fails with probability ``p_fail`` and
    every dead slot rejoins with probability ``p_join``. The transition at
    round ``r`` draws from ``SeedSequence([seed, r])``, so the full trace
    is a pure function of ``(seed, round)`` — the python and fused engines
    (and a resumed run) replay identical rounds. If a draw would kill
    every slot, the lowest-indexed live slot survives (a run must always
    have at least one live participant). ``initial_live`` slots are live
    at round 0 (None = all); round 0 itself applies no transition.
    """

    p_fail: float = 0.2
    p_join: float = 0.5
    seed: int = 0
    initial_live: int | None = None
    name = "random"

    def __post_init__(self):
        for nm, p in (("p_fail", self.p_fail), ("p_join", self.p_join)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1]; got {p}")

    @property
    def is_static(self):
        return self.p_fail == 0.0 and self.initial_live is None

    def live_mask(self, round_i, K):
        live = np.ones(K, bool)
        if self.initial_live is not None:
            if not 1 <= self.initial_live <= K:
                raise ValueError(f"initial_live={self.initial_live} "
                                 f"outside 1..K={K}")
            live[self.initial_live:] = False
        # replay transitions 1..round_i (bounded by the round counter —
        # rounds are O(10..100), and callers step sequentially anyway)
        for r in range(1, round_i + 1):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, r]))
            u = rng.random(K)
            nxt = np.where(live, u >= self.p_fail, u < self.p_join)
            if not nxt.any():
                nxt[np.argmax(live)] = True   # sole survivor, deterministic
            live = nxt
        return live


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
#: name -> factory(**kw) -> ChurnSchedule
CHURN_SCHEDULES: dict = {}


def register_churn(name, factory):
    CHURN_SCHEDULES[name] = factory
    return factory


register_churn("none", lambda **kw: NoChurn())
register_churn("scripted", ScriptedChurn)
register_churn("random", RandomChurn)


def get_churn(spec=None, **kw) -> ChurnSchedule:
    """None | registry name | ChurnSchedule instance -> ChurnSchedule."""
    if spec is None:
        return NoChurn()
    if isinstance(spec, ChurnSchedule):
        return spec
    if isinstance(spec, str):
        try:
            factory = CHURN_SCHEDULES[spec]
        except KeyError:
            raise KeyError(f"unknown churn schedule {spec!r}; registered: "
                           f"{sorted(CHURN_SCHEDULES)}") from None
        return factory(**kw)
    raise TypeError("churn must be None, a registry name, or a "
                    f"ChurnSchedule; got {spec!r}")
