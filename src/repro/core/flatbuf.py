"""Flat-buffer wire codec: one contiguous buffer for a stacked params tree.

The leafwise int8 path (``core.compression.quantize_roundtrip``) pays codec
overhead per parameter leaf — two ``pallas_call`` launches plus a host-shaped
pad/reshape for every tensor — and silently exempts leaves smaller than one
quantization block from the wire format. This module removes both costs by
committing to ONE wire layout per tree structure:

  * ``make_layout(stacked)`` computes a static table (offsets / trailing
    shapes / dtypes, all derived from ``.shape``/``.dtype`` only, so it works
    on tracers and ``ShapeDtypeStruct``s alike) describing how every leaf of
    a stacked ``(K, ...)`` params tree maps into one ``(K, N_pad)`` f32
    buffer, ``N_pad`` rounded up to a whole number of ``rows x block``
    quantization tiles. Each leaf's offset is aligned to a ``block``
    boundary (zero fill between leaves): quantization blocks never straddle
    leaves, so a small-magnitude leaf is never scaled by a neighbour's
    absmax, and for any leaf whose per-participant size is a block multiple
    the int8 codes match the leafwise reference path bit-for-bit.
  * ``flatten`` / ``unflatten`` move between the tree and the buffer.
    ``unflatten(flatten(t)) == t`` bit-exactly for every floating dtype
    (f32 is a superset of bf16/f16) — no leaf, however small or oddly
    shaped, escapes the wire format.
  * ``wire_bytes(layout)`` is the exact per-participant byte count of the
    int8 encoding of that buffer (int8 payload + one f32 scale per block
    row) — the bytes-on-the-wire guarantee the leafwise accounting could
    only approximate.

The codec's consumer is ``repro.core.engine.make_fused_compressed_average``,
which runs the fused quantize->average->dequantize kernel
(``repro.kernels.comm``) over the flat buffer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

# the wire tile shape is owned by the quantize kernel; layouts must pad to
# whole quantizer tiles or the blockwise kernels would slice mid-tile
from repro.kernels.quantize import DEFAULT_BLOCK, ROWS

# dtypes the f32 wire container holds losslessly (bit-exact roundtrip)
_WIRE_DTYPES = frozenset(
    jnp.dtype(d) for d in (jnp.float32, jnp.bfloat16, jnp.float16))


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static wire layout of one stacked params tree structure.

    All fields are python ints/tuples computed from shapes only — a layout
    never captures array data and can be built at trace time for free.
    """
    treedef: Any                     # jax treedef of the stacked tree
    shapes: tuple                    # per-leaf trailing shape (K stripped)
    dtypes: tuple                    # per-leaf original dtype
    offsets: tuple                   # per-leaf start offset in the buffer
    sizes: tuple                     # per-leaf element count (per participant)
    k: int                           # leading participant dim shared by leaves
    n: int                           # block-aligned payload end per row
    n_pad: int                       # n rounded up to rows*block tiles
    block: int
    rows: int


def make_layout(stacked, *, block: int = DEFAULT_BLOCK,
                rows: int = ROWS) -> FlatLayout:
    """Layout for a stacked tree whose every leaf has leading dim K.

    Accepts arrays, tracers, or ``ShapeDtypeStruct``s — only shape/dtype
    are read.
    """
    leaves, treedef = jax.tree.flatten(stacked)
    if not leaves:
        raise ValueError("cannot build a flat layout for an empty tree")
    k = leaves[0].shape[0] if leaves[0].ndim else 0
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        if leaf.ndim == 0 or leaf.shape[0] != k:
            raise ValueError(
                f"every leaf must share the leading participant dim {k}; "
                f"got shape {leaf.shape}")
        if jnp.dtype(leaf.dtype) not in _WIRE_DTYPES:
            raise ValueError(
                f"dtype {leaf.dtype} does not roundtrip bit-exactly "
                f"through the f32 wire container (allowed: "
                f"{sorted(d.name for d in _WIRE_DTYPES)})")
        size = int(math.prod(leaf.shape[1:]))
        shapes.append(tuple(leaf.shape[1:]))
        dtypes.append(jnp.dtype(leaf.dtype))
        offsets.append(off)
        sizes.append(size)
        off += -(-size // block) * block          # next leaf block-aligned
    tile = rows * block
    n_pad = -(-off // tile) * tile
    return FlatLayout(treedef=treedef, shapes=tuple(shapes),
                      dtypes=tuple(dtypes), offsets=tuple(offsets),
                      sizes=tuple(sizes), k=k, n=off, n_pad=n_pad,
                      block=block, rows=rows)


def flatten(stacked, layout: FlatLayout):
    """Stacked tree -> one contiguous ``(K, N_pad)`` f32 buffer.

    Leaves are laid out in tree order at the block-aligned
    ``layout.offsets``; all padding (between leaves and at the tail) is
    zero fill inside blocks owned by a single leaf or whole zero blocks,
    so no leaf ever shares a quantization scale with another.
    """
    # write leaves into a zero buffer with dynamic_update_slice: measured
    # ~10x faster on CPU than a padded many-operand concatenate, and the
    # zero fill gives the inter-leaf/tail padding for free
    buf = jnp.zeros((layout.k, layout.n_pad), jnp.float32)
    for leaf, off in zip(jax.tree.leaves(stacked), layout.offsets):
        buf = jax.lax.dynamic_update_slice(
            buf, leaf.astype(jnp.float32).reshape(layout.k, -1), (0, off))
    return buf


def unflatten(buf, layout: FlatLayout):
    """Exact inverse of ``flatten``: ``(K, N_pad)`` buffer -> stacked tree."""
    leaves = [
        buf[:, off:off + size].reshape(layout.k, *shape).astype(dt)
        for off, size, shape, dt in zip(layout.offsets, layout.sizes,
                                        layout.shapes, layout.dtypes)
    ]
    return jax.tree.unflatten(layout.treedef, leaves)


def unflatten_mean(mean, layout: FlatLayout):
    """``(N_pad,)`` averaged buffer -> stacked tree with the mean broadcast
    to all K slots (the ``average_fn`` contract). Equivalent to
    ``unflatten(broadcast_to(mean[None], (K, N_pad)))`` but lets XLA fuse
    the per-leaf slice + reshape + broadcast straight from the small mean
    buffer instead of materializing the broadcast first.
    """
    leaves = [
        jnp.broadcast_to(
            mean[off:off + size].reshape(shape)[None],
            (layout.k, *shape)).astype(dt)
        for off, size, shape, dt in zip(layout.offsets, layout.sizes,
                                        layout.shapes, layout.dtypes)
    ]
    return jax.tree.unflatten(layout.treedef, leaves)


def wire_bytes(layout: FlatLayout, bits: int = 8,
               scale_bytes: int = 4) -> int:
    """Exact bytes one participant puts on the wire for this layout:
    the packed ``bits``-wide payload for every (padded) element + one
    ``scale_bytes``-wide scale per block row. ``n_pad`` is a whole number
    of ``rows x block`` tiles, so the packed payload is always a whole
    number of bytes (``kernels.quantize.pack_codes`` packs per block row).
    """
    from repro.kernels.quantize import check_bits
    check_bits(bits)
    return (layout.n_pad * bits) // 8 + scale_bytes * (
        layout.n_pad // layout.block)
