"""Beyond-paper: int8 blockwise-quantized model averaging (wire emulation).

The paper explicitly notes it does NOT compress uploads ("we do not employ
the compression technique"); we add int8 upload compression as a
separately-reported optimization, cutting the inter-pod (WAN-analog)
collective bytes ~2x vs bf16 / ~4x vs f32. Two wire paths implement the
same int8 + per-block f32 absmax scale format:

* **leafwise** (this module, the tested reference): every parameter leaf is
  independently quantize-roundtripped (``repro.kernels.quantize``) and the
  dequantized f32 tensors are averaged afterwards. Simple, but it costs two
  pallas launches + a host-shaped pad/reshape per leaf, leaves with
  ``size < block`` (or scalars) bypass the codec entirely and travel
  uncompressed — ``compressed_bytes`` accounts for that bypass at raw-dtype
  rates — and because the STACKED (K, ...) leaf is flattened as one array,
  a quantization block can straddle two participants' data mid-leaf (a
  physical wire could not do that; the flat-buffer path quantizes strict
  per-participant rows).
* **flat-buffer** (``repro.core.flatbuf`` + ``repro.kernels.comm``,
  selected by ``CoLearner(codec=FlatFusedInt8(...))`` or the legacy
  ``from_flags(compress="fused")``): the whole stacked tree is
  flattened into one contiguous ``(K, N_pad)`` f32 buffer and a single
  fused quantize->average->dequantize kernel performs Eq. 2 in one
  blockwise pass. No leaf escapes the wire format and
  ``flatbuf.wire_bytes`` is exact by construction.

Reported ONLY in EXPERIMENTS.md §Perf beyond-paper rows, never mixed into
the paper-faithful baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def quantize_roundtrip(tree, block=256, impl="ref"):
    """Simulate upload-as-int8: quantize then dequantize every leaf.

    Leaves with fewer than ``block`` elements (and scalars) are returned
    unchanged — they go on the wire uncompressed (see ``compressed_bytes``).
    """
    def one(t):
        if t.ndim == 0 or t.size < block:
            return t
        q, scale, shape = kops.quantize_blockwise(t, block=block, impl=impl)
        return kops.dequantize_blockwise(q, scale, shape, impl=impl).astype(t.dtype)
    return jax.tree.map(one, tree)


def make_compress_fn(block=256, impl="ref"):
    """compress_fn for CoLearner: emulates the int8 wire format."""
    def fn(stacked):
        return quantize_roundtrip(stacked, block=block, impl=impl)
    return fn


def compressed_bytes(tree, block=256):
    """Idealized per-participant wire bytes of the leafwise int8 encoding.

    ``tree`` is ONE participant's (unstacked) params: int8 payload + one
    f32 scale per block for quantized leaves; leaves below the block
    threshold bypass the codec and are counted at their raw dtype size —
    the same bypass rule ``quantize_roundtrip`` applies. Note the in-sim
    emulation runs the roundtrip on the STACKED tree, where the threshold
    sees K*size and blocks can straddle participants, so at small K its
    behavior can differ from this per-upload accounting (the flat-buffer
    path has no such gap — ``flat_compressed_bytes`` is exact)."""
    total = 0
    for t in jax.tree.leaves(tree):
        n = t.size
        if t.ndim == 0 or n < block:
            total += n * t.dtype.itemsize        # uploaded uncompressed
        else:
            total += n + 4 * (-(-n // block))
    return total


def flat_compressed_bytes(tree, block=256):
    """Exact per-participant wire bytes of the flat-buffer codec for a
    STACKED tree (leading participant dim on every leaf) — every element,
    however small its leaf, is on the int8 + scale format."""
    from repro.core import flatbuf
    return flatbuf.wire_bytes(flatbuf.make_layout(tree, block=block))
