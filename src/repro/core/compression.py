"""Beyond-paper: blockwise-quantized model averaging (wire emulation).

The paper explicitly notes it does NOT compress uploads ("we do not employ
the compression technique"); we add upload compression as a separately-
reported optimization. The wire format is blockwise quantization at
``bits ∈ {8, 4, 1}`` — symmetric absmax integer codes for 8/4 (int4
packed two per byte), sign + per-block mean-|x| scale for 1-bit — with
one f32 scale per block (``repro.kernels.quantize``). Two wire paths
implement the same format:

* **leafwise** (this module, the tested reference): every parameter leaf is
  independently quantize-roundtripped and the dequantized f32 tensors are
  averaged afterwards. Simple, but it costs two pallas launches + a
  host-shaped pad/reshape per leaf, leaves with ``size < block`` (or
  scalars) bypass the codec entirely and travel uncompressed —
  ``compressed_bytes`` accounts for that bypass at raw-dtype rates — and
  because the STACKED (K, ...) leaf is flattened as one array, a
  quantization block can straddle two participants' data mid-leaf (a
  physical wire could not do that; the flat-buffer path quantizes strict
  per-participant rows).
* **flat-buffer** (``repro.core.flatbuf`` + ``repro.kernels.comm``,
  selected by ``CoLearner(codec=FlatFusedIntN(...))`` or the legacy
  ``from_flags(compress="fused")``): the whole stacked tree is
  flattened into one contiguous ``(K, N_pad)`` f32 buffer and a single
  fused quantize->average->dequantize kernel performs Eq. 2 in one
  blockwise pass. No leaf escapes the wire format and
  ``flatbuf.wire_bytes`` is exact by construction.

Byte accounting bills the canonical encoded representation INCLUDING the
block padding a real wire would carry: a quantized leaf costs
``ceil(n/block)`` whole packed blocks plus one scale each
(``scale_bytes`` wide, f32 by default), parameterized over the payload
bit width — never hardcoded to 1 byte/element.

``quantize_roundtrip_ef`` adds error-feedback residual memory (the
standard trick that keeps int4 / 1-bit quantization convergent): each
participant quantizes ``x + e`` and keeps ``e' = (x + e) - dequant`` for
the next round; bypassed leaves carry a zero residual forever.

Reported ONLY in EXPERIMENTS.md §Perf beyond-paper rows, never mixed into
the paper-faithful baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def quantize_roundtrip(tree, block=256, impl="ref", bits=8):
    """Simulate the compressed upload: quantize then dequantize every leaf.

    Leaves with fewer than ``block`` elements (and scalars) are returned
    unchanged — they go on the wire uncompressed (see ``compressed_bytes``).
    """
    def one(t):
        if t.ndim == 0 or t.size < block:
            return t
        q, scale, shape = kops.quantize_blockwise(t, block=block, bits=bits,
                                                  impl=impl)
        return kops.dequantize_blockwise(q, scale, shape, bits=bits,
                                         impl=impl).astype(t.dtype)
    return jax.tree.map(one, tree)


def quantize_roundtrip_ef(tree, residual, block=256, impl="ref", bits=8):
    """Error-feedback leafwise roundtrip: quantize ``t + e`` per leaf and
    return ``(roundtripped tree, new residual tree)`` with
    ``e' = (t + e) - dequant``. Residual leaves are f32 mirrors of the
    params; bypassed leaves pass through unchanged with residual zero.
    """
    def one(t, e):
        if t.ndim == 0 or t.size < block:
            return t, e
        y = t.astype(jnp.float32) + e
        q, scale, shape = kops.quantize_blockwise(y, block=block, bits=bits,
                                                  impl=impl)
        dq = kops.dequantize_blockwise(q, scale, shape, bits=bits, impl=impl)
        return dq.astype(t.dtype), y - dq
    flat, treedef = jax.tree.flatten(tree)
    res_flat = jax.tree.leaves(residual)
    out = [one(t, e) for t, e in zip(flat, res_flat)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def make_compress_fn(block=256, impl="ref", bits=8):
    """compress_fn for CoLearner: emulates the quantized wire format."""
    def fn(stacked):
        return quantize_roundtrip(stacked, block=block, impl=impl, bits=bits)
    return fn


def block_bytes(block, bits, scale_bytes=4):
    """Wire bytes of ONE encoded block: packed payload + its scale."""
    from repro.kernels.quantize import check_bits
    check_bits(bits)
    return block * bits // 8 + scale_bytes


def compressed_bytes(tree, block=256, bits=8, scale_bytes=4):
    """Per-participant wire bytes of the leafwise encoding.

    ``tree`` is ONE participant's (unstacked) params: each quantized leaf
    costs ``ceil(n/block)`` whole packed blocks (the encoder pads the last
    block — those bytes go on the wire) plus one ``scale_bytes`` scale per
    block; leaves below the block threshold bypass the codec and are
    counted at their raw dtype size — the same bypass rule
    ``quantize_roundtrip`` applies. Note the in-sim emulation runs the
    roundtrip on the STACKED tree, where the threshold sees K*size and
    blocks can straddle participants, so at small K its behavior can
    differ from this per-upload accounting (the flat-buffer path has no
    such gap — ``flat_compressed_bytes`` is exact)."""
    per_block = block_bytes(block, bits, scale_bytes)
    total = 0
    for t in jax.tree.leaves(tree):
        n = t.size
        if t.ndim == 0 or n < block:
            total += n * t.dtype.itemsize        # uploaded uncompressed
        else:
            total += (-(-n // block)) * per_block
    return total


def flat_compressed_bytes(tree, block=256, bits=8, scale_bytes=4):
    """Exact per-participant wire bytes of the flat-buffer codec for a
    STACKED tree (leading participant dim on every leaf) — every element,
    however small its leaf, is on the packed ``bits`` + scale format."""
    from repro.core import flatbuf
    return flatbuf.wire_bytes(flatbuf.make_layout(tree, block=block),
                              bits=bits, scale_bytes=scale_bytes)
