"""Beyond-paper: int8 blockwise-quantized model averaging.

The paper explicitly notes it does NOT compress uploads ("we do not employ
the compression technique"). We add it as a separately-reported
optimization: participants upload int8 block-quantized deltas, cutting the
inter-pod (WAN-analog) collective bytes ~2x vs bf16 / ~4x vs f32. The
quant/dequant hot loop is the `repro.kernels.quantize` Pallas kernel; this
module is the model-level wrapper. Reported ONLY in EXPERIMENTS.md §Perf
beyond-paper rows, never mixed into the paper-faithful baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def quantize_roundtrip(tree, block=256, impl="ref"):
    """Simulate upload-as-int8: quantize then dequantize every leaf."""
    def one(t):
        if t.ndim == 0 or t.size < block:
            return t
        q, scale, shape = kops.quantize_blockwise(t, block=block, impl=impl)
        return kops.dequantize_blockwise(q, scale, shape, impl=impl).astype(t.dtype)
    return jax.tree.map(one, tree)


def make_compress_fn(block=256, impl="ref"):
    """compress_fn for CoLearner: emulates the int8 wire format."""
    def fn(stacked):
        return quantize_roundtrip(stacked, block=block, impl=impl)
    return fn


def compressed_bytes(tree, block=256):
    """Wire bytes of the int8 encoding (int8 payload + f32 scale / block)."""
    total = 0
    for t in jax.tree.leaves(tree):
        n = t.size
        total += n + 4 * (-(-n // block))
    return total
