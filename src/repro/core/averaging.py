"""Model averaging (Eq. 2) and participant-parallel training wrappers.

Two equivalent distributed implementations are provided (both tested):

1. ``average_pjit`` — a plain mean over the leading participant dim of
   stacked parameter pytrees; under pjit with that dim sharded over the
   ``pod`` mesh axis this lowers to an all-reduce over the inter-pod links.
2. ``average_shard_map`` — explicit `shard_map` psum over the ``pod`` axis,
   for when the collective schedule should be pinned rather than inferred.

``participant_step`` wraps a single-participant train step with
``jax.vmap(..., spmd_axis_name='pod')`` so each pod trains its own replica
with gradient reductions kept *inside* the pod — the paper's "local
training" phase in SPMD form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import compat


def stack_participants(params, K: int):
    """Replicate a params pytree into K stacked participant copies."""
    return jax.tree.map(lambda t: jnp.broadcast_to(t[None], (K, *t.shape)), params)


@jax.jit
def _gather_slot(stacked, k):
    return jax.tree.map(lambda t: t[k], stacked)


def unstack_participant(stacked, k: int):
    """Slot k of a stacked (K, ...) pytree.

    Inside a trace the python int stays a static slice. Eager calls go
    through a jitted gather with the index staged explicitly: an eager
    python-int slice dispatches dynamic_slice with implicitly-transferred
    start scalars, which trips ``guards.no_transfer()`` on the round loop.
    The index is traced, so the gather compiles once per params geometry.
    """
    leaves = jax.tree.leaves(stacked)
    if leaves and isinstance(leaves[0], jax.core.Tracer):
        return jax.tree.map(lambda t: t[k], stacked)
    if not isinstance(k, jax.Array):
        import numpy as np
        k = jax.device_put(np.int32(k))
    return _gather_slot(stacked, k)


def average_pjit(stacked):
    """Eq. 2: w̄ = (1/K) Σ_k w_k, broadcast back to all K slots."""
    def avg(t):
        m = jnp.mean(t.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(m, t.shape).astype(t.dtype)
    return jax.tree.map(avg, stacked)


def average_mean(stacked):
    """Eq. 2 returning the un-stacked average (host-side convenience)."""
    return jax.tree.map(
        lambda t: jnp.mean(t.astype(jnp.float32), axis=0).astype(t.dtype), stacked)


def make_average_shard_map(mesh, param_specs, axis="pod"):
    """Explicit-collective averaging: psum over the participant mesh axis.

    param_specs: pytree of PartitionSpecs for the *stacked* params, whose
    leading dim is sharded over ``axis``.
    """
    K = mesh.shape[axis]

    def _avg(local):
        # local arrays have leading dim K/mesh.shape[axis] == 1 per shard
        def one(t):
            s = jax.lax.psum(t.astype(jnp.float32), axis) / K
            return jnp.broadcast_to(s, t.shape).astype(t.dtype)
        return jax.tree.map(one, local)

    return jax.jit(compat.shard_map(
        _avg, mesh=mesh, in_specs=(param_specs,), out_specs=param_specs,
        check_vma=False))


def participant_step(step_fn):
    """vmap a per-participant step over the leading K dim.

    step_fn(params, batch, *args) -> (params', metrics). The vmapped version
    takes stacked params (K, ...) and per-participant batches (K, B_k, ...);
    ``spmd_axis_name='pod'`` pins the participant dim to the pod mesh axis so
    XLA never reduces across it during local training.
    """
    return jax.vmap(step_fn, spmd_axis_name="pod")


def participant_step_sim(step_fn):
    """Simulation variant (single host, K participants, no pod axis)."""
    return jax.vmap(step_fn)
