"""Jit'd dispatch wrappers for the Pallas kernels.

impl semantics:
  * "ref"     — pure-jnp oracle (default on CPU; also what the dry-run
                lowers, since Mosaic custom-calls need a TPU backend);
  * "pallas"  — the real kernel; automatically falls back to interpret
                mode when the backend is not TPU (bit-accurate kernel-body
                execution in Python — how tests validate the kernels here);
  * "interpret" — force interpret mode explicitly.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import comm as _comm
from repro.kernels import flash_attention as _fa
from repro.kernels import mlstm as _ml
from repro.kernels import quantize as _qz
from repro.kernels import ref as _ref
from repro.kernels import selective_scan as _ss


def _interp(impl):
    if impl == "interpret":
        return True
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, n_kv_heads, window=0, softmax_scale=None,
                    impl="pallas", **kw):
    if impl == "ref":
        return _ref.flash_attention_ref(q, k, v, n_kv_heads=n_kv_heads,
                                        window=window,
                                        softmax_scale=softmax_scale)
    return _fa.flash_attention_fwd(q, k, v, n_kv_heads=n_kv_heads,
                                   window=window, softmax_scale=softmax_scale,
                                   interpret=_interp(impl), **kw)


def selective_scan(xc, dt, Bm, Cm, A, D, *, impl="pallas", **kw):
    if impl == "ref":
        return _ref.selective_scan_ref(xc, dt, Bm, Cm, A, D)
    return _ss.selective_scan_fwd(xc, dt, Bm, Cm, A, D,
                                  interpret=_interp(impl), **kw)


def mlstm(q, k, v, ig, fg, *, impl="pallas", **kw):
    if impl == "ref":
        return _ref.mlstm_ref(q, k, v, ig, fg)
    h = _ml.mlstm_fwd(q, k, v, ig, fg, interpret=_interp(impl), **kw)
    return h, None


def quantize_blockwise(x, *, block=256, bits=8, impl="pallas", **kw):
    if impl == "ref":
        return _ref.quantize_blockwise_ref(x, block=block, bits=bits)
    return _qz.quantize_blockwise_fwd(x, block=block, bits=bits,
                                      interpret=_interp(impl), **kw)


def dequantize_blockwise(q, scale, shape, *, bits=8, impl="pallas", **kw):
    if impl == "ref":
        return _ref.dequantize_blockwise_ref(q, scale, shape, bits=bits)
    return _qz.dequantize_blockwise_fwd(q, scale, shape, bits=bits,
                                        interpret=_interp(impl), **kw)


def quant_avg_dequant(buf, *, block=256, bits=8, impl="pallas", **kw):
    """Fused Eq. 2 wire pass over a (K, n) flat buffer: quantize every
    participant row blockwise at ``bits``, dequantize, mean -> (n,) f32."""
    if impl == "ref":
        return _ref.quant_avg_dequant_ref(buf, block=block, bits=bits)
    return _comm.quant_avg_dequant_fwd(buf, block=block, bits=bits,
                                       interpret=_interp(impl), **kw)


def quant_avg_dequant_ef(buf, residual, *, block=256, bits=8, impl="pallas",
                         **kw):
    """Error-feedback fused Eq. 2 wire pass: quantize ``buf + residual``
    per participant row, return ((n,) mean, (K, n) new residual)."""
    if impl == "ref":
        return _ref.quant_avg_dequant_ef_ref(buf, residual, block=block,
                                             bits=bits)
    return _comm.quant_avg_dequant_ef_fwd(buf, residual, block=block,
                                          bits=bits, interpret=_interp(impl),
                                          **kw)
