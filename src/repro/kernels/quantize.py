"""Blockwise int8 quant/dequant — Pallas TPU kernel (comm compression).

Used by the beyond-paper compressed model-averaging path: parameters are
flattened, padded, and quantized in VMEM-resident tiles of (rows × block)
with one f32 absmax scale per block row. Tiles are (8, 256) by default —
8 sublanes × 256 lanes (two 128-lane vregs), a natural VPU shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256
ROWS = 8


def _q_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                 # (ROWS, block)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # (ROWS, 1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _dq_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def quantize_blockwise_fwd(x, *, block=DEFAULT_BLOCK, interpret=False):
    """x: any shape -> (q int8 (nblocks, block), scale f32 (nblocks,), shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    nb = -(-n // block)
    nb = -(-nb // ROWS) * ROWS                          # pad rows to ROWS
    flat = jnp.pad(flat, (0, nb * block - n))
    xb = flat.reshape(nb, block)
    q, s = pl.pallas_call(
        _q_kernel,
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(xb)
    return q, s[:, 0], x.shape


def dequantize_blockwise_fwd(q, scale, shape, *, interpret=False):
    nb, block = q.shape
    if scale.shape != (nb,):
        raise ValueError(f"scale shape {scale.shape} != ({nb},)")
    n = 1
    for s in shape:
        n *= s
    if n > nb * block:
        raise ValueError(f"shape {shape} needs {n} elements; payload has "
                         f"only {nb}x{block}")
    # the quantizer pads its row count to ROWS, but accept any nb: a grid of
    # nb // ROWS would silently drop the trailing nb % ROWS rows
    nb_pad = -(-nb // ROWS) * ROWS
    if nb_pad != nb:
        q = jnp.pad(q, ((0, nb_pad - nb), (0, 0)))
        scale = jnp.pad(scale, (0, nb_pad - nb))
    x = pl.pallas_call(
        _dq_kernel,
        grid=(nb_pad // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb_pad, block), jnp.float32),
        interpret=interpret,
    )(q, scale[:, None])
    return x.reshape(-1)[:n].reshape(shape)
