"""Blockwise sub-f32 quant/dequant — Pallas TPU kernel (comm compression).

Used by the beyond-paper compressed model-averaging path: parameters are
flattened, padded, and quantized in VMEM-resident tiles of (rows × block)
with one f32 scale per block row. Tiles are (8, 256) by default —
8 sublanes × 256 lanes (two 128-lane vregs), a natural VPU shape.

The wire supports ``bits ∈ {8, 4, 1}``:

* 8 / 4 — symmetric absmax quantization to ``qmax = 2**(bits-1) - 1``
  integer codes (127 / 7); int4 codes are packed two per byte.
* 1 — sign quantization: codes are ±1 packed eight per byte, and the
  per-block scale is ``mean(|x|)`` (the L2-optimal magnitude for a sign
  code, as in 1-bit SGD / signSGD-with-majority); an all-zero block gets
  scale 0 so it dequantizes to exactly 0, preserving the flat-buffer
  zero-padding contract.

Bit-packing is plain jnp OUTSIDE the Pallas kernels (``pack_codes`` /
``unpack_codes``), shared by the ref oracles so both impls produce the
identical packed wire payload; the kernels always see unpacked int8 codes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256
ROWS = 8

# symmetric-integer code range per bit width (1-bit is sign-coded, not here)
QMAX = {8: 127.0, 4: 7.0}


def check_bits(bits):
    if bits not in (8, 4, 1):
        raise ValueError(f"bits must be 8, 4, or 1; got {bits}")


def pack_codes(q, bits):
    """(nb, block) int8 codes -> (nb, block*bits//8) packed payload.

    bits=8 is the identity; bits=4 packs two's-complement nibbles (even
    index = low nibble); bits=1 packs eight sign bits per byte (LSB =
    lowest index, set bit = +1).
    """
    check_bits(bits)
    if bits == 8:
        return q
    if bits == 4:
        u = q.astype(jnp.uint8) & 0xF
        return (u[:, 0::2] | (u[:, 1::2] << 4)).astype(jnp.uint8)
    b = (q > 0).astype(jnp.uint8).reshape(q.shape[0], -1, 8)
    w = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return (b * w).sum(axis=2).astype(jnp.uint8)


def unpack_codes(p, bits):
    """Exact inverse of ``pack_codes``: packed payload -> int8 codes."""
    check_bits(bits)
    if bits == 8:
        return p
    if bits == 4:
        u = jnp.stack([p & 0xF, p >> 4], axis=-1).reshape(p.shape[0], -1)
        s = u.astype(jnp.int8)
        return jnp.where(s > 7, s - 16, s)
    w = jnp.arange(8, dtype=jnp.uint8)
    b = (p[:, :, None] >> w) & 1
    return jnp.where(b == 1, 1, -1).astype(jnp.int8).reshape(p.shape[0], -1)


def packed_width(block, bits):
    """Payload columns of one packed block row."""
    check_bits(bits)
    return block * bits // 8


def _q_kernel(x_ref, q_ref, s_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)                 # (ROWS, block)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # (ROWS, 1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    s_ref[...] = scale


def _q1_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                 # (ROWS, block)
    # mean |x| minimizes ||x - scale*sign(x)||_2; zero block -> scale 0
    s_ref[...] = jnp.mean(jnp.abs(x), axis=1, keepdims=True)
    q_ref[...] = jnp.where(x > 0, 1, -1).astype(jnp.int8)


def _dq_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def quantize_blockwise_fwd(x, *, block=DEFAULT_BLOCK, bits=8,
                           interpret=False):
    """x: any shape -> (q packed (nblocks, block*bits//8), scale f32
    (nblocks,), shape)."""
    check_bits(bits)
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    nb = -(-n // block)
    nb = -(-nb // ROWS) * ROWS                          # pad rows to ROWS
    flat = jnp.pad(flat, (0, nb * block - n))
    xb = flat.reshape(nb, block)
    kernel = (_q1_kernel if bits == 1
              else functools.partial(_q_kernel, qmax=QMAX[bits]))
    q, s = pl.pallas_call(
        kernel,
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(xb)
    return pack_codes(q, bits), s[:, 0], x.shape


def dequantize_blockwise_fwd(q, scale, shape, *, bits=8, interpret=False):
    check_bits(bits)
    q = unpack_codes(q, bits)
    nb, block = q.shape
    if scale.shape != (nb,):
        raise ValueError(f"scale shape {scale.shape} != ({nb},)")
    n = 1
    for s in shape:
        n *= s
    if n > nb * block:
        raise ValueError(f"shape {shape} needs {n} elements; payload has "
                         f"only {nb}x{block}")
    # the quantizer pads its row count to ROWS, but accept any nb: a grid of
    # nb // ROWS would silently drop the trailing nb % ROWS rows
    nb_pad = -(-nb // ROWS) * ROWS
    if nb_pad != nb:
        q = jnp.pad(q, ((0, nb_pad - nb), (0, 0)))
        scale = jnp.pad(scale, (0, nb_pad - nb))
    x = pl.pallas_call(
        _dq_kernel,
        grid=(nb_pad // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb_pad, block), jnp.float32),
        interpret=interpret,
    )(q, scale[:, None])
    return x.reshape(-1)[:n].reshape(shape)
