"""Flash attention forward — Pallas TPU kernel.

TPU adaptation of the flash algorithm: the KV stream is the innermost
(sequential) grid dimension; the running (m, l, acc) online-softmax state
lives in VMEM scratch across KV steps; Q/K/V tiles are BlockSpec'd into
VMEM with MXU-aligned shapes (q block 256×hd, kv block 512×hd, hd a
multiple of 128 for full MXU occupancy at 128-lane width).

Supports causal masking, sliding window, and GQA (KV head index derived
from the Q head index in the BlockSpec index maps — no KV replication in
HBM or VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, window, bq, bk, n_kv_blocks, seq_k, seq_q):
    """Grid: (B, H, nq, nk); innermost nk is sequential on TPU."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd_v)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (seq_k - seq_q)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, n_kv_heads, window=0, softmax_scale=None,
                        block_q=DEFAULT_BQ, block_k=DEFAULT_BK,
                        interpret=False):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd/hd_v) -> (B,Sq,H,hd_v). Causal."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    hd_v = v.shape[-1]
    KV = n_kv_heads
    G = H // KV
    scale = softmax_scale or hd ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk
    assert Sq % bq == 0 and Sk % bk == 0

    # (B,H,S,hd) layouts so the head dim is a leading grid dim
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, bq=bq, bk=bk,
        n_kv_blocks=nk, seq_k=Sk, seq_q=Sq)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd_v), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd_v), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max m
            pltpu.VMEM((bq, 1), jnp.float32),      # running denom l
            pltpu.VMEM((bq, hd_v), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
