"""Mamba selective scan — Pallas TPU kernel.

TPU adaptation: instead of the GPU kernel's warp-parallel scan, we tile the
channel (d_inner) dimension across the grid — each grid cell owns a
(block_d × d_state) slab of SSM state resident in VMEM — and walk the
sequence in chunks as the innermost sequential grid dimension, carrying the
state slab across chunk steps in scratch. Per chunk the recurrence runs as
a `fori_loop` over time with all operands VMEM-resident (block_d is a
multiple of 128 to keep the VPU lanes full; d_state=16 rides the sublane
dim). This trades the GPU's intra-warp parallel prefix for TPU-friendly
long-vector elementwise work on the channel axis, which is where Mamba's
parallelism actually is (state update is elementwise over d_inner).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BD = 128
DEFAULT_CHUNK = 256


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, hout_ref,
            h_ref, *, chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)        # (chunk, bd)
    bm = b_ref[0].astype(jnp.float32)         # (chunk, st)
    cm = c_ref[0].astype(jnp.float32)         # (chunk, st)
    a = a_ref[...].astype(jnp.float32)        # (bd, st)
    d = d_ref[...].astype(jnp.float32)        # (1, bd)

    def step(t, carry):
        h, ys = carry
        dt_t = dt[t][:, None]                  # (bd,1)
        dA = jnp.exp(dt_t * a)                 # (bd,st)
        dBx = dt_t * bm[t][None, :] * x[t][:, None]
        h = dA * h + dBx
        y_t = jnp.sum(h * cm[t][None, :], axis=1) + x[t] * d[0]
        return h, jax.lax.dynamic_update_slice(ys, y_t[None], (t, 0))

    h0 = h_ref[...]
    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_ref[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan_fwd(xc, dt, Bm, Cm, A, D, *, block_d=DEFAULT_BD,
                       chunk=DEFAULT_CHUNK, interpret=False):
    """xc,dt: (B,S,di); Bm,Cm: (B,S,st); A: (di,st); D: (di,).

    Returns (y: (B,S,di) f32, h_final: (B,di,st) f32).
    """
    B, S, di = xc.shape
    st = A.shape[-1]
    bd = min(block_d, di)
    ck = min(chunk, S)
    assert di % bd == 0 and S % ck == 0
    nd, nc = di // bd, S // ck
    d2 = D.reshape(1, di)

    y, h = pl.pallas_call(
        functools.partial(_kernel, chunk=ck, n_chunks=nc),
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, ck, bd), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, ck, st), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((1, ck, st), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((bd, st), lambda b, i, c: (i, 0)),
            pl.BlockSpec((1, bd), lambda b, i, c: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, bd, st), lambda b, i, c: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, st), jnp.float32)],
        interpret=interpret,
    )(xc, dt, Bm, Cm, A, d2)
    return y, h
