"""Fused quantize->average->dequantize — Pallas TPU kernel (Eq. 2 wire path).

One blockwise pass over the flat-buffer wire codec's ``(K, N_pad)`` stacked
participant buffer (``repro.core.flatbuf``): each grid step loads one
``(K, ROWS, block)`` tile into VMEM, quantizes every participant row to int8
with one f32 absmax scale per (participant, row) — the same wire format as
``repro.kernels.quantize`` — widens the int8 codes through int32, scales
them back to the exactly-dequantized f32 payloads (|q| <= 127, so each
``q * scale`` product is exact in f32), and reduces them to the Eq. 2 mean
in one shot. This replaces the leafwise path's ~2 pallas_call launches +
host-side pad/reshape per parameter leaf plus a separate whole-tree mean
with a single kernel over a single buffer.

Scales are per participant (each participant quantizes its own upload
before it ever sees the others), so the cross-participant accumulation
happens on the dequantized payloads, not in the shared-integer domain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# one source of truth for the wire tile shape: the quantize kernel owns it
# (flatbuf layouts and this kernel's grid must stay in lockstep with it)
from repro.kernels.quantize import DEFAULT_BLOCK, ROWS


def _qad_kernel(x_ref, o_ref, *, k):
    x = x_ref[...]                                      # (K, ROWS, block) f32
    amax = jnp.max(jnp.abs(x), axis=2, keepdims=True)   # (K, ROWS, 1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    dq = q.astype(jnp.int32).astype(jnp.float32) * scale
    o_ref[...] = jnp.sum(dq, axis=0) / k                # Eq. 2 over K


def quant_avg_dequant_fwd(buf, *, block=DEFAULT_BLOCK, interpret=False):
    """buf: (K, n) f32 -> (n,) f32 mean of the int8-roundtripped rows.

    ``n`` is padded up to whole ``(ROWS, block)`` tiles internally (the flat
    codec's ``N_pad`` already is, so the pad is a no-op on the hot path);
    zero pad quantizes and dequantizes to exactly zero.
    """
    K, n = buf.shape
    tile = ROWS * block
    n_pad = -(-n // tile) * tile
    if n_pad != n:
        buf = jnp.pad(buf, ((0, 0), (0, n_pad - n)))
    nb = n_pad // block
    xb = buf.reshape(K, nb, block)
    out = pl.pallas_call(
        functools.partial(_qad_kernel, k=K),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((K, ROWS, block), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(xb)
    return out.reshape(n_pad)[:n]
