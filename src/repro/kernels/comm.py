"""Fused quantize->average->dequantize — Pallas TPU kernel (Eq. 2 wire path).

One blockwise pass over the flat-buffer wire codec's ``(K, N_pad)`` stacked
participant buffer (``repro.core.flatbuf``): each grid step loads one
``(K, ROWS, block)`` tile into VMEM, quantizes every participant row with
one f32 scale per (participant, row) — the same wire format as
``repro.kernels.quantize``, generalized over ``bits ∈ {8, 4, 1}`` — widens
the codes through int32, scales them back to the exactly-dequantized f32
payloads (|q| <= 127, so each ``q * scale`` product is exact in f32), and
reduces them to the Eq. 2 mean in one shot. This replaces the leafwise
path's ~2 pallas_call launches + host-side pad/reshape per parameter leaf
plus a separate whole-tree mean with a single kernel over a single buffer.

Scales are per participant (each participant quantizes its own upload
before it ever sees the others), so the cross-participant accumulation
happens on the dequantized payloads, not in the shared-integer domain.

``quant_avg_dequant_ef_fwd`` is the error-feedback variant: the quantizer
input is ``x + e`` (the row plus its residual memory), and the kernel emits
BOTH the Eq. 2 mean of the dequantized payloads and the new residual
``e' = (x + e) - dequant(quant(x + e))`` — the standard trick that keeps
aggressive (int4 / 1-bit) quantization convergent — still in one pass over
one buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# one source of truth for the wire tile shape: the quantize kernel owns it
# (flatbuf layouts and this kernel's grid must stay in lockstep with it)
from repro.kernels.quantize import DEFAULT_BLOCK, QMAX, ROWS, check_bits


def _quant_dequant_tile(x, bits):
    """(K, ROWS, block) f32 -> dequantized wire roundtrip, same shape."""
    if bits == 1:
        scale = jnp.mean(jnp.abs(x), axis=2, keepdims=True)   # (K, ROWS, 1)
        q = jnp.where(x > 0, 1, -1).astype(jnp.int8)
    else:
        qmax = QMAX[bits]
        amax = jnp.max(jnp.abs(x), axis=2, keepdims=True)     # (K, ROWS, 1)
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q.astype(jnp.int32).astype(jnp.float32) * scale


def _qad_kernel(x_ref, o_ref, *, k, bits):
    x = x_ref[...]                                      # (K, ROWS, block) f32
    dq = _quant_dequant_tile(x, bits)
    o_ref[...] = jnp.sum(dq, axis=0) / k                # Eq. 2 over K


def _qad_ef_kernel(x_ref, e_ref, o_ref, ne_ref, *, k, bits):
    y = x_ref[...] + e_ref[...]                         # (K, ROWS, block) f32
    dq = _quant_dequant_tile(y, bits)
    o_ref[...] = jnp.sum(dq, axis=0) / k
    ne_ref[...] = y - dq                                # residual memory


def _pad_tiles(buf, block):
    """Pad a (K, n) buffer to whole (ROWS, block) tiles -> (K, nb, block)."""
    K, n = buf.shape
    tile = ROWS * block
    n_pad = -(-n // tile) * tile
    if n_pad != n:
        buf = jnp.pad(buf, ((0, 0), (0, n_pad - n)))
    return buf.reshape(K, n_pad // block, block), n_pad


def quant_avg_dequant_fwd(buf, *, block=DEFAULT_BLOCK, bits=8,
                          interpret=False):
    """buf: (K, n) f32 -> (n,) f32 mean of the wire-roundtripped rows.

    ``n`` is padded up to whole ``(ROWS, block)`` tiles internally (the flat
    codec's ``N_pad`` already is, so the pad is a no-op on the hot path);
    zero pad quantizes and dequantizes to exactly zero.
    """
    check_bits(bits)
    K, n = buf.shape
    xb, n_pad = _pad_tiles(buf, block)
    nb = n_pad // block
    out = pl.pallas_call(
        functools.partial(_qad_kernel, k=K, bits=bits),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((K, ROWS, block), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(xb)
    return out.reshape(n_pad)[:n]


def quant_avg_dequant_ef_fwd(buf, residual, *, block=DEFAULT_BLOCK, bits=8,
                             interpret=False):
    """Error-feedback fused pass: (buf, residual) both (K, n) f32 ->
    ((n,) f32 mean of the roundtripped ``buf + residual`` rows,
    (K, n) f32 new residual). Zero pad stays exactly zero in both outputs.
    """
    check_bits(bits)
    if residual.shape != buf.shape:
        raise ValueError(f"residual shape {residual.shape} != buf shape "
                         f"{buf.shape}")
    K, n = buf.shape
    xb, n_pad = _pad_tiles(buf, block)
    eb, _ = _pad_tiles(residual, block)
    nb = n_pad // block
    out, ne = pl.pallas_call(
        functools.partial(_qad_ef_kernel, k=K, bits=bits),
        grid=(nb // ROWS,),
        in_specs=[pl.BlockSpec((K, ROWS, block), lambda i: (0, i, 0)),
                  pl.BlockSpec((K, ROWS, block), lambda i: (0, i, 0))],
        out_specs=[pl.BlockSpec((ROWS, block), lambda i: (i, 0)),
                   pl.BlockSpec((K, ROWS, block), lambda i: (0, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.float32),
                   jax.ShapeDtypeStruct((K, nb, block), jnp.float32)],
        interpret=interpret,
    )(xb, eb)
    return out.reshape(n_pad)[:n], ne.reshape(K, n_pad)[:, :n]
