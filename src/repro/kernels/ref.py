"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are intentionally naive (materialize full score matrices, sequential
scans) — clarity over speed. Tests sweep shapes/dtypes asserting the Pallas
kernels (interpret=True on CPU) match these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, n_kv_heads, window=0, softmax_scale=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd*) -> (B,Sq,H,hd_v). Causal."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], n_kv_heads
    G = H // KV
    scale = softmax_scale or hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qpos, kpos = jnp.arange(Sq), jnp.arange(Sk)
    ok = kpos[None, :] <= qpos[:, None] + (Sk - Sq)
    if window:
        ok &= kpos[None, :] > qpos[:, None] + (Sk - Sq) - window
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def selective_scan_ref(xc, dt, Bm, Cm, A, D, h0=None):
    """Sequential Mamba scan; identical math to models.mamba (re-exported)."""
    from repro.models.mamba import selective_scan_ref as _impl
    return _impl(xc, dt, Bm, Cm, A, D, h0)


def mlstm_ref(q, k, v, ig, fg, state=None):
    """Sequential stabilized mLSTM; identical math to models.xlstm."""
    from repro.models.xlstm import mlstm_cell_ref as _impl
    return _impl(q, k, v, ig, fg, state)


def _code_blocks_ref(blocks, bits):
    """(nb, block) f32 -> (codes int8, scale (nb,)) for bits in {8, 4, 1}."""
    from repro.kernels.quantize import QMAX
    if bits == 1:
        scale = jnp.mean(jnp.abs(blocks), axis=1)
        q = jnp.where(blocks > 0, 1, -1).astype(jnp.int8)
    else:
        qmax = QMAX[bits]
        amax = jnp.max(jnp.abs(blocks), axis=1)
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        q = jnp.clip(jnp.round(blocks / scale[:, None]),
                     -qmax, qmax).astype(jnp.int8)
    return q, scale


def quantize_blockwise_ref(x, block=256, bits=8):
    """x: any shape -> (q packed (nblocks, block*bits//8), scale f32
    (nblocks,), shape). Packing shared with the Pallas path — identical
    wire payload bytes (modulo the kernel's extra ROWS row padding)."""
    from repro.kernels.quantize import check_bits, pack_codes
    check_bits(bits)
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    q, scale = _code_blocks_ref(blocks, bits)
    return pack_codes(q, bits), scale, x.shape


def dequantize_blockwise_ref(q, scale, shape, bits=8):
    from repro.kernels.quantize import check_bits, unpack_codes
    check_bits(bits)
    q = unpack_codes(q, bits)
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def _roundtrip_rows_ref(xb, bits):
    """(K, nb, block) f32 -> dequantized wire roundtrip, same shape."""
    from repro.kernels.quantize import QMAX
    if bits == 1:
        scale = jnp.mean(jnp.abs(xb), axis=2, keepdims=True)
        q = jnp.where(xb > 0, 1, -1).astype(jnp.int8)
    else:
        qmax = QMAX[bits]
        amax = jnp.max(jnp.abs(xb), axis=2, keepdims=True)
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        q = jnp.clip(jnp.round(xb / scale), -qmax, qmax).astype(jnp.int8)
    return q.astype(jnp.int32).astype(jnp.float32) * scale


def quant_avg_dequant_ref(buf, block=256, bits=8):
    """buf: (K, n) f32 -> (n,) f32 — wire-roundtrip every participant row
    blockwise (one scale per (participant, block)), then Eq. 2 mean."""
    from repro.kernels.quantize import check_bits
    check_bits(bits)
    K, n = buf.shape
    pad = (-n) % block
    xb = jnp.pad(buf, ((0, 0), (0, pad))).reshape(K, -1, block)
    dq = _roundtrip_rows_ref(xb, bits)
    return (jnp.sum(dq, axis=0) / K).reshape(-1)[:n]


def quant_avg_dequant_ef_ref(buf, residual, block=256, bits=8):
    """Error-feedback oracle: quantize ``buf + residual`` per row, return
    (Eq. 2 mean of the dequantized rows (n,), new residual (K, n))."""
    from repro.kernels.quantize import check_bits
    check_bits(bits)
    K, n = buf.shape
    pad = (-n) % block
    yb = jnp.pad(buf + residual, ((0, 0), (0, pad))).reshape(K, -1, block)
    dq = _roundtrip_rows_ref(yb, bits)
    mean = (jnp.sum(dq, axis=0) / K).reshape(-1)[:n]
    new_res = (yb - dq).reshape(K, -1)[:, :n]
    return mean, new_res
