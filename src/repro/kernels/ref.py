"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are intentionally naive (materialize full score matrices, sequential
scans) — clarity over speed. Tests sweep shapes/dtypes asserting the Pallas
kernels (interpret=True on CPU) match these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, n_kv_heads, window=0, softmax_scale=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd*) -> (B,Sq,H,hd_v). Causal."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], n_kv_heads
    G = H // KV
    scale = softmax_scale or hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qpos, kpos = jnp.arange(Sq), jnp.arange(Sk)
    ok = kpos[None, :] <= qpos[:, None] + (Sk - Sq)
    if window:
        ok &= kpos[None, :] > qpos[:, None] + (Sk - Sq) - window
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def selective_scan_ref(xc, dt, Bm, Cm, A, D, h0=None):
    """Sequential Mamba scan; identical math to models.mamba (re-exported)."""
    from repro.models.mamba import selective_scan_ref as _impl
    return _impl(xc, dt, Bm, Cm, A, D, h0)


def mlstm_ref(q, k, v, ig, fg, state=None):
    """Sequential stabilized mLSTM; identical math to models.xlstm."""
    from repro.models.xlstm import mlstm_cell_ref as _impl
    return _impl(q, k, v, ig, fg, state)


def quantize_blockwise_ref(x, block=256):
    """x: any shape -> (q int8 (nblocks, block), scale f32 (nblocks,), shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, x.shape


def dequantize_blockwise_ref(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def quant_avg_dequant_ref(buf, block=256):
    """buf: (K, n) f32 -> (n,) f32 — int8-roundtrip every participant row
    blockwise (absmax scale per (participant, block)), then Eq. 2 mean."""
    K, n = buf.shape
    pad = (-n) % block
    xb = jnp.pad(buf, ((0, 0), (0, pad))).reshape(K, -1, block)
    amax = jnp.max(jnp.abs(xb), axis=2, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    dq = q.astype(jnp.int32).astype(jnp.float32) * scale
    return (jnp.sum(dq, axis=0) / K).reshape(-1)[:n]
