"""mLSTM (matrix-memory LSTM, xLSTM) — Pallas TPU kernel.

TPU adaptation: each grid cell owns one (batch, head); the (hd × hd) matrix
memory C, normalizer n and stabilizer m live in VMEM scratch and persist
across sequence chunks (innermost sequential grid dim). Within a chunk the
stabilized recurrence runs as a `fori_loop`; the rank-1 update v·kᵀ and the
readout C·q map onto the MXU as (hd×1)·(1×hd) and (hd×hd)·(hd×1) dots with
hd a multiple of 128. This is the TPU-idiomatic replacement for the GPU
version's shared-memory tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref, h_ref,
            c_s, n_s, m_s, *, chunk, n_chunks, hd):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_s[...] = jnp.zeros_like(c_s)
        n_s[...] = jnp.zeros_like(n_s)
        m_s[...] = jnp.full_like(m_s, -1e30)

    q = q_ref[0, 0].astype(jnp.float32) * hd ** -0.25   # (chunk, hd)
    k = k_ref[0, 0].astype(jnp.float32) * hd ** -0.25
    v = v_ref[0, 0].astype(jnp.float32)
    ig = i_ref[0, 0].astype(jnp.float32)                # (chunk, 1)
    fg = f_ref[0, 0].astype(jnp.float32)
    logf = -jnp.logaddexp(0.0, -fg)                     # log sigmoid

    def step(t, carry):
        C, n, m, hs = carry
        m_new = jnp.maximum(logf[t, 0] + m, ig[t, 0])
        i_p = jnp.exp(ig[t, 0] - m_new)
        f_p = jnp.exp(logf[t, 0] + m - m_new)
        C = f_p * C + i_p * jax.lax.dot(v[t][:, None], k[t][None, :])
        n = f_p * n + i_p * k[t][None, :]               # (1, hd)
        num = jax.lax.dot(C, q[t][:, None])[:, 0]       # (hd,)
        den = jnp.maximum(jnp.abs(jnp.sum(n[0] * q[t])), jnp.exp(-m_new))
        hs = jax.lax.dynamic_update_slice(hs, (num / den)[None], (t, 0))
        return C, n, m_new, hs

    hs0 = jnp.zeros((chunk, hd), jnp.float32)
    C, n, m, hs = jax.lax.fori_loop(
        0, chunk, step, (c_s[...], n_s[0:1], m_s[0, 0], hs0))
    c_s[...] = C
    n_s[...] = jnp.broadcast_to(n, n_s.shape)
    m_s[...] = jnp.full_like(m_s, m)
    h_ref[0, 0] = hs.astype(h_ref.dtype)


def mlstm_fwd(q, k, v, ig, fg, *, chunk=DEFAULT_CHUNK, interpret=False):
    """q,k,v: (B,S,H,hd); ig,fg: (B,S,H) raw gates -> h: (B,S,H,hd) f32.

    Note: the kernel applies the same 1/hd^(1/4) q,k scaling as the ref.
    """
    B, S, H, hd = q.shape
    ck = min(chunk, S)
    assert S % ck == 0
    nc = S // ck
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    it = ig.transpose(0, 2, 1)[..., None]
    ft = fg.transpose(0, 2, 1)[..., None]

    h = pl.pallas_call(
        functools.partial(_kernel, chunk=ck, n_chunks=nc, hd=hd),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, ck, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ck, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ck, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ck, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, ck, 1), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, ck, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),   # matrix memory C
            pltpu.VMEM((1, hd), jnp.float32),    # normalizer n
            pltpu.VMEM((1, 1), jnp.float32),     # stabilizer m
        ],
        interpret=interpret,
    )(qt, kt, vt, it, ft)
    return h.transpose(0, 2, 1, 3)
