"""Pytree checkpointing (npz) + co-learning round-state persistence.

No orbax offline; this is a compact, dependency-free implementation with
path-keyed flat storage so checkpoints survive refactors of dict ordering.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def jnp_astype(arr, dtype):
    """astype that tolerates ml_dtypes targets numpy can't cast to."""
    try:
        return arr.astype(dtype)
    except (TypeError, ValueError):
        import jax.numpy as jnp
        return np.asarray(jnp.asarray(arr).astype(dtype))


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz can't round-trip ml_dtypes
            key += "::bf16"
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save_pytree(path: str, tree):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore_pytree(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path) as data:
        flat = dict(data)
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for pth, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        if key + "::bf16" in flat:
            import ml_dtypes
            arr = flat[key + "::bf16"].view(ml_dtypes.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing {key}")
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(jnp_astype(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def save_round_state(path: str, state):
    """Persist the co-learning server state (params + opt + sync-policy
    state).

    ``prev_avg`` — the last *synced* shared model — is persisted too: under
    a divergence-gated sync policy the participant slots may hold divergent
    local models after a quiet round, so the reference cannot be recovered
    from ``params`` alone. The per-participant optimizer pytree
    (``state["opt"]``) is likewise persisted: it is non-trivial whenever a
    checkpoint lands mid-round or after a quiet round (local momentum /
    Adam moments still live), and dropping it would silently reset the
    optimizer trajectory on restore.
    """
    save_pytree(path + ".params.npz", state["params"])
    save_pytree(path + ".opt.npz", state["opt"])
    if state.get("prev_avg") is not None:
        save_pytree(path + ".prev_avg.npz", state["prev_avg"])
    if state.get("residual") is not None:
        # round-state memory (error-feedback residual and/or the D²
        # correction): without it a resumed run would restart from zero
        # memory and diverge from the uninterrupted one
        save_pytree(path + ".residual.npz", state["residual"])
    ctrl = state["ctrl"]
    meta = {"round": state["round"], "global_epoch": state["global_epoch"],
            "T": ctrl.T, "history": list(ctrl.history),
            "skipped": list(getattr(ctrl, "skipped", ())),
            "has_prev_avg": state.get("prev_avg") is not None,
            "has_residual": state.get("residual") is not None,
            "has_opt": True}
    mem = state.get("membership")
    if mem is not None:
        # elastic membership: liveness + join/leave log ride in the meta
        # (tiny, json-safe) so a resumed run replays the same trace
        meta["membership"] = {
            "live": [bool(a) for a in mem.live],
            "events": [[int(r), int(k), str(kind)]
                       for r, k, kind in mem.events]}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)


def restore_round_state(path: str, state):
    from repro.core.api import SyncState
    from repro.core.membership import Membership
    state["params"] = restore_pytree(path + ".params.npz", state["params"])
    with open(path + ".meta.json") as f:
        meta = json.load(f)
    if meta.get("has_opt"):
        state["opt"] = restore_pytree(path + ".opt.npz", state["opt"])
    # legacy checkpoints (pre-opt-persistence) carry no opt pytree: keep
    # the caller's ``state["opt"]`` — ``CoLearner.init``'s ``opt.init``
    # (the documented fallback; momentum restarts from zero, exactly the
    # old restore behavior, now explicit instead of silent-for-everyone)
    state["round"] = meta["round"]
    state["global_epoch"] = meta["global_epoch"]
    # the policy itself lives on the learner; checkpoints carry its state.
    # Pre-PR-4 checkpoints stored (rel, T) history pairs — pad them to the
    # (round, rel, T) triples every current consumer unpacks (one update
    # per round from round 0, so the index is the position).
    history = tuple(
        h if len(h) == 3 else (idx, *h)
        for idx, h in enumerate(tuple(h) for h in meta["history"]))
    state["ctrl"] = SyncState(meta["T"], history,
                              tuple(meta.get("skipped", ())))
    mm = meta.get("membership")
    if mm is not None:
        state["membership"] = Membership(
            live=tuple(bool(a) for a in mm["live"]),
            events=tuple((int(r), int(k), str(kind))
                         for r, k, kind in mm["events"]))
    else:
        # pre-membership checkpoints: every slot was (implicitly) live
        K = jax.tree_util.tree_leaves(state["params"])[0].shape[0]
        state["membership"] = Membership.all_live(K)
    if meta.get("has_residual") and state.get("residual") is not None:
        # restore into the learner's init-built residual structure; legacy
        # checkpoints (no flag) keep the caller's zero residual — the
        # documented fallback, matching the pre-EF quantization behavior
        state["residual"] = restore_pytree(path + ".residual.npz",
                                           state["residual"])
    if meta.get("has_prev_avg"):
        like = jax.tree.map(lambda t: t[0], state["params"])
        state["prev_avg"] = restore_pytree(path + ".prev_avg.npz", like)
    else:
        # pre-PR-4 / pre-first-sync checkpoints carry no reference: reset
        # it (the target state may be mid-run) to the legacy semantics —
        # next round's rel is inf and the sync reference is slot 0
        state["prev_avg"] = None
    return state
