"""Model/config dataclasses for the repro framework.

Every assigned architecture gets one file in this package exporting
``CONFIG`` (the exact published shape, citation in the docstring) and
``smoke_config()`` (a reduced same-family variant for CPU tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

# A layer pattern entry is "<mixer>:<ffn>" where
#   mixer ∈ {gqa, mla, mamba, slstm, mlstm}
#   ffn   ∈ {dense, moe, moe_dense, -}   (moe_dense = MoE in parallel with a
#                                         dense FFN residual, as in Arctic)
Segment = tuple[tuple[str, ...], int]  # (pattern, repeats)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: tuple[Segment, ...] = ()   # derived: default all gqa:dense
    head_dim: int = 0                    # 0 => d_model // n_heads
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # Attention variants
    window: int = 0                  # 0 => full causal; >0 => sliding window
    # MLA (DeepSeek-V3) geometry
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba)
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 => ceil(d_model / 16)

    # xLSTM
    xlstm_proj_factor: float = 2.0   # mLSTM up-projection factor
    slstm_proj_factor: float = 1.3334

    # Multi-token prediction (DeepSeek-V3)
    mtp_depth: int = 0

    # Modality frontend stub
    input_mode: str = "tokens"       # tokens | embeddings | tokens+prefix
    prefix_len: int = 256            # VLM: #patch embeddings prepended

    citation: str = ""

    def __post_init__(self):
        if not self.segments:
            object.__setattr__(self, "segments", ((("gqa:dense",), self.n_layers),))
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        total = sum(len(p) * r for p, r in self.segments)
        assert total == self.n_layers, (self.name, total, self.n_layers)

    # ---- derived helpers -------------------------------------------------
    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    def layer_kinds(self):
        """Flat list of n_layers '<mixer>:<ffn>' strings, in order."""
        out = []
        for pattern, repeats in self.segments:
            for _ in range(repeats):
                out.extend(pattern)
        return out

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 8
    seq_len: int = 128
    lr: float = 0.01                 # paper: eta^i = 0.01 (constant across rounds)
    optimizer: str = "sgd"           # sgd | momentum | adamw (paper: SGD)
    momentum: float = 0.9
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    param_dtype: str = "bfloat16"
    remat: bool = True
    seed: int = 0


@dataclass(frozen=True)
class CoLearnConfig:
    """The paper's Algorithm 1 knobs (Eqs. 3, 4).

    ``schedule``/``epochs_rule`` are the legacy string spellings of the
    ``api.LRSchedule``/``api.SyncPolicy`` strategy objects — a ``CoLearner``
    built without explicit ``schedule=``/``sync_policy=`` arguments
    resolves them through ``api.SCHEDULES``/``api.SYNC_POLICIES``. (The
    old ``compress`` field is gone: wire codecs are objects/registry names
    passed to ``CoLearner(codec=...)`` — see ROADMAP.md §Round strategy
    API migration table.)
    """
    n_participants: int = 5          # paper: 5 data centers
    T0: int = 5                      # initial local epochs (paper: 5 or 20)
    eta0: float = 0.01               # paper: constant shared eta^i
    decay_rate: float = 0.25         # paper: r = 1/4
    epsilon: float = 0.01            # Eq.4 relative-change threshold
    schedule: str = "clr"            # clr | elr  (cyclical vs exponential)
    epochs_rule: str = "ile"         # ile | fle  (increasing vs fixed)
    max_rounds: int = 10


# --- input shapes assigned to this paper (public pool) ---------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
