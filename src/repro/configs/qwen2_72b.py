"""qwen2-72b [dense] — 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

GQA with QKV bias. [arXiv:2407.10671]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152_064, qkv_bias=True,
    rope_theta=1_000_000.0,
    citation="arXiv:2407.10671",
)


def smoke_config():
    return ModelConfig(
        name="qwen2-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=512, qkv_bias=True,
        citation="arXiv:2407.10671 (reduced)",
    )
