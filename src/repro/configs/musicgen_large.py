"""musicgen-large [audio] — 48L d=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens. The EnCodec conv codec itself
is the modality-frontend stub (carve-out): ``input_specs()`` supplies the
precomputed code tokens. [arXiv:2306.05284]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    citation="arXiv:2306.05284",
)


def smoke_config():
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=256,
        citation="arXiv:2306.05284 (reduced)",
    )
