"""internvl2-76b [vlm] — 80L d=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

InternViT-6B vision encoder + projector are the modality-frontend stub
(carve-out): ``input_specs()`` supplies precomputed patch embeddings of
shape (B, prefix_len, d_model) prepended to the text tokens. The LLM
backbone implemented here is the Llama-3-70B-shaped decoder InternVL2-76B
uses. [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128_256,
    input_mode="tokens+prefix", prefix_len=256,
    rope_theta=500_000.0,
    citation="arXiv:2404.16821",
)


def smoke_config():
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=512,
        input_mode="tokens+prefix", prefix_len=16,
        citation="arXiv:2404.16821 (reduced)",
    )
