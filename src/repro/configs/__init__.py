"""Config registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``."""
from __future__ import annotations

import importlib

from repro.configs.base import (CoLearnConfig, InputShape, INPUT_SHAPES,
                                ModelConfig, TrainConfig)

_MODULES = {
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen1.5-32b": "qwen1_5_32b",
    "musicgen-large": "musicgen_large",
    "arctic-480b": "arctic_480b",
    "internvl2-76b": "internvl2_76b",
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen2-72b": "qwen2_72b",
    "internlm2-1.8b": "internlm2_1_8b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).smoke_config()
