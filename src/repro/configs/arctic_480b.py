"""arctic-480b [moe] — 35L d=7168 56H (GQA kv=8) d_ff=4864 vocab=32000.

Dense-MoE hybrid: a 128-expert top-2 MoE in *parallel* with a dense FFN
residual on every layer ("moe_dense"). [hf:Snowflake/snowflake-arctic-base]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32_000,
    segments=((("gqa:moe_dense",), 35),),
    n_experts=128, top_k=2, moe_d_ff=4864,
    citation="hf:Snowflake/snowflake-arctic-base",
)


def smoke_config():
    return ModelConfig(
        name="arctic-smoke", family="moe",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        segments=((("gqa:moe_dense",), 2),),
        n_experts=4, top_k=2, moe_d_ff=256,
        citation="hf:Snowflake/snowflake-arctic-base (reduced)",
    )
