"""phi4-mini-3.8b [dense] — 32L d=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.

RoPE + SwiGLU + GQA, tied embeddings. [arXiv:2412.08905]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=200_064, tie_embeddings=True,
    citation="arXiv:2412.08905",
)


def smoke_config():
    return ModelConfig(
        name="phi4-mini-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=512, tie_embeddings=True,
        citation="arXiv:2412.08905 (reduced)",
    )
