"""qwen1.5-32b [dense] — 64L d=5120 40H (GQA kv=40 = MHA) d_ff=27392 vocab=152064.

QKV bias. [hf:Qwen/Qwen1.5-0.5B family scaled per assignment]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab_size=152_064, qkv_bias=True,
    citation="hf:Qwen/Qwen1.5-0.5B",
)


def smoke_config():
    return ModelConfig(
        name="qwen1.5-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=512, qkv_bias=True,
        citation="hf:Qwen/Qwen1.5-0.5B (reduced)",
    )
