"""xlstm-1.3b [ssm] — 48L d=2048 4H d_ff=0 vocab=50304.

xLSTM[7:1]: 7 mLSTM blocks per sLSTM block (projection factors 2 / 4:3).
No attention, O(1) decode state — runs long_500k natively. [arXiv:2405.04517]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    segments=((("mlstm:-",) * 7 + ("slstm:-",), 6),),
    citation="arXiv:2405.04517",
)


def smoke_config():
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=256,
        segments=((("mlstm:-", "slstm:-"), 1),),
        citation="arXiv:2405.04517 (reduced)",
    )
