"""deepseek-v3-671b [moe] — 61L d=7168 128H d_ff(expert)=2048 vocab=129280.

MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v_head 128); first 3
layers dense (d_ff 18432 per the paper), remaining 58 layers MoE with
1 shared + 256 routed experts, top-8; MTP depth 1. [arXiv:2412.19437]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129_280,
    segments=((("mla:dense",), 3), (("mla:moe",), 58)),
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128, head_dim=192,
    n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    mtp_depth=1,
    citation="arXiv:2412.19437",
)


def smoke_config():
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
        d_ff=512, vocab_size=512,
        segments=((("mla:dense",), 1), (("mla:moe",), 1)),
        q_lora_rank=64, kv_lora_rank=32,
        qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32, head_dim=48,
        n_experts=4, top_k=2, moe_d_ff=128, n_shared_experts=1,
        mtp_depth=1,
        citation="arXiv:2412.19437 (reduced)",
    )
