"""jamba-v0.1-52b [hybrid] — 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Mamba:attention 7:1 interleave (1 attention layer per 8-layer period),
MoE (16 experts, top-2) every other layer. [arXiv:2403.19887]
"""
from repro.configs.base import ModelConfig

_PERIOD = tuple(
    f"{'gqa' if i == 4 else 'mamba'}:{'moe' if i % 2 == 1 else 'dense'}"
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65_536,
    segments=((_PERIOD, 4),),
    n_experts=16, top_k=2, moe_d_ff=14336,
    ssm_state_dim=16, ssm_conv_dim=4, ssm_expand=2,
    citation="arXiv:2403.19887",
)


def smoke_config():
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=512,
        segments=((("mamba:moe", "gqa:dense"), 1),),
        n_experts=4, top_k=2, moe_d_ff=256,
        ssm_state_dim=8, ssm_conv_dim=4, ssm_expand=2,
        citation="arXiv:2403.19887 (reduced)",
    )
