"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

We implement the *absorbed* formulation throughout: queries are projected
into the KV latent space (q_eff = q_nope @ W_uk), so attention is MQA-like
with a single shared latent "KV head" of width ``kv_lora_rank`` plus the
decoupled RoPE key of width ``qk_rope_dim``.  The decode cache stores only
``(c_kv, k_rope)`` — the paper's KV-cache compression — and the sliding
window (for long_500k) applies to that latent cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention, NEG_INF
from repro.models.layers import apply_rope, rmsnorm_apply, trunc_normal


def mla_init(key, cfg, dtype, stack=()):
    d = cfg.d_model
    H, ql, kl = cfg.n_heads, cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": trunc_normal(ks[0], (*stack, d, ql), d ** -0.5, dtype),
        "q_norm_g": jnp.ones((*stack, ql), dtype),
        "w_uq": trunc_normal(ks[1], (*stack, ql, H, nope + rope), ql ** -0.5, dtype),
        "w_dkv": trunc_normal(ks[2], (*stack, d, kl), d ** -0.5, dtype),
        "kv_norm_g": jnp.ones((*stack, kl), dtype),
        "w_kr": trunc_normal(ks[3], (*stack, d, rope), d ** -0.5, dtype),
        "w_uk": trunc_normal(ks[4], (*stack, kl, H, nope), kl ** -0.5, dtype),
        "w_uv": trunc_normal(ks[5], (*stack, kl, H, vh), kl ** -0.5, dtype),
        "w_o": trunc_normal(ks[6], (*stack, H, vh, d), (H * vh) ** -0.5, dtype),
    }


def _latents(p, x, cfg, positions):
    """Returns q_eff (B,S,H,kl+rope), c_kv (B,S,kl), k_rope (B,S,rope)."""
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = jnp.einsum("bsd,dq->bsq", x, p["w_dq"])
    cq = rmsnorm_apply({"g": p["q_norm_g"]}, cq, cfg.norm_eps)
    q = jnp.einsum("bsq,qhe->bshe", cq, p["w_uq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # absorb: q_eff_latent = q_nope @ W_uk  -> (B,S,H,kl)
    q_eff = jnp.einsum("bshn,khn->bshk", q_nope, p["w_uk"])
    c_kv = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"])
    c_kv = rmsnorm_apply({"g": p["kv_norm_g"]}, c_kv, cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return jnp.concatenate([q_eff, q_rope], -1), c_kv, k_rope


def _out_proj(p, o_latent, cfg):
    """o_latent: (B,S,H,kl) -> (B,S,D) via per-head W_uv then W_o."""
    o = jnp.einsum("bshk,khv->bshv", o_latent, p["w_uv"])
    return jnp.einsum("bshv,hvd->bsd", o, p["w_o"])


def mla_apply(p, x, cfg, positions, impl="ref"):
    """Training/prefill forward; returns (y, (c_kv, k_rope)) for caching."""
    q_all, c_kv, k_rope = _latents(p, x, cfg, positions)
    kv = jnp.concatenate([c_kv, k_rope], -1)[:, :, None, :]   # (B,S,1,kl+r)
    v = c_kv[:, :, None, :]                                    # (B,S,1,kl)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    o_latent = chunked_attention(q_all, kv, v, n_kv_heads=1,
                                 window=cfg.window, softmax_scale=scale)
    return _out_proj(p, o_latent, cfg), (c_kv, k_rope)


def mla_cache_init(cfg, batch, seq_len, dtype):
    S = min(cfg.window, seq_len) if cfg.window else seq_len
    return {"c_kv": jnp.zeros((batch, S, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, S, cfg.qk_rope_dim), dtype)}


def mla_decode(p, x, cfg, cache, pos):
    B = x.shape[0]
    S = cache["c_kv"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_all, c_kv, k_rope = _latents(p, x, cfg, positions)
    slot = pos % S if cfg.window else pos
    cc = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, slot, 0))
    cr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, slot, 0))
    kv = jnp.concatenate([cc, cr], -1)                         # (B,S,kl+r)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    qh = (q_all * scale)[:, 0]                                 # (B,H,kl+r)
    s = jnp.einsum("bhd,bsd->bhs", qh, kv).astype(jnp.float32)
    idx = jnp.arange(S)
    valid = ((idx <= pos) | (pos >= S)) if cfg.window else (idx <= pos)
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_latent = jnp.einsum("bhs,bsk->bhk", w.astype(cc.dtype), cc)[:, None]
    return _out_proj(p, o_latent, cfg), {"c_kv": cc, "k_rope": cr}
