"""Compact conv/recurrent classifiers for the paper-claims experiments.

The paper's testbeds (VGG/ResNet/DenseNet/Inception on CIFAR, LSTM/Capsule
on text, CRNN on audio) are reproduced as same-family reduced JAX models:
  * vgg_tiny / resnet_tiny / densenet_tiny — image task (Table 2 analog)
  * gru_text / transformer_text           — text task  (Table 4 analog)
  * crnn_{ap,mp,sa,ma}                    — audio task (Table 6 analog:
                                            avg/max pooling, single/multi attention)
All are ``init(key, ...) -> params`` / ``apply(params, x) -> logits``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import trunc_normal


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_init(key, k, cin, cout):
    return trunc_normal(key, (k, k, cin, cout), (k * k * cin) ** -0.5,
                        jnp.float32)


def _dense_init(key, din, dout):
    return {"w": trunc_normal(key, (din, dout), din ** -0.5, jnp.float32),
            "b": jnp.zeros(dout)}


def _dense(p, x):
    return x @ p["w"] + p["b"]


# ---------------------------------------------------------------------------
# Image models
# ---------------------------------------------------------------------------
def vgg_tiny_init(key, n_classes=10, c=24, cin=3):
    ks = jax.random.split(key, 4)
    return {"c1": _conv_init(ks[0], 3, cin, c), "c2": _conv_init(ks[1], 3, c, 2 * c),
            "c3": _conv_init(ks[2], 3, 2 * c, 2 * c),
            "head": _dense_init(ks[3], 2 * c, n_classes)}


def vgg_tiny_apply(p, x):
    x = jax.nn.relu(_conv(x, p["c1"], 2))
    x = jax.nn.relu(_conv(x, p["c2"], 2))
    x = jax.nn.relu(_conv(x, p["c3"], 1))
    return _dense(p["head"], x.mean((1, 2)))


def resnet_tiny_init(key, n_classes=10, c=24, cin=3):
    ks = jax.random.split(key, 5)
    return {"c1": _conv_init(ks[0], 3, cin, c),
            "r1": _conv_init(ks[1], 3, c, c), "r2": _conv_init(ks[2], 3, c, c),
            "c2": _conv_init(ks[3], 3, c, 2 * c),
            "head": _dense_init(ks[4], 2 * c, n_classes)}


def resnet_tiny_apply(p, x):
    x = jax.nn.relu(_conv(x, p["c1"], 2))
    h = jax.nn.relu(_conv(x, p["r1"]))
    x = jax.nn.relu(x + _conv(h, p["r2"]))          # residual block
    x = jax.nn.relu(_conv(x, p["c2"], 2))
    return _dense(p["head"], x.mean((1, 2)))


def densenet_tiny_init(key, n_classes=10, c=16, cin=3):
    ks = jax.random.split(key, 4)
    return {"c1": _conv_init(ks[0], 3, cin, c),
            "d1": _conv_init(ks[1], 3, c, c), "d2": _conv_init(ks[2], 3, 2 * c, c),
            "head": _dense_init(ks[3], 3 * c, n_classes)}


def densenet_tiny_apply(p, x):
    x = jax.nn.relu(_conv(x, p["c1"], 2))
    h1 = jax.nn.relu(_conv(x, p["d1"]))
    x = jnp.concatenate([x, h1], -1)                # dense connectivity
    h2 = jax.nn.relu(_conv(x, p["d2"]))
    x = jnp.concatenate([x, h2], -1)
    return _dense(p["head"], x.mean((1, 2)))


# ---------------------------------------------------------------------------
# GRU cell (text + audio recurrent backbones)
# ---------------------------------------------------------------------------
def gru_init(key, din, dh):
    ks = jax.random.split(key, 2)
    return {"wx": trunc_normal(ks[0], (din, 3 * dh), din ** -0.5, jnp.float32),
            "wh": trunc_normal(ks[1], (dh, 3 * dh), dh ** -0.5, jnp.float32),
            "b": jnp.zeros(3 * dh)}


def gru_apply(p, x):
    """x: (B,S,din) -> (B,S,dh)."""
    dh = p["wh"].shape[0]
    wx = x @ p["wx"] + p["b"]

    def step(h, wx_t):
        r, z, n = jnp.split(wx_t + h @ p["wh"], 3, -1)
        # reset gate applies to the candidate's recurrent term
        n = jnp.tanh(jnp.split(wx_t, 3, -1)[2]
                     + jax.nn.sigmoid(r) * jnp.split(h @ p["wh"], 3, -1)[2])
        z = jax.nn.sigmoid(z)
        h = (1 - z) * n + z * h
        return h, h

    h0 = jnp.zeros((x.shape[0], dh))
    _, hs = jax.lax.scan(step, h0, wx.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2)


def gru_text_init(key, vocab=128, d=48, n_classes=6):
    ks = jax.random.split(key, 4)
    return {"emb": trunc_normal(ks[0], (vocab, d), d ** -0.5, jnp.float32),
            "fwd": gru_init(ks[1], d, d), "bwd": gru_init(ks[2], d, d),
            "head": _dense_init(ks[3], 2 * d, n_classes)}


def gru_text_apply(p, x):
    e = p["emb"][x]                                  # (B,S,d)
    hf = gru_apply(p["fwd"], e)
    hb = gru_apply(p["bwd"], e[:, ::-1])[:, ::-1]
    h = jnp.concatenate([hf, hb], -1).max(1)         # bi-GRU + max pool
    return _dense(p["head"], h)


def transformer_text_init(key, vocab=128, d=48, n_classes=6):
    """Stands in for the paper's Capsule text model (see DESIGN.md)."""
    ks = jax.random.split(key, 6)
    return {"emb": trunc_normal(ks[0], (vocab, d), d ** -0.5, jnp.float32),
            "wq": _dense_init(ks[1], d, d), "wk": _dense_init(ks[2], d, d),
            "wv": _dense_init(ks[3], d, d), "ff": _dense_init(ks[4], d, d),
            "head": _dense_init(ks[5], d, n_classes)}


def transformer_text_apply(p, x):
    e = p["emb"][x]
    q, k, v = _dense(p["wq"], e), _dense(p["wk"], e), _dense(p["wv"], e)
    a = jax.nn.softmax(q @ k.transpose(0, 2, 1) / q.shape[-1] ** 0.5, -1)
    h = e + a @ v
    h = h + jax.nn.relu(_dense(p["ff"], h))
    return _dense(p["head"], h.mean(1))


# ---------------------------------------------------------------------------
# CRNN audio models (paper Table 6: AP / MP / SA / MA pooling variants)
# ---------------------------------------------------------------------------
def crnn_init(key, mels=32, d=48, n_classes=10, variant="ap"):
    ks = jax.random.split(key, 5)
    p = {"conv": _conv_init(ks[0], 3, 1, 8),
         "gru": gru_init(ks[1], 8 * (mels // 2), d),
         "head": _dense_init(ks[2], d, n_classes)}
    if variant in ("sa", "ma"):
        p["att1"] = _dense_init(ks[3], d, 1)
    if variant == "ma":
        p["att2"] = _dense_init(ks[4], d, 1)
    return p


def crnn_apply(p, x, variant="ap"):
    """x: (B,frames,mels). variant passed by closure — params stay a pure
    array pytree (strings break participant stacking)."""
    B, T, M = x.shape
    h = jax.nn.relu(_conv(x[..., None], p["conv"], 1))       # (B,T,M,8)
    h = h.reshape(B, T // 2, 2, M, 8).mean(2)                # pool time
    h = h.reshape(B, T // 2, 2, (M // 2) * 8 * 2 // 2)       # fold mels
    h = h.mean(2)
    h = gru_apply(p["gru"], h)                               # (B,T',d)
    v = variant
    if v == "ap":
        g = h.mean(1)
    elif v == "mp":
        g = h.max(1)
    else:
        a1 = jax.nn.softmax(_dense(p["att1"], h), 1)
        g = (a1 * h).sum(1)
        if v == "ma":
            a2 = jax.nn.softmax(_dense(p["att2"], h), 1)
            g = 0.5 * g + 0.5 * (a2 * h).sum(1)
    return _dense(p["head"], g)


IMAGE_MODELS = {"vgg_tiny": (vgg_tiny_init, vgg_tiny_apply),
                "resnet_tiny": (resnet_tiny_init, resnet_tiny_apply),
                "densenet_tiny": (densenet_tiny_init, densenet_tiny_apply)}
TEXT_MODELS = {"gru_text": (gru_text_init, gru_text_apply),
               "transformer_text": (transformer_text_init,
                                    transformer_text_apply)}
AUDIO_MODELS = {f"crnn_{v}": (functools.partial(crnn_init, variant=v),
                              functools.partial(crnn_apply, variant=v))
                for v in ("ap", "mp", "sa", "ma")}
