"""Token-choice top-k MoE with sort-based capacity dispatch.

Design notes (TPU adaptation):
  * Dispatch avoids the classic (tokens, experts, capacity) one-hot einsum —
    at 32k-seq prefill that tensor is O(10^13). Instead tokens are argsorted
    by expert id, ranked within their expert by position arithmetic, and
    scattered into a static (E, capacity, D) buffer (`mode='drop'` handles
    over-capacity tokens = the standard "token dropping" semantics).
  * Expert weights carry a leading E dim sharded over the `model` mesh axis
    (expert parallelism); the scatter/gather pair is where XLA inserts the
    all-to-all — visible in the dry-run collective table.
  * Router math in f32; aux load-balance loss is the Switch-style E·Σ f_e·P_e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import trunc_normal
from repro.sharding.constrain import constrain


def moe_init(key, cfg, dtype, stack=()):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p = {
        "router": trunc_normal(ks[0], (*stack, d, E), d ** -0.5, jnp.float32),
        "wi": trunc_normal(ks[1], (*stack, E, d, f), d ** -0.5, dtype),
        "wg": trunc_normal(ks[2], (*stack, E, d, f), d ** -0.5, dtype),
        "wo": trunc_normal(ks[3], (*stack, E, f, d), f ** -0.5, dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = {
            "wi": trunc_normal(ks[4], (*stack, d, fs), d ** -0.5, dtype),
            "wg": trunc_normal(ks[5], (*stack, d, fs), d ** -0.5, dtype),
            "wo": trunc_normal(ks[6], (*stack, fs, d), fs ** -0.5, dtype),
        }
    return p


def capacity(n_tokens, cfg):
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(4, -(-c // 4) * 4)    # round up to a multiple of 4, >= 4


def n_groups(T, E):
    """Dispatch groups: largest power of two <= 64 such that every group
    still holds >= 4·E tokens (so per-group capacity stays meaningful)."""
    g = 1
    while g < 64 and T % (2 * g) == 0 and T // (2 * g) >= 4 * E:
        g *= 2
    return g


def moe_apply(p, x, cfg):
    """x: (B,S,D) -> (y, aux_loss).

    Grouped sort-based dispatch (§Perf cycle 2): tokens are split into G
    data-parallel groups; sort/rank/scatter happen *within* a group, so with
    the G dim pinned to `data` and the E dim to `model` every scatter is
    shard-local and the only cross-shard movement is the (G,E)-blocked
    token buffer — which GSPMD lowers as all-to-all/all-gather instead of
    the pathological full-buffer all-reduce the global scatter produced
    (measured 78 GiB -> ~9 GiB link bytes per DeepSeek MoE layer at 32k
    prefill). Per-(group,expert) capacity is the standard TPU "grouped"
    token-dropping semantic (Switch/GShard style).
    """
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (T,E)
    top_p, top_i = jax.lax.top_k(probs, k)                      # (T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- grouped sort-based dispatch ---------------------------------------
    G = n_groups(T, E)
    Tg = T // G
    cap = capacity(Tg, cfg)
    xg = constrain(xt.reshape(G, Tg, D), ("dp", None, None))
    ge = top_i.reshape(G, Tg * k)                               # expert ids
    gp = top_p.reshape(G, Tg * k)

    def dispatch_one(eids):
        order = jnp.argsort(eids)
        se = eids[order]
        start = jnp.searchsorted(se, jnp.arange(E))
        rank = jnp.arange(Tg * k) - start[se]
        keep = rank < cap
        dest = jnp.where(keep, se * cap + rank, E * cap)
        return order, dest, keep

    order, dest, keep = jax.vmap(dispatch_one)(ge)
    st = order // k                                             # token in group
    src = jnp.take_along_axis(
        xg, st[..., None], axis=1)                              # (G,Tg*k,D)
    buf = jax.vmap(lambda d, s: jnp.zeros((E * cap, D), xt.dtype)
                   .at[d].set(s, mode="drop"))(dest, src)
    buf = constrain(buf.reshape(G, E, cap, D),
                    ("dp", "model", None, None))

    # ---- expert compute (block-local: G on data, E on model) ----------------
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    g_ = jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    h = jax.nn.silu(g_.astype(jnp.float32)).astype(buf.dtype) * h
    h = constrain(h, ("dp", "model", None, None))
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"]).reshape(G, E * cap, D)
    out = constrain(out, ("dp", None, None))      # gather experts per group

    # ---- combine (group-local gather + weighted scatter-add) ----------------
    back = jnp.take_along_axis(out, jnp.minimum(dest, E * cap - 1)[..., None],
                               axis=1)
    sp = jnp.take_along_axis(gp, order, axis=1)
    w = jnp.where(keep, sp, 0.0).astype(back.dtype)[..., None]
    y = jax.vmap(lambda t, bw: jnp.zeros((Tg, D), back.dtype)
                 .at[t].add(bw))(st, back * w * keep[..., None])
    y = y.reshape(B, S, D)

    # ---- shared experts (always-on, DeepSeek-style) --------------------------
    if "shared" in p:
        s = p["shared"]
        hs = jnp.einsum("td,df->tf", xt, s["wi"])
        gs = jnp.einsum("td,df->tf", xt, s["wg"])
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(xt.dtype) * hs
        y = y + jnp.einsum("tf,fd->td", hs, s["wo"]).reshape(B, S, D)

    # ---- Switch aux load-balance loss ----------------------------------------
    f_e = jnp.zeros(E, jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * k)
    P_e = probs.mean(0)
    aux = cfg.router_aux_coef * E * jnp.sum(f_e * P_e)
    return y.astype(x.dtype), aux
