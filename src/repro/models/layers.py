"""Core layers: norms, RoPE, embeddings, dense (SwiGLU) FFN.

Functional style: ``init_*`` builds a params dict (optionally with a stacked
leading ``repeats`` dim for scan-over-layers); ``apply_*`` consumes it.
Compute runs in the activation dtype; norms/softmax accumulate in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def trunc_normal(key, shape, scale, dtype):
    """Fan-in scaled init (normal, as in most published decoder stacks)."""
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in, d_out, dtype, stack=(), bias=False):
    p = {"w": trunc_normal(key, (*stack, d_in, d_out), d_in ** -0.5, dtype)}
    if bias:
        p["b"] = jnp.zeros((*stack, d_out), dtype)
    return p


def dense_apply(p, x, prec=None):
    y = jnp.einsum("...i,io->...o", x, p["w"], precision=prec)
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def rmsnorm_init(d, dtype, stack=()):
    return {"g": jnp.ones((*stack, d), dtype)}


def rmsnorm_apply(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    if x.ndim == ang.ndim + 1:                        # has a heads dim
        ang = ang[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding + LM head
# --------------------------------------------------------------------------
def embed_init(key, vocab, d, dtype):
    return {"table": trunc_normal(key, (vocab, d), d ** -0.5, dtype)}


def embed_apply(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def lm_head_apply(p_embed, p_head, x, tie):
    if tie:
        return jnp.einsum("...d,vd->...v", x, p_embed["table"])
    return jnp.einsum("...d,dv->...v", x, p_head["w"])


# --------------------------------------------------------------------------
# Dense FFN (SwiGLU — used by every assigned dense arch)
# --------------------------------------------------------------------------
def ffn_init(key, d, d_ff, dtype, stack=()):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": trunc_normal(k1, (*stack, d, d_ff), d ** -0.5, dtype),
        "wg": trunc_normal(k2, (*stack, d, d_ff), d ** -0.5, dtype),
        "wo": trunc_normal(k3, (*stack, d_ff, d), d_ff ** -0.5, dtype),
    }


def ffn_apply(p, x):
    from repro.sharding.constrain import constrain
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    g = jnp.einsum("...d,df->...f", x, p["wg"])
    # pin the hidden to the TP axis: without this GSPMD resolves the
    # (batch='data' x, D='data' weight) contraction by all-gathering the
    # weight and computing the FULL d_ff per device (§Perf cycle 2b: 13x)
    h = constrain(h, tuple([None] * (h.ndim - 1)) + ("model",))
    g = constrain(g, tuple([None] * (g.ndim - 1)) + ("model",))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# --------------------------------------------------------------------------
# Chunked-remat scan (recurrent memory fix — EXPERIMENTS.md §Perf cycle 1)
# --------------------------------------------------------------------------
def chunked_scan(step, carry, xs, chunk=256, remat=True):
    """`lax.scan(step, carry, xs)` with gradient checkpoints every `chunk`
    steps: backward saves the carry only at chunk boundaries and recomputes
    inside — O(S/chunk) instead of O(S) saved state. Critical when the
    carry is large (mLSTM's hd×hd matrix memory: 347 GiB -> GBs at 4k seq).
    Falls back to a plain scan when S doesn't divide."""
    S = jax.tree.leaves(xs)[0].shape[0]
    c = min(chunk, S)
    if S % c or c == S:
        return jax.lax.scan(step, carry, xs)

    def chunk_body(carry, xc):
        return jax.lax.scan(step, carry, xc)

    if remat:
        chunk_body = jax.checkpoint(chunk_body)
    xs_c = jax.tree.map(lambda t: t.reshape(S // c, c, *t.shape[1:]), xs)
    carry, ys = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(lambda t: t.reshape(S, *t.shape[2:]), ys)
    return carry, ys


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------
def softmax_xent(logits, labels, ignore_index=-1):
    """Mean next-token cross-entropy over valid positions. logits f32-cast.

    Implemented with a fused one-hot select/reduce rather than
    take_along_axis so a vocab dim sharded over the `model` mesh axis never
    forces an all-gather of the logits (critical at 152k–200k vocabs).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = labels[..., None] == jnp.arange(logits.shape[-1], dtype=labels.dtype)
    ll = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    valid = (labels != ignore_index).astype(jnp.float32)
    nll = (lse - ll) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1.0)
