"""Mamba selective-SSM block (Jamba's recurrent mixer, arXiv:2403.19887).

Reference path is a `lax.scan` recurrence (the CPU/lowering oracle); the
TPU hot path is `repro.kernels.selective_scan` (chunked parallel scan in
Pallas). Decode keeps O(1) state: a (conv_dim-1) tail of inputs plus the
(d_inner, d_state) SSM state — this is what makes long_500k native here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import chunked_scan, trunc_normal
from repro.sharding.constrain import constrain


def mamba_init(key, cfg, dtype, stack=()):
    d, di = cfg.d_model, cfg.d_inner_ssm
    st, dtr, K = cfg.ssm_state_dim, cfg.dt_rank, cfg.ssm_conv_dim
    ks = jax.random.split(key, 6)
    return {
        "in_proj": trunc_normal(ks[0], (*stack, d, 2 * di), d ** -0.5, dtype),
        "conv_w": trunc_normal(ks[1], (*stack, K, di), K ** -0.5, dtype),
        "conv_b": jnp.zeros((*stack, di), dtype),
        "x_proj": trunc_normal(ks[2], (*stack, di, dtr + 2 * st), di ** -0.5, dtype),
        "dt_proj": {"w": trunc_normal(ks[3], (*stack, dtr, di), dtr ** -0.5, dtype),
                    "b": jnp.full((*stack, di), -4.6, dtype)},  # softplus^-1(0.01)
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, st + 1, dtype=jnp.float32)), (*stack, di, st)
        ).astype(jnp.float32) * jnp.ones((*stack, di, st), jnp.float32),
        "D": jnp.ones((*stack, di), jnp.float32),
        "out_proj": trunc_normal(ks[5], (*stack, di, d), di ** -0.5, dtype),
    }


def _ssm_inputs(p, xc, cfg):
    """xc: (B,S,di) post-conv. Returns dt (B,S,di), Bm/Cm (B,S,st), A (di,st)."""
    st, dtr = cfg.ssm_state_dim, cfg.dt_rank
    proj = jnp.einsum("bsd,de->bse", xc, p["x_proj"])
    dt_in, Bm, Cm = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"]["w"]) + p["dt_proj"]["b"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    A = -jnp.exp(p["A_log"])                                   # (di,st)
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32), A


def selective_scan_ref(xc, dt, Bm, Cm, A, D, h0=None):
    """Sequential selective scan. xc:(B,S,di) -> (y:(B,S,di), h:(B,di,st))."""
    B, S, di = xc.shape
    st = A.shape[-1]
    xf = xc.astype(jnp.float32)

    def step(h, inp):
        # discretization INSIDE the step: materializing dA/dBx as (B,S,di,st)
        # up front cost ~4 GiB/device/layer at 4k seq (§Perf cycle 1)
        dt_t, B_t, C_t, x_t = inp                              # (B,di)/(B,st)
        dA_t = jnp.exp(dt_t[..., None] * A)                    # (B,di,st)
        dBx_t = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h = dA_t * h + dBx_t                                   # (B,di,st)
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((B, di, st), jnp.float32) if h0 is None else h0
    h, ys = chunked_scan(step, h0,
                         (dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
                          Cm.transpose(1, 0, 2), xf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + xf * D                         # (B,S,di)
    return y, h


def _causal_conv(p, x, state=None):
    """x: (B,S,di); depthwise causal conv (kernel K). state: (B,K-1,di)."""
    K = p["conv_w"].shape[0]
    pad = state if state is not None else jnp.zeros(
        (x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                     # (B,S+K-1,di)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out + p["conv_b"], new_state


def mamba_apply(p, x, cfg, impl="ref"):
    """Training/prefill. x: (B,S,D) -> (B,S,D)."""
    di = cfg.d_inner_ssm
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :di], xz[..., di:]
    xi = constrain(xi, (None, None, "model"))   # d_inner stays TP-sharded
    xc, _ = _causal_conv(p, xi)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, Bm, Cm, A = _ssm_inputs(p, xc, cfg)
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.selective_scan(xc, dt, Bm, Cm, A, p["D"])
    else:
        y, _ = selective_scan_ref(xc, dt, Bm, Cm, A, p["D"])
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bsd,de->bse", y.astype(x.dtype), p["out_proj"])


def mamba_state_init(cfg, batch, dtype):
    di, st, K = cfg.d_inner_ssm, cfg.ssm_state_dim, cfg.ssm_conv_dim
    return {"conv": jnp.zeros((batch, K - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, st), jnp.float32)}


def mamba_decode(p, x, cfg, state, pos):
    """x: (B,1,D) -> (y, new_state). O(1) per token."""
    di = cfg.d_inner_ssm
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :di], xz[..., di:]
    xc, conv_state = _causal_conv(p, xi, state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt, Bm, Cm, A = _ssm_inputs(p, xc, cfg)
    y, h = selective_scan_ref(xc, dt, Bm, Cm, A, p["D"], h0=state["ssm"])
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsd,de->bse", y.astype(x.dtype), p["out_proj"])
    return out, {"conv": conv_state, "ssm": h}
