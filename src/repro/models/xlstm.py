"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory), arXiv:2405.04517.

Both use the stabilized exponential gating of the paper (running max m_t).
Reference recurrences are `lax.scan`; the TPU hot path for mLSTM is the
chunkwise-parallel `repro.kernels.mlstm` Pallas kernel. Both are O(1)-state
at decode, so xlstm-1.3b runs long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import chunked_scan, trunc_normal

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg, dtype, stack=()):
    d = cfg.d_model
    di = int(cfg.xlstm_proj_factor * d)
    H = cfg.n_heads
    hd = di // H
    ks = jax.random.split(key, 6)
    return {
        "up": trunc_normal(ks[0], (*stack, d, 2 * di), d ** -0.5, dtype),
        "wq": trunc_normal(ks[1], (*stack, di, H, hd), di ** -0.5, dtype),
        "wk": trunc_normal(ks[2], (*stack, di, H, hd), di ** -0.5, dtype),
        "wv": trunc_normal(ks[3], (*stack, di, H, hd), di ** -0.5, dtype),
        "w_if": trunc_normal(ks[4], (*stack, di, H, 2), di ** -0.5, jnp.float32),
        "b_if": jnp.zeros((*stack, H, 2), jnp.float32),
        "gn_g": jnp.ones((*stack, H, hd), dtype),
        "down": trunc_normal(ks[5], (*stack, di, d), di ** -0.5, dtype),
    }


def mlstm_cell_ref(q, k, v, ig, fg, state=None):
    """Stabilized mLSTM recurrence.

    q,k,v: (B,S,H,hd); ig,fg: (B,S,H) raw gate pre-activations.
    state: dict(C:(B,H,hd,hd), n:(B,H,hd), m:(B,H)) or None.
    Returns (h: (B,S,H,hd) f32, new_state).
    """
    B, S, H, hd = q.shape
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    qf, kf, vf = (t.astype(jnp.float32) * (hd ** -0.25) for t in (q, k, v))
    vf = vf * hd ** 0.25  # only q,k scaled (standard 1/sqrt(hd) split)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, i_t, lf_t = inp                     # (B,H,...)
        m_new = jnp.maximum(lf_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(lf_t + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :])         # (B,H,hd,hd)
        n = f_p[..., None] * n + i_p[..., None] * k_t
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    # chunked_scan: bwd saves the (B,H,hd,hd) matrix memory only at chunk
    # boundaries (347 GiB -> ~13 GiB at 4k seq; EXPERIMENTS.md §Perf)
    (C, n, m), hs = chunked_scan(
        step, (C0, n0, m0),
        (qf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
         vf.transpose(1, 0, 2, 3), ig.astype(jnp.float32).transpose(1, 0, 2),
         logf.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2, 3), {"C": C, "n": n, "m": m}


def _mlstm_qkvg(p, x, cfg):
    from repro.sharding.constrain import constrain
    xz = jnp.einsum("bsd,de->bse", x, p["up"])
    xz = constrain(xz, (None, None, "model"))   # keep d_inner TP-sharded
    xm, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bsd,dhk->bshk", xm, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xm, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xm, p["wv"])
    g = jnp.einsum("bsd,dhg->bshg", xm.astype(jnp.float32), p["w_if"]) + p["b_if"]
    return q, k, v, g[..., 0], g[..., 1], z


def _mlstm_out(p, h, z, x_dtype, eps):
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)        # per-head groupnorm
    hn = (hf * jax.lax.rsqrt(var + eps)) * p["gn_g"].astype(jnp.float32)
    hn = hn.reshape(*h.shape[:-2], -1)
    y = hn * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bsd,de->bse", y.astype(x_dtype), p["down"])


def mlstm_apply(p, x, cfg, impl="ref"):
    q, k, v, ig, fg, z = _mlstm_qkvg(p, x, cfg)
    if impl == "pallas":
        from repro.kernels import ops as kops
        h, _ = kops.mlstm(q, k, v, ig, fg)
    else:
        h, _ = mlstm_cell_ref(q, k, v, ig, fg)
    return _mlstm_out(p, h, z, x.dtype, cfg.norm_eps)


def mlstm_state_init(cfg, batch, dtype):
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    H, hd = cfg.n_heads, di // cfg.n_heads
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def mlstm_decode(p, x, cfg, state, pos):
    q, k, v, ig, fg, z = _mlstm_qkvg(p, x, cfg)
    h, new_state = mlstm_cell_ref(q, k, v, ig, fg, state)
    return _mlstm_out(p, h, z, x.dtype, cfg.norm_eps), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg, dtype, stack=()):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    f = int(cfg.slstm_proj_factor * d)
    ks = jax.random.split(key, 5)
    return {
        "w_in": trunc_normal(ks[0], (*stack, d, H, 4 * hd), d ** -0.5, dtype),
        # block-diagonal hidden-to-hidden recurrence, per head
        "r": trunc_normal(ks[1], (*stack, H, hd, 4 * hd), hd ** -0.5, jnp.float32),
        "b": jnp.zeros((*stack, H, 4 * hd), jnp.float32),
        "gn_g": jnp.ones((*stack, H, hd), dtype),
        "up1": trunc_normal(ks[2], (*stack, d, f), d ** -0.5, dtype),
        "up2": trunc_normal(ks[3], (*stack, d, f), d ** -0.5, dtype),
        "down": trunc_normal(ks[4], (*stack, f, d), f ** -0.5, dtype),
    }


def slstm_cell_ref(wx, r, b, state):
    """wx: (B,S,H,4*hd) input contributions; recurrence per head.

    state: dict(h,c,n:(B,H,hd), m:(B,H,hd)). Returns (h_seq (B,S,H,hd) f32, state).
    """
    hd = r.shape[-2]

    def step(carry, wx_t):
        h, c, n, m = carry
        pre = wx_t + jnp.einsum("bhk,bhkg->bhg", h, jnp.broadcast_to(
            r, (h.shape[0], *r.shape[-3:]))) + b               # (B,H,4hd)
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(lf + m - m_new)
        c = f_p * c + i_p * zt
        n = f_p * n + i_p
        h = ot * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    (h, c, n, m), hs = chunked_scan(
        step, (state["h"], state["c"], state["n"], state["m"]),
        wx.astype(jnp.float32).transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2, 3), {"h": h, "c": c, "n": n, "m": m}


def slstm_state_init(cfg, batch, dtype):
    H, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}


def _slstm_out(p, h, x, cfg):
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    hn = (hf * jax.lax.rsqrt(var + cfg.norm_eps)) * p["gn_g"].astype(jnp.float32)
    hn = hn.reshape(*h.shape[:-2], -1).astype(x.dtype)
    a = jnp.einsum("bsd,df->bsf", hn, p["up1"])
    g = jnp.einsum("bsd,df->bsf", hn, p["up2"])
    a = a * jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", a, p["down"])


def slstm_apply(p, x, cfg, impl="ref"):
    wx = jnp.einsum("bsd,dhg->bshg", x, p["w_in"])
    st = slstm_state_init(cfg, x.shape[0], x.dtype)
    h, _ = slstm_cell_ref(wx, p["r"], p["b"], st)
    return _slstm_out(p, h, x, cfg)


def slstm_decode(p, x, cfg, state, pos):
    wx = jnp.einsum("bsd,dhg->bshg", x, p["w_in"])
    h, new_state = slstm_cell_ref(wx, p["r"], p["b"], state)
    return _slstm_out(p, h, x, cfg), new_state
