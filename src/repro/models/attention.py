"""GQA attention: chunked-flash training/prefill path + KV-cache decode.

The training path is an online-softmax chunked attention written in pure
jnp/lax (the CPU-lowering oracle); on TPU the same contract is served by
``repro.kernels.flash_attention`` (selected via ``impl='pallas'``).

Sharding note: GQA keeps the full H head dim intact through every einsum —
KV heads are repeated to H at compute time (cheap: they are replicated or
resliced, never stored repeated in the cache) — because reshaping H into
(KV, G) breaks GSPMD head-sharding propagation (measured: 16x compute
replication on the model axis). MQA/MLA (KV=1) uses a shared-KV einsum
with no repetition at all. `constrain` pins the head dim to the `model`
mesh axis whenever divisible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, trunc_normal
from repro.sharding.constrain import constrain

NEG_INF = -1e30


def attn_init(key, cfg, dtype, stack=()):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": trunc_normal(ks[0], (*stack, d, H, hd), d ** -0.5, dtype),
        "wk": trunc_normal(ks[1], (*stack, d, KV, hd), d ** -0.5, dtype),
        "wv": trunc_normal(ks[2], (*stack, d, KV, hd), d ** -0.5, dtype),
        "wo": trunc_normal(ks[3], (*stack, H, hd, d), (H * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((*stack, H, hd), dtype)
        p["bk"] = jnp.zeros((*stack, KV, hd), dtype)
        p["bv"] = jnp.zeros((*stack, KV, hd), dtype)
    return p


def _qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def repeat_kv(k, n_heads):
    """(B,S,KV,hd) -> (B,S,H,hd); H-dim then constrained to `model`."""
    KV = k.shape[2]
    if KV == n_heads:
        return k
    k = jnp.repeat(k, n_heads // KV, axis=2)
    return constrain(k, (None, None, "model", None))


def mask_bias(q_pos, k_pos, window):
    """(Sq,Sk) additive mask: causal, optional sliding window."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def chunked_attention(q, k, v, *, n_kv_heads, window=0, q_offset=0,
                      chunk_q=1024, chunk_kv=1024, softmax_scale=None):
    """Online-softmax attention. q:(B,Sq,H,hd) k,v:(B,Sk,KV,hd/hd_v).

    Chunked over both Sq (outer scan) and Sk (inner scan) so the peak score
    tensor is (B,H,cq,ck) regardless of sequence length. KV==1 uses the
    shared-KV (MQA/MLA) path.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    hd_v = v.shape[-1]
    mqa = n_kv_heads == 1
    scale = softmax_scale or hd ** -0.5

    def _chunk(S, target):                 # largest divisor of S <= target
        c = min(target, S)
        while S % c:
            c -= 1
        return c

    cq, ck = _chunk(Sq, chunk_q), _chunk(Sk, chunk_kv)
    nq, nk = Sq // cq, Sk // ck

    q = constrain(q * scale, (None, None, "model", None))
    if mqa:
        k2, v2 = k[:, :, 0], v[:, :, 0]                    # (B,Sk,hd)
        kg = k2.reshape(B, nk, ck, hd).transpose(1, 0, 2, 3)
        vg = v2.reshape(B, nk, ck, hd_v).transpose(1, 0, 2, 3)
    else:
        k2 = repeat_kv(k, H)
        v2 = repeat_kv(v, H)
        kg = k2.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
        vg = v2.reshape(B, nk, ck, H, hd_v).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(B, nq, cq, H, hd).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx                                # qi: (B,cq,H,hd)
        q_pos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry, kv_and_idx):
            m, l, acc = carry
            (kc, vc), ik = kv_and_idx
            k_pos = ik * ck + jnp.arange(ck)
            if mqa:
                s = jnp.einsum("bqhd,bkd->bhqk", qi, kc).astype(jnp.float32)
            else:
                s = jnp.einsum("bqhd,bkhd->bhqk", qi, kc).astype(jnp.float32)
            s = constrain(s, (None, "model", None, None))
            s = s + mask_bias(q_pos, k_pos, window)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            if mqa:
                pv = jnp.einsum("bhqk,bkd->bhqd", p.astype(vc.dtype), vc)
            else:
                pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vc.dtype), vc)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      ((kg, vg), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,H,cq,hd_v)
        return None, out.transpose(0, 2, 1, 3)             # (B,cq,H,hd_v)

    _, outs = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd_v)
    return out.astype(q.dtype)


def attn_apply(p, x, cfg, positions, impl="ref"):
    """Training/prefill forward. x: (B,S,D) -> (B,S,D), plus (k,v) for cache."""
    q, k, v = _qkv(p, x, cfg, positions)
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, n_kv_heads=cfg.n_kv_heads,
                                   window=cfg.window)
    else:
        out = chunked_attention(q, k, v, n_kv_heads=cfg.n_kv_heads,
                                window=cfg.window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, (k, v)


# --------------------------------------------------------------------------
# Decode (one token, KV cache; ring buffer when cfg.window > 0)
# --------------------------------------------------------------------------
def attn_cache_init(cfg, batch, seq_len, dtype):
    S = min(cfg.window, seq_len) if cfg.window else seq_len
    shp = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def decode_attend(q, ck, cv, pos, *, window, softmax_scale):
    """q: (B,1,H,hd); ck/cv: (B,S,KV,hd). Single-token attention."""
    B, _, H, hd = q.shape
    S = ck.shape[1]
    qh = q[:, 0] * softmax_scale                           # (B,H,hd)
    k2 = repeat_kv(ck, H)                                  # (B,S,H,hd)
    v2 = repeat_kv(cv, H)
    s = jnp.einsum("bhd,bshd->bhs", qh, k2).astype(jnp.float32)
    idx = jnp.arange(S)
    valid = ((idx <= pos) | (pos >= S)) if window else (idx <= pos)
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", w.astype(v2.dtype), v2)
    return out[:, None]                                    # (B,1,H,hd_v)


def attn_decode(p, x, cfg, cache, pos):
    """x: (B,1,D); pos: () int32 current position. Returns (y, new_cache)."""
    B = x.shape[0]
    S = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)                   # k,v: (B,1,KV,hd)
    slot = pos % S if cfg.window else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    out = decode_attend(q, ck, cv, pos, window=cfg.window,
                        softmax_scale=cfg.head_dim ** -0.5)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}
