"""Model assembly: heterogeneous layer stacks, scan/unroll lowering, decode.

A config's ``segments`` is a sequence of (pattern, repeats); each pattern
entry is "<mixer>:<ffn>". Parameters for each pattern position carry a
leading ``repeats`` dim so the production path is a single `lax.scan` per
segment (compact HLO, per-layer remat), while the roofline path unrolls the
same body (`lowering='unroll'`) for accurate XLA cost analysis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import (dense_init, embed_apply, embed_init,
                                 ffn_apply, ffn_init, lm_head_apply,
                                 rmsnorm_apply, rmsnorm_init, softmax_xent,
                                 trunc_normal)
from repro.sharding.constrain import constrain


# ---------------------------------------------------------------------------
# Per-layer init / apply / decode dispatch
# ---------------------------------------------------------------------------
def layer_init(key, kind, cfg, dtype, stack=()):
    mixer, ffn = kind.split(":")
    k1, k2 = jax.random.split(key)
    p = {"norm1": rmsnorm_init(cfg.d_model, dtype, stack)}
    p["mixer"] = {
        "gqa": attn.attn_init, "mla": mla_mod.mla_init, "mamba": mam.mamba_init,
        "mlstm": xl.mlstm_init, "slstm": xl.slstm_init,
    }[mixer](k1, cfg, dtype, stack)
    if ffn != "-":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype, stack)
        if ffn == "dense":
            p["ffn"] = ffn_init(k2, cfg.d_model, cfg.d_ff, dtype, stack)
        elif ffn == "moe":
            p["ffn"] = moe_mod.moe_init(k2, cfg, dtype, stack)
        elif ffn == "moe_dense":                       # Arctic: MoE ∥ dense
            ka, kb = jax.random.split(k2)
            p["ffn"] = {"moe": moe_mod.moe_init(ka, cfg, dtype, stack),
                        "dense": ffn_init(kb, cfg.d_model, cfg.d_ff, dtype, stack)}
    return p


def layer_apply(p, kind, x, cfg, positions, impl="ref"):
    mixer, ffn = kind.split(":")
    h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    if mixer == "gqa":
        y, _ = attn.attn_apply(p["mixer"], h, cfg, positions, impl)
    elif mixer == "mla":
        y, _ = mla_mod.mla_apply(p["mixer"], h, cfg, positions, impl)
    elif mixer == "mamba":
        y = mam.mamba_apply(p["mixer"], h, cfg, impl)
    elif mixer == "mlstm":
        y = xl.mlstm_apply(p["mixer"], h, cfg, impl)
    else:
        y = xl.slstm_apply(p["mixer"], h, cfg, impl)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ffn != "-":
        h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        if ffn == "dense":
            y = ffn_apply(p["ffn"], h)
        elif ffn == "moe":
            y, aux = moe_mod.moe_apply(p["ffn"], h, cfg)
        else:
            ym, aux = moe_mod.moe_apply(p["ffn"]["moe"], h, cfg)
            y = ym + ffn_apply(p["ffn"]["dense"], h)
        x = x + y
    return constrain(x, ("dp", "r", "r")), aux


def layer_cache_init(kind, cfg, batch, seq_len, dtype):
    mixer, _ = kind.split(":")
    if mixer == "gqa":
        return attn.attn_cache_init(cfg, batch, seq_len, dtype)
    if mixer == "mla":
        return mla_mod.mla_cache_init(cfg, batch, seq_len, dtype)
    if mixer == "mamba":
        return mam.mamba_state_init(cfg, batch, dtype)
    if mixer == "mlstm":
        return xl.mlstm_state_init(cfg, batch, dtype)
    return xl.slstm_state_init(cfg, batch, dtype)


def layer_decode(p, kind, x, cfg, cache, pos):
    mixer, ffn = kind.split(":")
    h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    fn = {"gqa": attn.attn_decode, "mla": mla_mod.mla_decode,
          "mamba": mam.mamba_decode, "mlstm": xl.mlstm_decode,
          "slstm": xl.slstm_decode}[mixer]
    y, new_cache = fn(p["mixer"], h, cfg, cache, pos)
    x = x + y
    if ffn != "-":
        h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        if ffn == "dense":
            y = ffn_apply(p["ffn"], h)
        elif ffn == "moe":
            y, _ = moe_mod.moe_apply(p["ffn"], h, cfg)
        else:
            ym, _ = moe_mod.moe_apply(p["ffn"]["moe"], h, cfg)
            y = ym + ffn_apply(p["ffn"]["dense"], h)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------
def init_params(key, cfg, dtype=jnp.bfloat16):
    keys = jax.random.split(key, len(cfg.segments) + 3)
    params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
              "final_norm": rmsnorm_init(cfg.d_model, dtype),
              "segments": []}
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)
    for si, (pattern, repeats) in enumerate(cfg.segments):
        seg = {}
        pkeys = jax.random.split(keys[2 + si], len(pattern))
        for j, kind in enumerate(pattern):
            seg[f"p{j}"] = layer_init(pkeys[j], kind, cfg, dtype, stack=(repeats,))
        params["segments"].append(seg)
    if cfg.mtp_depth:                                   # DeepSeek-V3 MTP head
        km = jax.random.split(keys[-1], 2)
        last_kind = cfg.segments[-1][0][-1]
        params["mtp"] = {
            "proj": dense_init(km[0], 2 * cfg.d_model, cfg.d_model, dtype),
            "norm_h": rmsnorm_init(cfg.d_model, dtype),
            "norm_e": rmsnorm_init(cfg.d_model, dtype),
            "layer": layer_init(km[1], last_kind, cfg, dtype, stack=(1,)),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg, batch):
    """batch: dict with 'tokens' (B,S_t) and optionally 'prefix' (B,P,D)."""
    x = embed_apply(params["embed"], batch["tokens"])
    if cfg.input_mode == "tokens+prefix":
        x = jnp.concatenate([batch["prefix"].astype(x.dtype), x], axis=1)
    # keep activations batch-sharded only: without this, the embedding
    # table's FSDP dim leaks a `data`-sharded d_model into the residual
    # stream and GSPMD replicates downstream layers (measured: ~45 GiB)
    return constrain(x, ("dp", "r", "r"))


def forward(params, cfg, batch, lowering="scan", impl="ref", remat=True,
            return_hidden=False, apply_head=True):
    """Returns (logits, aux_loss[, hidden])."""
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    for seg_params, (pattern, repeats) in zip(params["segments"], cfg.segments):
        def body(x, p_r, _pattern=pattern):
            aux = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(_pattern):
                x, a = layer_apply(p_r[f"p{j}"], kind, x, cfg, positions, impl)
                aux = aux + a
            return x, aux
        if remat:
            body = jax.checkpoint(body)
        # 'unroll' uses scan(unroll=R), NOT a python loop over t[r] slices:
        # indexing stacked layer params drops their PartitionSpec and GSPMD
        # replicates the weights (measured: 13x per-layer FLOPs at qwen2
        # prefill — EXPERIMENTS.md §Perf cycle 2b)
        unroll = repeats if lowering == "unroll" else 1
        x, auxs = jax.lax.scan(body, x, seg_params, unroll=unroll)
        aux_total = aux_total + auxs.sum()

    h = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = None
    if apply_head:
        logits = lm_head_apply(params["embed"], params.get("head"), h,
                               cfg.tie_embeddings)
        logits = constrain(logits, ("dp", "r", "model"))  # vocab stays sharded
    if return_hidden:
        return logits, aux_total, h
    return logits, aux_total


def loss_fn(params, cfg, batch, lowering="scan", impl="ref", remat=True):
    """Next-token LM loss (+aux, +MTP when configured). labels: -1 = ignore."""
    need_h = bool(cfg.mtp_depth)
    out = forward(params, cfg, batch, lowering, impl, remat, return_hidden=need_h)
    logits, aux = out[0], out[1]
    loss = softmax_xent(logits, batch["labels"])
    metrics = {"lm_loss": loss, "aux_loss": aux}
    if need_h:
        h = out[2]
        mtp = params["mtp"]
        S = h.shape[1]
        tok_emb = embed_inputs(params, cfg, batch)
        h_in = jnp.concatenate(
            [rmsnorm_apply(mtp["norm_h"], h[:, : S - 1], cfg.norm_eps),
             rmsnorm_apply(mtp["norm_e"], tok_emb[:, 1:], cfg.norm_eps)], -1)
        x2 = jnp.einsum("...i,io->...o", h_in, mtp["proj"]["w"])
        positions = jnp.broadcast_to(
            jnp.arange(S - 1, dtype=jnp.int32), (h.shape[0], S - 1))
        last_kind = cfg.segments[-1][0][-1]
        x2, _ = layer_apply(jax.tree.map(lambda t: t[0], mtp["layer"]),
                            last_kind, x2, cfg, positions, impl)
        h2 = rmsnorm_apply(params["final_norm"], x2, cfg.norm_eps)
        logits2 = lm_head_apply(params["embed"], params.get("head"), h2,
                                cfg.tie_embeddings)
        mtp_labels = jnp.concatenate(
            [batch["labels"][:, 2:],
             jnp.full((h.shape[0], 1), -1, batch["labels"].dtype)], axis=1)
        mtp_loss = softmax_xent(logits2, mtp_labels)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    total = loss + aux
    metrics["loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Decode (serve_step: one token against an existing cache)
# ---------------------------------------------------------------------------
def init_cache(cfg, batch, seq_len, dtype=jnp.bfloat16):
    caches = []
    for pattern, repeats in cfg.segments:
        seg = {}
        for j, kind in enumerate(pattern):
            one = layer_cache_init(kind, cfg, batch, seq_len, dtype)
            seg[f"p{j}"] = jax.tree.map(
                lambda t, _r=repeats: jnp.broadcast_to(t[None], (_r, *t.shape)),
                one)
        caches.append(seg)
    return caches


def decode_step(params, cfg, cache, token, pos, lowering="scan"):
    """token: (B,1) int32; pos: () int32. Returns (logits (B,1,V), new_cache)."""
    x = embed_apply(params["embed"], token)
    new_caches = []
    for seg_params, seg_cache, (pattern, repeats) in zip(
            params["segments"], cache, cfg.segments):
        def body(x, pc, _pattern=pattern):
            p_r, c_r = pc
            nc = {}
            for j, kind in enumerate(_pattern):
                x, nc[f"p{j}"] = layer_decode(p_r[f"p{j}"], kind, x, cfg,
                                              c_r[f"p{j}"], pos)
            return x, nc
        unroll = repeats if lowering == "unroll" else 1
        x, nc = jax.lax.scan(body, x, (seg_params, seg_cache), unroll=unroll)
        new_caches.append(nc)
    h = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head_apply(params["embed"], params.get("head"), h,
                           cfg.tie_embeddings)
    return logits, new_caches


def prefill(params, cfg, batch, lowering="scan", impl="ref"):
    """Full-sequence forward returning last-position logits (cache is
    produced by the per-layer apply fns; for the dry-run the interesting
    artifact is the compute/collective profile, so we return logits only).

    Perf note (§Perf cycle 0, found via the roofline): the LM head is
    applied ONLY to the last position — materializing (B, 32k, 200k)
    logits made the head dominate dense-arch prefill by >10x."""
    _, _, h = forward(params, cfg, batch, lowering, impl, remat=False,
                      return_hidden=True, apply_head=False)
    logits = lm_head_apply(params["embed"], params.get("head"), h[:, -1:],
                           cfg.tie_embeddings)
    return constrain(logits, ("dp", "r", "model"))[:, 0]


def count_params(params):
    return sum(x.size for x in jax.tree.leaves(params))
