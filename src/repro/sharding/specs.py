"""PartitionSpec rules for params, batches, and decode caches.

Strategy (baseline — the §Perf hillclimbs change some of these):
  * TP over `model`: attention heads / FFN hidden / experts / vocab;
  * FSDP over `data`: the complementary d_model dim of every large weight;
  * any template axis whose dim is not divisible by the mesh axis size is
    dropped (replicated) — e.g. phi4's 24 heads on a model=16 axis fall
    back to replicated attention weights (recorded in the roofline notes);
  * co-learning stacks a leading participant dim sharded over `pod`.

Templates are keyed by leaf name and aligned to the TRAILING dims of the
leaf (leading stack/repeat dims are replicated).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# trailing-dim templates per leaf name
_TEMPLATES = {
    # embeddings / head
    "table": ("model", "data"),                 # (V, D)
    # generic dense (head.w is (D,V))
    "w": ("data", "model"),
    "b": ("model",),
    # attention
    "wq": ("data", "model", None),              # (D,H,hd)
    "wk": ("data", "model", None),              # (D,KV,hd)
    "wv": ("data", "model", None),
    "wo": ("model", None, "data"),              # (H,hd,D)
    "bq": ("model", None),
    "bk": ("model", None),
    "bv": ("model", None),
    # FFN
    "wi": ("data", "model"),                    # (D,F) — and (E,D,F) via moe
    "wg": ("data", "model"),
    # MLA
    "w_dq": ("data", "model"),                  # (D,ql)
    "w_uq": (None, "model", None),              # (ql,H,e)
    "w_dkv": ("data", "model"),                 # (D,kl)
    "w_kr": ("data", None),                     # (D,rope)
    "w_uk": (None, "model", None),              # (kl,H,nope)
    "w_uv": (None, "model", None),              # (kl,H,vh)
    "w_o": ("model", None, "data"),             # (H,vh,D)
    # MoE
    "router": ("data", None),                   # (D,E)
    # Mamba
    "in_proj": ("data", "model"),               # (D,2di)
    "conv_w": (None, "model"),                  # (K,di)
    "conv_b": ("model",),
    "x_proj": ("model", None),                  # (di,dtr+2st)
    "A_log": ("model", None),                   # (di,st)
    "D": ("model",),
    "out_proj": ("model", "data"),              # (di,D)
    # xLSTM
    "up": ("data", "model"),                    # (D,2di)
    "down": ("model", "data"),                  # (di,D)
    "w_if": ("model", None, None),              # (di,H,2)
    "b_if": (None, None),
    "gn_g": (None, None),
    "w_in": ("data", None, "model"),            # (D,H,4hd)
    "r": (None, None, "model"),                 # (H,hd,4hd)
    "up1": ("data", "model"),
    "up2": ("data", "model"),
}
# MoE expert weights: leading E dim gets 'model', rest from dense template
_MOE_LEAF = {"wi": ("model", "data", None), "wg": ("model", "data", None),
             "wo": ("model", None, "data")}


def _path_names(path):
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return out


def _fits(dim, axis, mesh):
    return axis is not None and axis in mesh.shape and dim % mesh.shape[axis] == 0


def leaf_spec(path_names, shape, mesh, participant=False):
    name = path_names[-1]
    in_moe = any(n in ("ffn", "moe") for n in path_names) and \
        name in _MOE_LEAF and len(shape) >= 3 and "shared" not in path_names
    tmpl = _MOE_LEAF[name] if in_moe else _TEMPLATES.get(name)
    ndim = len(shape)
    off = 1 if participant else 0               # leading participant dim
    spec = [None] * ndim
    if participant:
        spec[0] = "pod"
    if tmpl is not None:
        k = len(tmpl)
        lead = ndim - k                          # stack/repeat dims replicated
        if lead >= off:
            used = {"pod"} if participant else set()
            for i, ax in enumerate(tmpl):
                dim_i = lead + i
                if ax in used:
                    continue
                if _fits(shape[dim_i], ax, mesh):
                    spec[dim_i] = ax
                    used.add(ax)
    return P(*spec)


def param_specs(params_shapes, cfg, mesh, participant=False):
    """pytree of ShapeDtypeStructs -> pytree of PartitionSpecs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = [leaf_spec(_path_names(p), v.shape, mesh, participant)
             for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def _dp_axes(mesh, participant):
    """Data-parallel axes for the batch dim."""
    if participant:
        return "data"                            # leading K dim carries 'pod'
    return tuple(a for a in ("pod", "data") if a in mesh.shape) or None


def batch_specs(cfg, mesh, kind="train", participant=False):
    """Specs for the input batch dict (tokens/labels/prefix or decode)."""
    dp = _dp_axes(mesh, participant)
    lead = ("pod",) if participant else ()
    tok = P(*lead, dp, None)
    out = {"tokens": tok, "labels": tok}
    if cfg.input_mode == "tokens+prefix":
        out["prefix"] = P(*lead, dp, None, None)
    if kind == "decode":
        out = {"tokens": P(*lead, dp, None)}
    return out


def cache_specs(cache_shapes, mesh, batch_size, participant=False):
    """Decode-cache specs: batch over data (when divisible), long dims over
    model; falls back gracefully for batch=1 (long_500k) by sharding the
    sequence/state dims over both axes where divisible."""
    dsz = mesh.shape.get("data", 1)
    msz = mesh.shape.get("model", 1)
    lead = ("pod",) if participant else ()
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape) \
        if not participant else ("data",)

    def one(path, v):
        shape = v.shape
        names = _path_names(path)
        off = len(lead)
        # layout: (repeats, B, ...) — repeats replicated
        spec = [None] * len(shape)
        for i, _ in enumerate(lead):
            spec[i] = lead[i]
        bdim = off + 1                           # after repeats dim
        rest = list(range(bdim + 1, len(shape)))
        b_ok = shape[bdim] % dsz == 0 and shape[bdim] > 1
        if b_ok:
            spec[bdim] = dp if len(dp) > 1 else dp[0]
        if names[-1] in ("k", "v") and len(shape) - off == 5:
            # GQA KV cache (R,B,S,KV,hd): never shard S over `model` — the
            # per-step single-slot update would move the whole cache
            # (§Perf cycle 3: 3.3s -> small collective term). Shard KV
            # heads if divisible, else head_dim; batch=1 long-context
            # spreads S over `data` (window ring, update stays local-ish).
            kv_dim, hd_dim = off + 3, off + 4
            if shape[kv_dim] % msz == 0:
                spec[kv_dim] = "model"
            elif shape[hd_dim] % msz == 0:
                spec[hd_dim] = "model"
            if not b_ok and shape[off + 2] % dsz == 0:
                spec[off + 2] = "data"
            return P(*spec)
        if b_ok:
            # shard the largest remaining dim over model
            cands = [i for i in rest if shape[i] % msz == 0 and shape[i] >= msz]
            if cands:
                big = max(cands, key=lambda i: shape[i])
                spec[big] = "model"
        else:
            # batch=1: spread the biggest dims over model then data
            cands = sorted(rest, key=lambda i: -shape[i])
            used = []
            for ax, sz in (("model", msz), ("data", dsz)):
                for i in cands:
                    if i not in used and shape[i] % sz == 0 and shape[i] >= sz:
                        spec[i] = ax
                        used.append(i)
                        break
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, v) for p, v in flat])


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
