"""Version-compat shims for the moving jax mesh / shard_map API surface.

The repo targets the *new* spellings (``jax.set_mesh``, ``jax.shard_map``
with ``check_vma``) but must run on the pinned toolchain image, whose
jax 0.4.37 predates both. Rationale for a dedicated module instead of
inline try/excepts: every mesh-entry and shard_map call site in the repo
(tests, dryrun, averaging, the fused round engine) goes through exactly
one shim each, so the day the image moves to jax>=0.6 the fallbacks are
deleted in one place and the call sites never change.

Resolution order:

``use_mesh(mesh)``
    1. ``jax.set_mesh``            (jax >= 0.6 context-manager form)
    2. ``jax.sharding.use_mesh``   (jax ~0.5 experimental spelling)
    3. the ``Mesh`` object itself  (jax <= 0.4.x: ``with mesh:``)

``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
    1. ``jax.shard_map``                         (keyword ``check_vma``)
    2. ``jax.experimental.shard_map.shard_map``  (keyword ``check_rep``)
"""
from __future__ import annotations

import jax


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh, on any jax."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    sharding_use_mesh = getattr(jax.sharding, "use_mesh", None)
    if sharding_use_mesh is not None:
        return sharding_use_mesh(mesh)
    # jax <= 0.4.x: Mesh is itself a context manager
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` with the old/new replication-check kwarg mapped.

    The kwarg is chosen by inspecting the resolved function's signature,
    not by where it lives: mid-range jax versions promoted ``jax.shard_map``
    while it still took ``check_rep``.
    """
    import inspect
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        params = inspect.signature(sm).parameters
        kwarg = "check_vma" if "check_vma" in params else "check_rep"
    except (TypeError, ValueError):
        kwarg = "check_vma"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kwarg: check_vma})
