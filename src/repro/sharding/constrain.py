"""In-model sharding hints that are safe without a mesh.

``constrain(x, spec)`` applies `with_sharding_constraint` where dims are
UNCONSTRAINED unless marked. Any named axis absent from the ambient abstract
mesh, or that does not divide the dim, is dropped — so model code stays
mesh-agnostic (tests run with no mesh at all; phi4's 24 heads on a model=16
axis simply fall back to unconstrained).

Markers:
  None  -> UNCONSTRAINED (leave to propagation)
  "r"   -> force replicated
  "dp"  -> the data-parallel axes, default ("pod","data"); the co-learning
           participant step narrows this to ("data",) via `batch_axes`
           because its vmap already consumes the pod axis
  name / tuple of names -> those mesh axes
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

U = P.UNCONSTRAINED
_CTX = threading.local()


def _dp_axes():
    return getattr(_CTX, "dp", ("pod", "data"))


@contextlib.contextmanager
def batch_axes(axes):
    """Override the axes 'dp' resolves to (trace-time context)."""
    prev = _dp_axes()
    _CTX.dp = tuple(axes)
    try:
        yield
    finally:
        _CTX.dp = prev


def _resolve(dim, ax, mesh, axes):
    if ax == "r":
        return None, True
    if ax == "dp":
        ax = _dp_axes()
    if isinstance(ax, str):
        ax = (ax,)
    present = tuple(a for a in ax if a in axes)
    # drop leading axes until the product divides the dim
    while present:
        prod = 1
        for a in present:
            prod *= mesh.shape[a]
        if dim % prod == 0 and prod > 1:
            return (present if len(present) > 1 else present[0]), True
        present = present[1:]
    return U, False


def constrain(x, spec):
    try:
        mesh = jax.sharding.get_abstract_mesh()
        axes = set(mesh.axis_names)
    except Exception:
        return x
    if not axes:
        return x
    out = []
    changed = False
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            out.append(U)
            continue
        r, ch = _resolve(dim, ax, mesh, axes)
        out.append(r)
        changed |= ch
    if not changed:
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))
