"""Step builders + ShapeDtypeStruct input specs for every (arch × shape).

``long_500k`` policy (DESIGN.md §4): SSM/hybrid run natively; DeepSeek's MLA
latent cache is ~0.6 GB at 524k so it also runs natively (the latent *is*
the compression); pure full-attention dense/vlm/audio archs switch to the
first-class sliding-window variant (window 4096).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape
from repro.models import transformer as tr
from repro.optim.optimizers import apply_updates, get_optimizer

LONG_WINDOW = 4096
# families whose long-context decode needs the SWA carve-in
SWA_AT_500K = {"dense", "vlm", "audio"}


def config_for_shape(cfg, shape: InputShape):
    """Apply per-shape config adjustments (the SWA carve-in)."""
    if shape.name == "long_500k" and cfg.family in SWA_AT_500K:
        return cfg.with_(window=LONG_WINDOW)
    return cfg


def params_shapes(cfg, dtype=jnp.bfloat16):
    """Abstract (ShapeDtypeStruct) params — no allocation."""
    return jax.eval_shape(
        lambda k: tr.init_params(k, cfg, dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def cache_shapes(cfg, batch, seq_len, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(tr.init_cache, cfg, batch, seq_len, dtype))


def input_specs(cfg, shape: InputShape, participants: int = 0,
                dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for the step's data inputs.

    train/prefill -> batch dict; decode -> (cache, token, pos).
    participants > 0 stacks a leading K dim (co-learning variant).
    """
    B, S = shape.global_batch, shape.seq_len
    lead = (participants,) if participants else ()
    if participants:
        assert B % participants == 0
        B = B // participants

    if shape.kind in ("train", "prefill"):
        S_tok = S - (cfg.prefix_len if cfg.input_mode == "tokens+prefix" else 0)
        batch = {"tokens": jax.ShapeDtypeStruct((*lead, B, S_tok), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((*lead, B, S), jnp.int32)}
        if cfg.input_mode == "tokens+prefix":
            batch["prefix"] = jax.ShapeDtypeStruct(
                (*lead, B, cfg.prefix_len, cfg.d_model), dtype)
        return batch

    cache = cache_shapes(cfg, B, S, dtype)
    if participants:
        cache = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct((participants, *v.shape), v.dtype),
            cache)
    token = jax.ShapeDtypeStruct((*lead, B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"cache": cache, "token": token, "pos": pos}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------
def make_train_step(cfg, optimizer="sgd", lr=0.01, lowering="scan",
                    impl="ref", remat=True, microbatch=1):
    """Paper-faithful local step: SGD on the LM loss.

    (params, batch) -> (params, loss). microbatch>1 scans over gradient-
    accumulation slices of the global batch (numerically identical SGD step,
    M× lower activation memory — the production memory knob)."""
    opt = get_optimizer(optimizer)

    def grad_of(params, b):
        return jax.value_and_grad(
            lambda p: tr.loss_fn(p, cfg, b, lowering, impl, remat),
            has_aux=True)(params)

    def train_step(params, batch):
        if microbatch > 1:
            mb = jax.tree.map(
                lambda t: t.reshape(microbatch, t.shape[0] // microbatch,
                                    *t.shape[1:]), batch)

            def acc(g, b):
                (loss, _), gi = grad_of(params, b)
                return jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g, gi), loss

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            grads, losses = jax.lax.scan(acc, g0, mb)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = losses.mean()
        else:
            (loss, _), grads = grad_of(params, batch)
        upd, _ = opt.update(grads, opt.init(params), params, lr)
        return apply_updates(params, upd), loss

    return train_step


def make_colearn_train_step(cfg, **kw):
    """One local step for every participant: vmapped over the leading K dim,
    pinned to the `pod` mesh axis so gradient reductions stay intra-pod."""
    from repro.sharding.constrain import batch_axes
    step = make_train_step(cfg, **kw)
    vstep = jax.vmap(step, spmd_axis_name="pod")

    def wrapped(params, batch):
        # the vmap consumes the pod axis; in-model "dp" hints must not
        with batch_axes(("data",)):
            return vstep(params, batch)
    return wrapped


def make_average_step():
    """Eq. 2 over the leading participant dim (all-reduce over `pod`)."""
    from repro.core.averaging import average_pjit
    return average_pjit


def make_fused_round_step(cfg, ccfg, *, optimizer="sgd", lowering="scan",
                          impl="ref", remat=True, mesh=None,
                          param_specs=None, codec=None, aggregator=None,
                          schedule=None, round_index=0,
                          expose_schedule_args=False, masked=False,
                          live=False, compress=None, compress_block=256,
                          compress_impl="ref", codec_bits=8,
                          error_feedback=False):
    """Pod-path fused round: the whole communication round as one program.

    Shares ``repro.core.engine`` with the simulation path, but pins the
    participant vmap to the ``pod`` mesh axis (``spmd_axis_name``) and — when
    ``mesh``/``param_specs`` are given — Eq. 2 to an explicit shard_map psum
    over that axis instead of an inferred all-reduce.

    codec / aggregator / schedule take ``repro.core.api`` strategy objects
    or registry names (schedule=None resolves ``ccfg.schedule``). Under
    ``FullAverage`` (the default) the codec keeps its pod fast path:
    ``FlatFusedInt8`` runs each pod's int8 roundtrip locally and ONE psum
    over the ``pod`` axis aggregates the dequantized block payloads of one
    contiguous buffer, instead of L per-leaf collectives; ``LeafwiseInt8``
    keeps the per-leaf reference roundtrip in front of the shard_map
    average. ``compress=None|"leafwise"|"fused"`` remains the legacy
    spelling of the codec choice (mutually exclusive with codec=).

    The schedule rides into the engine as traced data (``lr_fn`` +
    parameter pack, see ``repro.core.engine``). By default this step
    closes the pack for ``round_index`` plus the static ``T0 * max_rounds``
    budget over the returned fn as baked constants — the compact
    signature below, right for compile-oriented callers (the dry-run) and
    for constant-η schedules, but a schedule whose parameters move per
    round (warmup, policy-aware budget) would be frozen at ``round_index``.
    A driver stepping many rounds should instead pass
    ``expose_schedule_args=True`` and feed
    ``schedule.device_round_params(i)`` + the budget per round: the same
    ONE compiled executable serves every round (do NOT rebuild this step
    per round — each build returns a fresh ``jax.jit`` with an empty
    cache, i.e. a full recompile).

    Returns round_fn(stacked_params, opt_state, batches, global_epoch0)
    for weight-free aggregators (Eq. 2), or round_fn(..., agg_weights) when
    the aggregator mixes with a per-round (K, K) matrix (partial
    participation / gossip — build it with ``aggregator.mixing_matrix``).
    With ``expose_schedule_args=True`` the signature grows to
    round_fn(stacked_params, opt_state, batches, global_epoch0, sched,
    total_epochs[, agg_weights]) with ``sched``/``total_epochs`` traced.
    ``batches`` is the (T_i, K, n_batches, ...) stacked-epoch batch dict.

    ``masked=True`` (ragged shards — unequal per-pod batch counts): the
    returned round_fn takes a traced (K, n_batches) bool ``batch_mask``
    right after ``batches`` (``ParticipantData.batch_mask``; masked epoch
    steps are identity carries, see ``repro.core.engine``).

    ``live=True`` (elastic membership): the returned round_fn additionally
    takes a traced (K,) float ``live_row`` right after ``batch_mask`` (or
    right after ``batches`` when not masked). Dead pods identity-carry
    through the local epochs AND the aggregation, and the aggregate fn is
    built ``dynamic`` so the per-round mixing matrix renormalizes over the
    live set (``Membership.live_mask()`` feeds both the row and
    ``aggregator.mixing_matrix(..., live=...)``). Membership changes ride
    in as data — the compiled executable is reused across churn.

    ``codec_bits``/``error_feedback`` parameterize the quantizing codecs
    (registry-name or legacy ``compress=`` spellings): payload bit width
    in {8, 4, 1} and error-feedback residual memory. An error-feedback
    codec is STATEFUL, and so is a stateful aggregator (``"d2"``'s
    variance-reduction correction) — the returned round_fn then takes the
    (K,)-leading round-state pytree right after ``opt_state``
    (``aggregator.init_round_state(codec, stacked)`` builds the zero
    state; the pod paths keep each pod's rows resident on that pod) and
    its aux dict grows ``{"residual": new_state}``.
    """
    from repro.core import api, engine as engine_mod
    from repro.optim.optimizers import get_optimizer as _get_opt
    from repro.sharding.constrain import batch_axes

    def loss_fn(params, batch):
        return tr.loss_fn(params, cfg, batch, lowering, impl, remat)

    if compress is not None:
        if codec is not None:
            raise ValueError("pass codec= or the legacy compress=, not both")
        if compress not in ("leafwise", "fused"):
            raise ValueError(f"unknown compress {compress!r}")
        codec = compress
    codec = api.get_codec(codec, block=compress_block, impl=compress_impl,
                          bits=codec_bits, error_feedback=error_feedback)
    aggregator = api.get_aggregator(aggregator)
    # the round is stateful when either side carries per-participant
    # memory: the codec's EF residual and/or the aggregator's state
    # (D² correction) — one slot, one plumbing
    stateful = (getattr(codec, "stateful", False)
                or getattr(aggregator, "stateful", False))
    schedule = api.get_schedule(schedule, ccfg)
    aggregate_fn = aggregator.make_aggregate_fn(
        codec, mesh=mesh, param_specs=param_specs, dynamic=live)

    fused = engine_mod.make_fused_round(
        loss_fn, _get_opt(optimizer), lr_fn=api.traced_body(schedule),
        spmd_axis_name="pod", aggregate_fn=aggregate_fn, masked=masked,
        live=live, stateful=stateful, donate=False)

    # the engine's vmap consumes the pod axis; in-model "dp" hints must
    # then resolve to data only (same contract as the colearn step)
    if expose_schedule_args:
        def round_fn(stacked_params, opt_state, *rest):
            """round_fn(params, opt[, residual], batches[, batch_mask]
            [, live_row], ge0, sched, total_epochs[, agg_weights]) — the
            bracketed args appear per the step's error_feedback=/masked=/
            live= flags and the aggregator's uses_weights."""
            with batch_axes(("data",)):
                return fused(stacked_params, opt_state, *rest)
        return round_fn

    sched = schedule.device_round_params(round_index)
    total = jnp.int32(max(ccfg.T0 * ccfg.max_rounds, 1))
    # (residual?, batches, batch_mask?, live_row?, ge0) lead the varargs;
    # agg_weights trails. The baked sched/total pair splices in between —
    # one wrapper covers every stateful × masked × live × uses_weights
    # combination.
    n_lead = 2 + int(stateful) + int(masked) + int(live)

    def round_fn(stacked_params, opt_state, *rest):
        """round_fn(params, opt[, residual], batches[, batch_mask]
        [, live_row], ge0[, agg_weights]) — bracketed args per
        error_feedback=/masked=/live=/uses_weights."""
        lead, tail = rest[:n_lead], rest[n_lead:]
        with batch_axes(("data",)):
            return fused(stacked_params, opt_state,
                         *lead, sched, total, *tail)
    return round_fn


def make_prefill_step(cfg, lowering="scan", impl="ref"):
    def prefill_step(params, batch):
        return tr.prefill(params, cfg, batch, lowering, impl)
    return prefill_step


def make_serve_step(cfg, lowering="scan"):
    def serve_step(params, cache, token, pos):
        return tr.decode_step(params, cfg, cache, token, pos, lowering)
    return serve_step
