"""Analytic FLOPs/params model for the roofline report.

Two uses:
  1. MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) — the "useful compute"
     numerator of the roofline ratio row;
  2. scan-region corrections: XLA `cost_analysis()` counts a `lax.scan`
     body ONCE (measured; see EXPERIMENTS.md §Dry-run). The dry-run unrolls
     the *layer* loop, but chunked attention and the SSM/xLSTM recurrences
     keep inner scans, so their compute is undercounted by (trip-1)/trip.
     We correct with analytic per-region FLOPs and the known trip counts —
     derived from the same compiled HLO structure, not a guess.
"""
from __future__ import annotations

import jax

from repro.launch import steps as steps_mod

CHUNK_Q = 1024
CHUNK_KV = 1024


def param_counts(cfg):
    """(total, active) parameter counts, exact from abstract shapes."""
    shapes = steps_mod.params_shapes(cfg)
    total = sum(v.size for v in jax.tree.leaves(shapes))
    # inactive = routed-expert params beyond top_k, per MoE layer
    inactive = 0
    if cfg.n_experts:
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        n_moe_layers = sum(1 for k in cfg.layer_kinds() if k.endswith(":moe")
                           or k.endswith(":moe_dense"))
        inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total, total - inactive


def _attn_layers(cfg):
    kinds = [k.split(":")[0] for k in cfg.layer_kinds()]
    return {m: sum(1 for k in kinds if k == m)
            for m in ("gqa", "mla", "mamba", "mlstm", "slstm")}


def model_flops(cfg, shape, kind):
    """6·N_active·D (+ attention quadratic term) for the ratio row."""
    _, active = param_counts(cfg)
    n = _attn_layers(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6 * active * tokens
        mult = 3  # fwd+bwd
    elif kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2 * active * tokens
        mult = 1
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        base = 2 * active * tokens
        mult = 1
    S_kv = (min(cfg.window, shape.seq_len) if cfg.window else shape.seq_len)
    if kind == "decode":
        attn_ctx = S_kv
    else:
        attn_ctx = S_kv / 2 if not cfg.window else min(S_kv, shape.seq_len / 2)
    hd_qk = cfg.head_dim
    hd_v = cfg.v_head_dim or cfg.head_dim
    if n["mla"]:
        hd_qk = cfg.kv_lora_rank + cfg.qk_rope_dim   # absorbed form
        hd_v = cfg.kv_lora_rank
    attn = 2 * tokens * attn_ctx * cfg.n_heads * (hd_qk + hd_v) * \
        (n["gqa"] + n["mla"]) * mult
    return base + attn


def scan_corrections(cfg, shape, kind):
    """FLOPs missed by once-counted inner scans, per compiled module."""
    if kind == "decode":
        return 0.0                                   # no inner scans at decode
    T = shape.global_batch * shape.seq_len
    S = shape.seq_len
    mult = 3 if kind == "train" else 1
    n = _attn_layers(cfg)
    missed = 0.0
    # chunked attention: trips = nq*nk (both scans), counted once
    S_kv = min(cfg.window, S) if cfg.window else S
    nq = max(S // CHUNK_Q, 1)
    nk = max(S_kv // CHUNK_KV, 1)
    trips = nq * nk
    if trips > 1 and (n["gqa"] or n["mla"]):
        hd_qk = cfg.head_dim
        hd_v = cfg.v_head_dim or cfg.head_dim
        if n["mla"]:
            hd_qk = cfg.kv_lora_rank + cfg.qk_rope_dim
            hd_v = cfg.kv_lora_rank
        ctx = S_kv / 2 if not cfg.window else min(S_kv, S / 2)
        attn = 2 * T * ctx * cfg.n_heads * (hd_qk + hd_v) * \
            (n["gqa"] + n["mla"]) * mult
        missed += attn * (trips - 1) / trips
    # mamba selective scan: ~10 flops per (t, di, st) cell, trips = S
    if n["mamba"]:
        di, st = cfg.d_inner_ssm, cfg.ssm_state_dim
        scan_f = 10 * T * di * st * n["mamba"] * mult
        missed += scan_f * (S - 1) / S
    # mLSTM: rank-1 update + readout ≈ 6·hd² per (t, head), trips = S
    if n["mlstm"]:
        di = int(cfg.xlstm_proj_factor * cfg.d_model)
        hd = di // cfg.n_heads
        f = 6 * T * cfg.n_heads * hd * hd * n["mlstm"] * mult
        missed += f * (S - 1) / S
    # sLSTM: recurrent matmul hd×4hd per (t, head), trips = S
    if n["slstm"]:
        hd = cfg.d_model // cfg.n_heads
        f = 2 * T * cfg.n_heads * hd * 4 * hd * n["slstm"] * mult
        missed += f * (S - 1) / S
    return missed
