import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh ×
variant) against the production meshes.

Two phases per combination (see EXPERIMENTS.md §Dry-run for why):
  * PROOF — the full config, scan-over-layers lowering, per-layer remat,
    gradient accumulation: proves the production program compiles and fits
    (memory_analysis) on the target mesh.
  * PROFILE — XLA cost_analysis counts a scan body once (measured), so for
    accurate roofline terms we compile reduced-depth *unrolled* variants
    (segment repeats 1, then 1+1 per segment) and difference them: per-layer
    flops/bytes/collective-bytes × true layer counts + the outside-the-loop
    cost. Intra-layer chunk scans (attention) are corrected analytically
    (launch/analytic.py).

Usage:  python -m repro.launch.dryrun [--arch ID|all] [--shape NAME|all]
        [--mesh single|multi|both] [--out artifacts/dryrun] [--no-profile]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch import analytic, steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.sharding import compat, specs as sp

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\]\S*)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _shape_bytes(s):
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text):
    """Per-device link-byte estimates (ring model) from post-SPMD HLO."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_s, op = m.group(1), m.group(2).replace("-start", "")
        nbytes = _shape_bytes(shape_s)
        g, stride, span = 1, 0, 0
        gm = _GROUPS_RE.search(line)
        if gm:
            ids = [int(x) for x in gm.group(1).split(",")]
            g = len(ids)
            stride = ids[1] - ids[0] if g > 1 else 0
            span = max(ids) - min(ids)
        else:
            im = _IOTA_RE.search(line)
            if im:
                # iota groups: arange(n).reshape(dims)[.transpose(perm)]
                # .reshape(G, S) — compute the true member span of group 0
                import numpy as np
                G, S = int(im.group(1)), int(im.group(2))
                dims = [int(d) for d in im.group(3).split(",")]
                ids = np.arange(int(np.prod(dims))).reshape(dims)
                if im.group(4):
                    ids = ids.transpose([int(p) for p in im.group(4).split(",")])
                row = ids.reshape(G, S)[0]
                g = S
                stride = int(row[1] - row[0]) if S > 1 else 0
                span = int(row.max() - row.min())
        if g <= 1:
            continue
        if op == "all-gather":
            link = nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            link = nbytes * (g - 1)
        elif op == "all-reduce":
            link = 2 * nbytes * (g - 1) / g
        elif op == "all-to-all":
            link = nbytes * (g - 1) / g
        else:                                    # collective-permute
            link = nbytes
        out.append({"op": op, "link_bytes": link, "group": g,
                    "span": span})
    return out


def coll_summary(colls, multi_pod):
    by_op = {}
    for c in colls:
        by_op[c["op"]] = by_op.get(c["op"], 0.0) + c["link_bytes"]
    return {"n_ops": len(colls),
            "link_bytes": sum(c["link_bytes"] for c in colls),
            "cross_pod_link_bytes":
                sum(c["link_bytes"] for c in colls if c["span"] >= 256)
                if multi_pod else 0.0,
            "by_op": by_op}


def _microbatch(shape):
    if shape.kind != "train":
        return 1
    tokens = shape.global_batch * shape.seq_len
    m = max(1, tokens // (32 * 8192))            # ~8k tokens/device/microbatch
    while shape.global_batch % m:
        m -= 1
    return m


# ---------------------------------------------------------------------------
def build(cfg, shape, mesh, multi_pod, variant, lowering):
    """Returns (jitted_fn, abstract args)."""
    pshapes = steps_mod.params_shapes(cfg)
    K = mesh.shape.get("pod", 1)
    participant = (variant in ("train_colearn", "average", "round_colearn")
                   and multi_pod)

    if participant:
        pshapes = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct((K, *v.shape), v.dtype), pshapes)
    psh = sp.named(mesh, sp.param_specs(pshapes, cfg, mesh,
                                        participant=participant))

    if variant in ("train_vanilla", "train_colearn"):
        data = steps_mod.input_specs(cfg, shape,
                                     participants=K if participant else 0)
        bspecs = sp.named(mesh, sp.batch_specs(cfg, mesh, "train", participant))
        mb = _microbatch(shape)
        step = (steps_mod.make_colearn_train_step(cfg, lowering=lowering,
                                                  microbatch=mb)
                if participant else
                steps_mod.make_train_step(cfg, lowering=lowering,
                                          microbatch=mb))
        fn = jax.jit(step, in_shardings=(psh, bspecs),
                     out_shardings=(psh, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
        return fn, (pshapes, data)

    if variant == "average":
        fn = jax.jit(steps_mod.make_average_step(),
                     in_shardings=(psh,), out_shardings=psh,
                     donate_argnums=(0,))
        return fn, (pshapes,)

    if variant == "round_colearn":
        # fused round engine on the pod mesh: T_dry-epoch scan + shard_map
        # Eq. 2 + on-device Eq. 4, compiled as ONE program. T_dry=2 and one
        # batch per epoch keep the compile bounded while still exercising
        # the epoch scan (the real T_i only changes scan trip count).
        from repro.configs.base import CoLearnConfig
        T_dry, n_b = 2, 1
        data = steps_mod.input_specs(cfg, shape, participants=K)
        data = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(
                (T_dry, v.shape[0], n_b, *v.shape[1:]), v.dtype), data)
        bspecs = sp.batch_specs(cfg, mesh, "train", participant=True)
        rspecs = jax.tree.map(lambda s: P(None, *s[:1], None, *s[1:]),
                              bspecs, is_leaf=lambda x: isinstance(x, P))
        rbsh = sp.named(mesh, rspecs)
        ccfg = CoLearnConfig(n_participants=K, T0=T_dry, max_rounds=1)
        round_fn = steps_mod.make_fused_round_step(
            cfg, ccfg, lowering=lowering, mesh=mesh,
            param_specs=sp.param_specs(pshapes, cfg, mesh, participant=True))
        fn = jax.jit(round_fn,
                     in_shardings=(psh, (), rbsh, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
        return fn, (pshapes, (), data, jax.ShapeDtypeStruct((), jnp.int32))

    if variant == "prefill":
        data = steps_mod.input_specs(cfg, shape)
        bspecs = sp.named(mesh, sp.batch_specs(cfg, mesh, "train"))
        fn = jax.jit(steps_mod.make_prefill_step(cfg, lowering=lowering),
                     in_shardings=(psh, bspecs))
        return fn, (pshapes, data)

    # serve (decode)
    data = steps_mod.input_specs(cfg, shape)
    cspecs = sp.named(mesh, sp.cache_specs(data["cache"], mesh,
                                           shape.global_batch))
    dp_n = 512 if multi_pod else 256
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    b_spec = dp if shape.global_batch % (32 if multi_pod else 16) == 0 else None
    tok_sh = NamedSharding(mesh, P(b_spec, None))
    fn = jax.jit(steps_mod.make_serve_step(cfg, lowering=lowering),
                 in_shardings=(psh, cspecs, tok_sh, NamedSharding(mesh, P())),
                 donate_argnums=(1,))
    return fn, (pshapes, data["cache"], data["token"], data["pos"])


def _compile(cfg, shape, mesh, multi_pod, variant, lowering):
    fn, args = build(cfg, shape, mesh, multi_pod, variant, lowering)
    with compat.use_mesh(mesh):
        compiled = fn.lower(*args).compile()
    return compiled


def _costs(compiled, multi_pod):
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    cs = coll_summary(colls, multi_pod)
    return {"flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "link_bytes": cs["link_bytes"],
            "cross_pod_link_bytes": cs["cross_pod_link_bytes"],
            "by_op": cs["by_op"], "n_coll": cs["n_ops"]}


def _reduced(cfg, repeats):
    segs = tuple((pat, r) for (pat, _), r in zip(cfg.segments, repeats))
    n = sum(len(p) * r for p, r in segs)
    return cfg.with_(n_layers=n, segments=segs)


def profile_costs(cfg, shape, mesh, multi_pod, variant):
    """Depth-differenced per-layer costs extrapolated to full depth."""
    n_seg = len(cfg.segments)
    base_r = [1] * n_seg
    t0 = time.time()
    c_base = _costs(_compile(_reduced(cfg, base_r), shape, mesh, multi_pod,
                             variant, "unroll"), multi_pod)
    deltas = []
    for s in range(n_seg):
        r = list(base_r)
        r[s] += 1
        c_s = _costs(_compile(_reduced(cfg, r), shape, mesh, multi_pod,
                              variant, "unroll"), multi_pod)
        deltas.append({k: (c_s[k] - c_base[k]) if not isinstance(c_base[k], dict)
                       else {o: c_s[k].get(o, 0) - c_base[k].get(o, 0)
                             for o in set(c_base[k]) | set(c_s[k])}
                       for k in c_base})
    full = {}
    for k in ("flops", "bytes", "link_bytes", "cross_pod_link_bytes"):
        full[k] = c_base[k] + sum(
            max(d[k], 0.0) * (R - 1)
            for d, (_, R) in zip(deltas, cfg.segments))
    full["by_op"] = {
        o: c_base["by_op"].get(o, 0.0) + sum(
            max(d["by_op"].get(o, 0.0), 0.0) * (R - 1)
            for d, (_, R) in zip(deltas, cfg.segments))
        for o in set().union(c_base["by_op"],
                             *[d["by_op"] for d in deltas])}
    full["profile_s"] = round(time.time() - t0, 1)
    full["per_layer"] = deltas
    full["outside"] = c_base
    return full


def run_one(arch, shape_name, mesh_kind, variant, profile=True):
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = INPUT_SHAPES[shape_name]
    cfg = steps_mod.config_for_shape(get_config(arch), shape)
    t0 = time.time()
    compiled = _compile(cfg, shape, mesh, multi_pod, variant, "scan")
    t1 = time.time()
    ma = compiled.memory_analysis()
    total_p, active_p = analytic.param_counts(cfg)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "compile_s": round(t1 - t0, 1),
        "n_devices": int(len(mesh.devices.flat)),
        "microbatch": _microbatch(shape) if "train" in variant else 1,
        "params_total": int(total_p), "params_active": int(active_p),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "scan_raw_cost": _costs(compiled, multi_pod),
        "analytic": {
            "model_flops": analytic.model_flops(cfg, shape, shape.kind)
            if variant not in ("average", "round_colearn") else 0.0,
            "scan_correction_flops":
                analytic.scan_corrections(cfg, shape, shape.kind)
                if variant not in ("average", "round_colearn") else 0.0,
        },
    }
    del compiled
    if profile and variant not in ("average", "round_colearn"):
        rec["profile"] = profile_costs(cfg, shape, mesh, multi_pod, variant)
    return rec


VARIANTS = {
    "train": {"single": ["train_vanilla"],
              "multi": ["train_vanilla", "train_colearn", "average",
                        "round_colearn"]},
    "prefill": {"single": ["prefill"], "multi": ["prefill"]},
    "decode": {"single": ["serve"], "multi": ["serve"]},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-profile", action="store_true")
    ap.add_argument("--profile-meshes", default="single",
                    help="comma list of meshes to run the profile phase on")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    prof_meshes = set(args.profile_meshes.split(","))
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            kind = INPUT_SHAPES[shape_name].kind
            for mesh_kind in meshes:
                for variant in VARIANTS[kind][mesh_kind]:
                    tag = f"{arch}__{shape_name}__{mesh_kind}__{variant}"
                    path = os.path.join(args.out, tag + ".json")
                    if os.path.exists(path):
                        print(f"[skip cached] {tag}", flush=True)
                        n_ok += 1
                        continue
                    try:
                        rec = run_one(arch, shape_name, mesh_kind, variant,
                                      profile=(not args.no_profile and
                                               mesh_kind in prof_meshes))
                        with open(path, "w") as f:
                            json.dump(rec, f, indent=1)
                        pk = rec["memory"]["peak_bytes_per_device"] / 2 ** 30
                        fl = rec.get("profile", rec["scan_raw_cost"])["flops"]
                        print(f"[ok {rec['compile_s']:6.1f}s] {tag} "
                              f"flops/dev={fl:.3e} peak={pk:.2f}GiB",
                              flush=True)
                        n_ok += 1
                    except Exception as e:
                        n_fail += 1
                        with open(path + ".fail", "w") as f:
                            f.write(traceback.format_exc())
                        print(f"[FAIL] {tag}: {type(e).__name__}: {e}",
                              flush=True)
    print(f"dry-run done: {n_ok} ok, {n_fail} failed", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
