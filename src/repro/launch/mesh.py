"""Mesh construction. Functions only — importing this module never touches
jax device state (jax locks the device count on first real init)."""
from __future__ import annotations

import numpy as np

SINGLE_POD = (16, 16)                       # 256 chips (TPU v5e pod)
MULTI_POD = (2, 16, 16)                     # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    """(pod, data, model) = (2,16,16) or (data, model) = (16,16).

    Uses the first prod(shape) devices so it works inside the 512-device
    dry-run process for both mesh sizes.
    """
    import jax
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for {axes}={shape}, have {len(devs)} "
            "(run under XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_sim_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small virtual mesh for CPU tests (e.g. 8 forced host devices)."""
    import jax
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_host_mesh():
    """Trivial 1-device mesh for smoke-scale runs."""
    import jax
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
