"""Batched KV-cache decode driver (CPU-scale serving of a reduced model).

Thin CLI over :class:`repro.serving.ServeLoop` — prefills a batch of
prompts then greedily decodes through the loop's single jitted step.
``launch/continuous.py`` drives the same loop interleaved with training.

Usage: PYTHONPATH=src python -m repro.launch.serve --arch jamba-v0.1-52b \
           --batch 4 --prompt-len 16 --new-tokens 24
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import transformer as tr
from repro.serving import ServeLoop


def prefill_into_cache(loop: ServeLoop, tokens):
    """Sequential prefill through the loop's jitted step (one compiled
    executable reused per position — not the eager per-token dispatch
    this driver used to pay)."""
    return loop.prefill(tokens)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.max_seq < args.prompt_len + args.new_tokens:
        ap.error(f"--max-seq {args.max_seq} < --prompt-len {args.prompt_len}"
                 f" + --new-tokens {args.new_tokens}: decode would index "
                 "past the KV cache")

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = tr.init_params(key, cfg, jnp.float32)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    loop = ServeLoop(cfg, params, batch=args.batch, max_seq=args.max_seq)
    gen, stats = loop.generate(prompts, args.new_tokens)
    print(f"{cfg.name}: prefill {args.prompt_len} tok in "
          f"{stats['prefill_s']:.2f}s, decoded {args.new_tokens} tok in "
          f"{stats['decode_s']:.2f}s ({stats['tokens_per_s']:.1f} tok/s "
          f"batch={args.batch}, {stats['compile_count']} compile)")
    print("generated[0]:", gen[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
