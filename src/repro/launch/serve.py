"""Batched KV-cache decode driver (CPU-scale serving of a reduced model).

Prefills a batch of prompts then greedily decodes, exercising the same
serve_step the dry-run lowers at production shapes.

Usage: PYTHONPATH=src python -m repro.launch.serve --arch jamba-v0.1-52b \
           --batch 4 --prompt-len 16 --new-tokens 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import transformer as tr


def prefill_into_cache(params, cfg, tokens, cache):
    """Sequential prefill via serve_step (token-by-token; CPU-scale)."""
    logits = None
    for t in range(tokens.shape[1]):
        logits, cache = tr.decode_step(params, cfg, cache,
                                       tokens[:, t:t + 1], jnp.int32(t))
    return logits, cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = tr.init_params(key, cfg, jnp.float32)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    cache = tr.init_cache(cfg, args.batch, args.max_seq, jnp.float32)

    step = jax.jit(lambda p, c, t, i: tr.decode_step(p, cfg, c, t, i))
    t0 = time.time()
    logits, cache = prefill_into_cache(params, cfg, prompts, cache)
    t1 = time.time()
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(args.new_tokens):
        out.append(tok)
        logits, cache = step(params, cache, tok,
                             jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    gen = jnp.concatenate(out, axis=1)
    t2 = time.time()
    print(f"{cfg.name}: prefill {args.prompt_len} tok in {t1-t0:.2f}s, "
          f"decoded {args.new_tokens} tok in {t2-t1:.2f}s "
          f"({args.batch*args.new_tokens/(t2-t1):.1f} tok/s batch={args.batch})")
    print("generated[0]:", gen[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
