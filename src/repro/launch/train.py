"""End-to-end co-learning training driver (CPU-scale, real training).

Trains a reduced-config model of any assigned architecture with the paper's
Algorithm 1 on synthetic-LM shards split across K participants, logging
per-round losses, the Eq.4 controller decisions, and communication volume.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --participants 5 --rounds 6 --t0 2 --steps-per-epoch 8
  ... --vanilla     # centralized baseline (same total data, K=1)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_round_state
from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.colearn import CoLearner
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr


def build_data(cfg, K, batch_size, seq_len, n_examples, seed=0):
    x, y = lm_examples(seed, n_examples, seq_len, cfg.vocab_size)
    shards = partition_arrays([x, y], K, seed)
    return ParticipantData(shards, batch_size, seed)


# Module-level so every eval batch reuses one compiled executable; a
# jax.jit created inside the loop is a fresh wrapper (and retrace) per batch.
_eval_loss_step = jax.jit(tr.loss_fn, static_argnums=(1,))


def eval_loss(params, cfg, x, y, batch=64):
    tot, n = 0.0, 0
    for i in range(0, len(x) - batch + 1, batch):
        b = {"tokens": jnp.asarray(x[i:i + batch]),
             "labels": jnp.asarray(y[i:i + batch])}
        loss, _ = _eval_loss_step(params, cfg, b)
        tot += float(loss) * batch
        n += batch
    return tot / max(n, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--participants", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--t0", type=int, default=2)
    ap.add_argument("--eta0", type=float, default=0.01)
    ap.add_argument("--epsilon", type=float, default=0.05)
    ap.add_argument("--schedule", default="clr", choices=["clr", "elr"])
    ap.add_argument("--epochs-rule", default="ile", choices=["ile", "fle"])
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--n-examples", type=int, default=1280)
    ap.add_argument("--steps-per-epoch", type=int, default=0,
                    help="truncate each epoch to this many batches (0=full)")
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "fused"],
                    help="Eq. 2 upload emulation: int8 = leafwise "
                         "quantize-roundtrip; fused = flat-buffer wire "
                         "codec (one quant->avg->dequant kernel pass)")
    ap.add_argument("--engine", default="fused", choices=["fused", "python"],
                    help="round engine: fused = one executable per round "
                         "(repro.core.engine); python = reference loop")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    K = args.participants
    ccfg = CoLearnConfig(
        n_participants=K, T0=args.t0, eta0=args.eta0, epsilon=args.epsilon,
        schedule=args.schedule, epochs_rule=args.epochs_rule,
        max_rounds=args.rounds, compress=args.compress)

    data = build_data(cfg, K, args.batch_size, args.seq_len,
                      args.n_examples, args.seed)
    ex, ey = lm_examples(args.seed + 99, 256, args.seq_len, cfg.vocab_size)

    def loss_fn(params, batch):
        x, y = batch
        return tr.loss_fn(params, cfg, {"tokens": x, "labels": y})

    learner = CoLearner(ccfg, loss_fn, optimizer_name=args.optimizer,
                        compress={"int8": "leafwise", "fused": "fused",
                                  "none": None}[args.compress],
                        engine=args.engine)
    params = tr.init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32)
    state = learner.init(params)
    print(f"co-learning {cfg.name}: K={K} params="
          f"{tr.count_params(params):,} rounds={args.rounds} T0={args.t0} "
          f"{args.schedule}+{args.epochs_rule} engine={args.engine}",
          flush=True)

    for i in range(args.rounds):
        t0 = time.time()

        def epoch_batches(round_i, epoch_j):
            bx, by = data.epoch_batches(round_i, epoch_j)
            if args.steps_per_epoch:
                bx, by = bx[:, :args.steps_per_epoch], by[:, :args.steps_per_epoch]
            return (jnp.asarray(bx), jnp.asarray(by))

        state = learner.run_round(state, epoch_batches)
        log = state["log"][-1]
        ev = eval_loss(learner.shared_model(state), cfg, ex, ey)
        print(f"round {log.round}: T={log.T} lr {log.lr_first:.4f}->"
              f"{log.lr_last:.4f} rel_dw={log.rel_change:.4f} "
              f"local_loss={np.mean(log.local_losses):.4f} eval={ev:.4f} "
              f"comm={log.comm_bytes/2**20:.1f}MiB next_T={state['ctrl'].T} "
              f"({time.time()-t0:.1f}s)", flush=True)

    if args.checkpoint:
        save_round_state(args.checkpoint, state)
        print(f"saved {args.checkpoint}.params.npz")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
