"""End-to-end co-learning training driver (CPU-scale, real training).

Trains a reduced-config model of any assigned architecture with the paper's
Algorithm 1 on synthetic-LM shards split across K participants, logging
per-round losses, the Eq.4 controller decisions, and communication volume.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --participants 5 --rounds 6 --t0 2 --steps-per-epoch 8
  ... --vanilla     # centralized baseline (same total data, K=1)

Round strategy (see repro.core.api): --codec picks the wire format of the
uploads (exact f32 | leafwise int8 | fused flat-buffer), --aggregator picks
who averages what (full Eq. 2 | FedAvg-style partial participation with
--partial-m sampled uploads per round | ring gossip | graph gossip over an
arbitrary --topology sparse graph | d2 graph gossip with the D² non-IID
correction), --engine picks the round executor, --lr-schedule the Eq. 3
family member (clr | elr |
warmup_clr | cosine; defaults to the legacy --schedule flag), and
--sync-policy the Eq. 4 rule (ile | fle | divtrigger with --trigger-delta;
defaults to the legacy --epochs-rule flag). --compress remains the legacy
spelling of --codec, resolved through the api.CODECS registry aliases.

Data scenario (see repro.data.partition): --partition picks the split
(iid | dirichlet label-skew with --dirichlet-alpha | sizes quantity skew
with --sizes), --weighted-avg switches Eq. 2 to FedAvg's example-count
weighting, and ragged shards automatically thread their validity mask into
the engines (no shard is clamped, no example silently dropped;
--drop-remainder restores the paper's exactly-equal split explicitly).

Elastic membership (see repro.core.membership): --churn injects per-round
participant failures — scripted (--churn-events "crash:2:1,rejoin:4:1")
or random i.i.d. (--churn-p per-round failure probability, deterministic
in --churn-seed) — and --k-max reserves standby slots beyond
--participants that start dead and can warm-join mid-run. Dead slots are
identity carries inside the same compiled round executables; the
aggregators renormalize over the live set.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_round_state
from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.data import partition as part_mod
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr


def build_data(cfg, K, batch_size, seq_len, n_examples, seed=0,
               partition="iid", dirichlet_alpha=0.5, sizes=None,
               drop_remainder=False, k_max=None):
    """Shard the synthetic LM corpus under the chosen data scenario.

    partition="iid": the paper's random split (remainder round-robin, or
    dropped with ``drop_remainder``). "dirichlet": label-skew non-IID over
    a coarse sequence label (the first target token bucketed into 10
    classes — a deterministic proxy for topic skew on synthetic text).
    "sizes": quantity skew with the given counts/fractions.
    """
    x, y = lm_examples(seed, n_examples, seq_len, cfg.vocab_size)
    idx = part_mod.scenario_indices(
        len(x), K, seed, scenario=partition, labels=y[:, 0] % 10,
        dirichlet_alpha=dirichlet_alpha, sizes=sizes, min_size=batch_size,
        drop_remainder=drop_remainder)
    shards = part_mod.shard_by_indices([x, y], idx)
    return ParticipantData(shards, batch_size, seed, k_max=k_max)


# Module-level so every eval batch reuses one compiled executable; a
# jax.jit created inside the loop is a fresh wrapper (and retrace) per batch.
_eval_loss_step = jax.jit(tr.loss_fn, static_argnums=(1,))


def eval_loss(params, cfg, x, y, batch=64):
    tot, n = 0.0, 0
    for i in range(0, len(x) - batch + 1, batch):
        b = {"tokens": jnp.asarray(x[i:i + batch]),
             "labels": jnp.asarray(y[i:i + batch])}
        loss, _ = _eval_loss_step(params, cfg, b)
        tot += float(loss) * batch
        n += batch
    return tot / max(n, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--participants", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--t0", type=int, default=2)
    ap.add_argument("--eta0", type=float, default=0.01)
    ap.add_argument("--epsilon", type=float, default=0.05)
    ap.add_argument("--schedule", default="clr", choices=["clr", "elr"],
                    help="legacy spelling of --lr-schedule")
    ap.add_argument("--epochs-rule", default="ile", choices=["ile", "fle"],
                    help="legacy spelling of --sync-policy")
    ap.add_argument("--lr-schedule", default="",
                    choices=["", "clr", "elr", "warmup_clr", "cosine"],
                    help="Eq. 3 family member (api.SCHEDULES): clr = paper "
                         "per-round restart; elr = global anneal; "
                         "warmup_clr = clr with eta ramped over the first "
                         "rounds; cosine = per-round cosine anneal")
    ap.add_argument("--sync-policy", default="",
                    choices=["", "ile", "fle", "divtrigger"],
                    help="Eq. 4 rule (api.SYNC_POLICIES): ile = paper "
                         "doubling; fle = fixed T; divtrigger = Kamp-style "
                         "divergence-triggered sync (quiet rounds skip the "
                         "wire and bill 0 bytes)")
    ap.add_argument("--trigger-delta", type=float, default=0.05,
                    help="divergence threshold for --sync-policy divtrigger")
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--n-examples", type=int, default=1280)
    ap.add_argument("--steps-per-epoch", type=int, default=0,
                    help="truncate each epoch to this many batches (0=full)")
    ap.add_argument("--partition", default="iid",
                    choices=["iid", "dirichlet", "sizes"],
                    help="data scenario: iid = the paper's random equal "
                         "split (remainder round-robin); dirichlet = "
                         "label-skew non-IID (--dirichlet-alpha); sizes = "
                         "quantity skew (--sizes)")
    ap.add_argument("--dirichlet-alpha", type=float, default=0.5,
                    help="Dirichlet concentration for --partition "
                         "dirichlet (small = more skew)")
    ap.add_argument("--sizes", default="",
                    help="comma-separated per-participant counts or "
                         "fractions for --partition sizes, e.g. "
                         "'0.5,0.2,0.1,0.1,0.1'")
    ap.add_argument("--drop-remainder", action="store_true",
                    help="paper-faithful exactly-equal IID shards (the "
                         "n %% K remainder is EXPLICITLY discarded; "
                         "default distributes it round-robin)")
    ap.add_argument("--weighted-avg", action="store_true",
                    help="example-count-weighted Eq. 2 (FedAvg weighting; "
                         "uniform = paper-faithful default). full "
                         "aggregator only")
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "fused"],
                    help="legacy alias for --codec: int8 = leafwise, "
                         "fused = flat-buffer")
    ap.add_argument("--codec", default="",
                    choices=["", "exact", "leafwise", "fused"],
                    help="wire codec for uploads: exact f32 | leafwise "
                         "int8 quantize-roundtrip | fused flat-buffer "
                         "(one quant->avg->dequant kernel pass)")
    ap.add_argument("--codec-bits", type=int, default=8, choices=[8, 4, 1],
                    help="wire payload bit width for the quantizing codecs "
                         "(leafwise/fused): 8 = int8, 4 = packed int4, "
                         "1 = sign + per-block scale")
    ap.add_argument("--error-feedback", action="store_true",
                    help="error-feedback residual memory for the quantizing "
                         "codecs: each participant quantizes x + e and "
                         "carries e' = (x + e) - dequant to the next round "
                         "(recommended at 4/1 bits)")
    ap.add_argument("--aggregator", default="full",
                    choices=["full", "partial", "ring", "graph", "d2"],
                    help="aggregation strategy: full = paper Eq. 2; "
                         "partial = FedAvg-style sampled uploads "
                         "(--partial-m per round); ring = one neighbor-"
                         "exchange gossip step over a fixed ring; graph = "
                         "gossip over --topology; d2 = graph gossip + the "
                         "D2 variance-reduction correction (non-IID "
                         "shards)")
    ap.add_argument("--partial-m", type=int, default=2,
                    help="participants sampled per round (partial only)")
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "grid2d", "torus", "hypercube",
                             "exponential", "erdos_renyi", "complete"],
                    help="gossip graph for --aggregator graph|d2 "
                         "(repro.core.topology registry): ring cycle | "
                         "2-D torus | hypercube (K a power of two) | "
                         "time-varying one-peer exponential | Erdos-Renyi "
                         "G(K, --er-p) | complete")
    ap.add_argument("--er-p", type=float, default=0.5,
                    help="edge probability for --topology erdos_renyi")
    ap.add_argument("--er-seed", type=int, default=0,
                    help="graph draw seed for --topology erdos_renyi")
    ap.add_argument("--engine", default="fused", choices=["fused", "python"],
                    help="round engine: fused = one executable per round "
                         "(repro.core.engine); python = reference loop")
    ap.add_argument("--churn", default="none",
                    choices=["none", "scripted", "random"],
                    help="elastic-membership fault injection "
                         "(repro.core.membership): scripted = deterministic "
                         "crash/rejoin trace (--churn-events); random = "
                         "i.i.d. per-round failures (--churn-p, "
                         "deterministic in --churn-seed)")
    ap.add_argument("--churn-events", default="",
                    help="scripted trace: comma-separated kind:round:slot "
                         "triples, e.g. 'crash:2:1,rejoin:4:1'")
    ap.add_argument("--churn-p", type=float, default=0.2,
                    help="per-round failure probability for --churn random")
    ap.add_argument("--churn-seed", type=int, default=0,
                    help="churn RNG seed (--churn random; the trace is a "
                         "pure function of (seed, round))")
    ap.add_argument("--k-max", type=int, default=0,
                    help="total participant slots (>= --participants); the "
                         "extra slots start dead as standby capacity a "
                         "rejoin can warm-join. 0 = no standby slots")
    ap.add_argument("--naive-membership", action="store_true",
                    help="ablation: keep the static mixing matrix under "
                         "churn (dead rows pollute the mean) — the "
                         "baseline benchmarks/churn.py measures against")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.codec and args.compress != "none":
        ap.error("pass --codec or the legacy --compress, not both")
    codec_spec = args.codec or args.compress
    if (args.codec_bits != 8 or args.error_feedback) and codec_spec in (
            "", "none", "exact"):
        ap.error("--codec-bits/--error-feedback require a quantizing codec "
                 "(--codec leafwise|fused or --compress int8|fused)")
    # the legacy --compress spellings ("none"/"int8"/"fused") are registry
    # aliases in api.CODECS, so both flags resolve through the one registry
    codec = api.get_codec(codec_spec, bits=args.codec_bits,
                          error_feedback=args.error_feedback)

    # partial participation samples from the participant pool — a sample
    # size beyond the pool is a config bug, caught here instead of rounds
    # later inside the mixing-matrix draw
    if args.aggregator == "partial" and args.partial_m > args.participants:
        ap.error(f"--partial-m {args.partial_m} exceeds --participants "
                 f"{args.participants}")
    if args.aggregator == "partial" and args.partial_m < 1:
        ap.error("--partial-m must be >= 1")

    # topology sub-flags only make sense for the graph-structured gossips
    if args.topology != "ring" and args.aggregator not in ("graph", "d2"):
        ap.error("--topology requires --aggregator graph|d2")
    if ((args.er_p != 0.5 or args.er_seed)
            and args.topology != "erdos_renyi"):
        ap.error("--er-p/--er-seed require --topology erdos_renyi")

    # elastic-membership flag surface: churn sub-flags must match --churn
    if args.churn_events and args.churn != "scripted":
        ap.error("--churn-events requires --churn scripted")
    if (args.churn_p != 0.2 or args.churn_seed) and args.churn != "random":
        ap.error("--churn-p/--churn-seed require --churn random")
    if args.k_max and args.churn == "none":
        ap.error("--k-max requires --churn scripted|random (standby slots "
                 "only join through membership events)")
    if args.k_max and args.k_max < args.participants:
        ap.error(f"--k-max {args.k_max} smaller than --participants "
                 f"{args.participants}")
    k_max = args.k_max or args.participants
    churn = None
    if args.churn != "none":
        from repro.core import membership as membership_mod
        init_live = args.participants if k_max > args.participants else None
        if args.churn == "random":
            churn = membership_mod.RandomChurn(
                p_fail=args.churn_p, seed=args.churn_seed,
                initial_live=init_live)
        else:
            events = []
            for spec in filter(None, args.churn_events.split(",")):
                try:
                    kind, r, k = spec.split(":")
                    events.append((kind, int(r), int(k)))
                except ValueError:
                    ap.error(f"bad --churn-events entry {spec!r} "
                             "(want kind:round:slot)")
            try:
                churn = membership_mod.ScriptedChurn(
                    events=tuple(events), initial_live=init_live)
            except ValueError as e:
                ap.error(str(e))
    if args.naive_membership and churn is None:
        ap.error("--naive-membership requires --churn")

    cfg = get_smoke_config(args.arch)
    K = k_max
    ccfg = CoLearnConfig(
        n_participants=K, T0=args.t0, eta0=args.eta0, epsilon=args.epsilon,
        schedule=args.schedule, epochs_rule=args.epochs_rule,
        max_rounds=args.rounds)

    # scenario flags must match --partition — silently ignoring them would
    # let a user believe they benchmarked a skew they never ran
    if args.sizes and args.partition != "sizes":
        ap.error("--sizes requires --partition sizes")
    if not args.sizes and args.partition == "sizes":
        ap.error("--partition sizes requires --sizes")
    if args.dirichlet_alpha != 0.5 and args.partition != "dirichlet":
        ap.error("--dirichlet-alpha requires --partition dirichlet")
    if args.drop_remainder and args.partition != "iid":
        ap.error("--drop-remainder only applies to --partition iid")
    sizes = ([float(s) for s in args.sizes.split(",")] if args.sizes
             else None)
    data = build_data(cfg, args.participants, args.batch_size, args.seq_len,
                      args.n_examples, args.seed, partition=args.partition,
                      dirichlet_alpha=args.dirichlet_alpha, sizes=sizes,
                      drop_remainder=args.drop_remainder,
                      k_max=k_max if args.k_max else None)
    ex, ey = lm_examples(args.seed + 99, 256, args.seq_len, cfg.vocab_size)

    def loss_fn(params, batch):
        x, y = batch
        return tr.loss_fn(params, cfg, {"tokens": x, "labels": y})

    if args.weighted_avg and args.aggregator != "full":
        ap.error("--weighted-avg only applies to --aggregator full")
    if args.aggregator == "partial":
        aggregator = api.PartialParticipation(m=args.partial_m,
                                              seed=args.seed)
    elif args.weighted_avg:
        aggregator = api.FullAverage(weights=data.sizes)
    elif args.aggregator in ("graph", "d2"):
        from repro.core import topology as topo_mod
        if args.topology == "erdos_renyi":
            topo = topo_mod.ErdosRenyiTopology(p=args.er_p,
                                               seed=args.er_seed)
        else:
            topo = topo_mod.get_topology(args.topology)
        cls = api.D2Gossip if args.aggregator == "d2" else api.GraphGossip
        aggregator = cls(topology=topo)
    else:
        aggregator = api.get_aggregator(args.aggregator)
    # ragged shards (unequal batch counts): thread the validity mask into
    # the engines so every shard trains on exactly its own batches
    batch_mask = data.batch_mask if data.ragged else None
    if batch_mask is not None and args.steps_per_epoch:
        batch_mask = batch_mask[:, :args.steps_per_epoch]
    # --lr-schedule/--sync-policy override the legacy string flags; either
    # way the objects come out of the same registries
    schedule = api.get_schedule(args.lr_schedule or None, ccfg)
    sync_policy = api.get_sync_policy(args.sync_policy or None, ccfg,
                                      delta=args.trigger_delta)
    learner = CoLearner(ccfg, loss_fn, optimizer_name=args.optimizer,
                        codec=codec, aggregator=aggregator,
                        round_engine=args.engine, schedule=schedule,
                        sync_policy=sync_policy, shard_sizes=data.sizes,
                        batch_mask=batch_mask, churn=churn,
                        liveness_aware=not args.naive_membership)
    params = tr.init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32)
    state = learner.init(params)
    shard_s = (f" shards={list(data.sizes)}" if args.partition != "iid"
               or data.ragged else "")
    if churn is not None:
        shard_s += (f" churn={learner.churn.name}"
                    + (f" k_max={k_max}" if args.k_max else "")
                    + (" naive" if args.naive_membership else ""))
    print(f"co-learning {cfg.name}: K={K} params="
          f"{tr.count_params(params):,} rounds={args.rounds} T0={args.t0} "
          f"{learner.schedule.name}+{learner.sync_policy.name} "
          f"engine={args.engine} codec={learner.codec.name} "
          f"aggregator={learner.aggregator.name} "
          f"partition={args.partition}{shard_s}", flush=True)

    for _ in range(args.rounds):
        t0 = time.time()

        def epoch_batches(round_i, epoch_j):
            bx, by = data.epoch_batches(round_i, epoch_j)
            if args.steps_per_epoch:
                bx, by = bx[:, :args.steps_per_epoch], by[:, :args.steps_per_epoch]
            return (jnp.asarray(bx), jnp.asarray(by))

        state = learner.run_round(state, epoch_batches)
        log = state["log"][-1]
        ev = eval_loss(learner.shared_model(state), cfg, ex, ey)
        sync_s = "" if log.synced else " SKIP(sync)"
        if churn is not None:
            sync_s += f" live={log.live}/{K}"
        print(f"round {log.round}: T={log.T} lr {log.lr_first:.4f}->"
              f"{log.lr_last:.4f} rel_dw={log.rel_change:.4f} "
              f"local_loss={np.mean(log.local_losses):.4f} eval={ev:.4f} "
              f"comm={log.comm_bytes/2**20:.1f}MiB next_T={state['ctrl'].T}"
              f"{sync_s} ({time.time()-t0:.1f}s)", flush=True)

    if args.checkpoint:
        save_round_state(args.checkpoint, state)
        print(f"saved {args.checkpoint}.params.npz")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
