"""End-to-end co-learning training driver (CPU-scale, real training).

Trains a reduced-config model of any assigned architecture with the paper's
Algorithm 1 on synthetic-LM shards split across K participants, logging
per-round losses, the Eq.4 controller decisions, and communication volume.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --participants 5 --rounds 6 --t0 2 --steps-per-epoch 8
  ... --vanilla     # centralized baseline (same total data, K=1)

Round strategy (see repro.core.api): --codec picks the wire format of the
uploads (exact f32 | leafwise int8 | fused flat-buffer), --aggregator picks
who averages what (full Eq. 2 | FedAvg-style partial participation with
--partial-m sampled uploads per round | ring gossip), --engine picks the
round executor, --lr-schedule the Eq. 3 family member (clr | elr |
warmup_clr | cosine; defaults to the legacy --schedule flag), and
--sync-policy the Eq. 4 rule (ile | fle | divtrigger with --trigger-delta;
defaults to the legacy --epochs-rule flag). --compress remains the legacy
spelling of --codec, resolved through the api.CODECS registry aliases.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_round_state
from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr


def build_data(cfg, K, batch_size, seq_len, n_examples, seed=0):
    x, y = lm_examples(seed, n_examples, seq_len, cfg.vocab_size)
    shards = partition_arrays([x, y], K, seed)
    return ParticipantData(shards, batch_size, seed)


# Module-level so every eval batch reuses one compiled executable; a
# jax.jit created inside the loop is a fresh wrapper (and retrace) per batch.
_eval_loss_step = jax.jit(tr.loss_fn, static_argnums=(1,))


def eval_loss(params, cfg, x, y, batch=64):
    tot, n = 0.0, 0
    for i in range(0, len(x) - batch + 1, batch):
        b = {"tokens": jnp.asarray(x[i:i + batch]),
             "labels": jnp.asarray(y[i:i + batch])}
        loss, _ = _eval_loss_step(params, cfg, b)
        tot += float(loss) * batch
        n += batch
    return tot / max(n, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--participants", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--t0", type=int, default=2)
    ap.add_argument("--eta0", type=float, default=0.01)
    ap.add_argument("--epsilon", type=float, default=0.05)
    ap.add_argument("--schedule", default="clr", choices=["clr", "elr"],
                    help="legacy spelling of --lr-schedule")
    ap.add_argument("--epochs-rule", default="ile", choices=["ile", "fle"],
                    help="legacy spelling of --sync-policy")
    ap.add_argument("--lr-schedule", default="",
                    choices=["", "clr", "elr", "warmup_clr", "cosine"],
                    help="Eq. 3 family member (api.SCHEDULES): clr = paper "
                         "per-round restart; elr = global anneal; "
                         "warmup_clr = clr with eta ramped over the first "
                         "rounds; cosine = per-round cosine anneal")
    ap.add_argument("--sync-policy", default="",
                    choices=["", "ile", "fle", "divtrigger"],
                    help="Eq. 4 rule (api.SYNC_POLICIES): ile = paper "
                         "doubling; fle = fixed T; divtrigger = Kamp-style "
                         "divergence-triggered sync (quiet rounds skip the "
                         "wire and bill 0 bytes)")
    ap.add_argument("--trigger-delta", type=float, default=0.05,
                    help="divergence threshold for --sync-policy divtrigger")
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--n-examples", type=int, default=1280)
    ap.add_argument("--steps-per-epoch", type=int, default=0,
                    help="truncate each epoch to this many batches (0=full)")
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "fused"],
                    help="legacy alias for --codec: int8 = leafwise, "
                         "fused = flat-buffer")
    ap.add_argument("--codec", default="",
                    choices=["", "exact", "leafwise", "fused"],
                    help="wire codec for uploads: exact f32 | leafwise "
                         "int8 quantize-roundtrip | fused flat-buffer "
                         "(one quant->avg->dequant kernel pass)")
    ap.add_argument("--aggregator", default="full",
                    choices=["full", "partial", "ring"],
                    help="aggregation strategy: full = paper Eq. 2; "
                         "partial = FedAvg-style sampled uploads "
                         "(--partial-m per round); ring = one neighbor-"
                         "exchange gossip step over a fixed ring")
    ap.add_argument("--partial-m", type=int, default=2,
                    help="participants sampled per round (partial only)")
    ap.add_argument("--engine", default="fused", choices=["fused", "python"],
                    help="round engine: fused = one executable per round "
                         "(repro.core.engine); python = reference loop")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.codec and args.compress != "none":
        ap.error("pass --codec or the legacy --compress, not both")
    # the legacy --compress spellings ("none"/"int8"/"fused") are registry
    # aliases in api.CODECS, so both flags resolve through the one registry
    codec = api.get_codec(args.codec or args.compress)

    cfg = get_smoke_config(args.arch)
    K = args.participants
    ccfg = CoLearnConfig(
        n_participants=K, T0=args.t0, eta0=args.eta0, epsilon=args.epsilon,
        schedule=args.schedule, epochs_rule=args.epochs_rule,
        max_rounds=args.rounds)

    data = build_data(cfg, K, args.batch_size, args.seq_len,
                      args.n_examples, args.seed)
    ex, ey = lm_examples(args.seed + 99, 256, args.seq_len, cfg.vocab_size)

    def loss_fn(params, batch):
        x, y = batch
        return tr.loss_fn(params, cfg, {"tokens": x, "labels": y})

    aggregator = (api.PartialParticipation(m=args.partial_m, seed=args.seed)
                  if args.aggregator == "partial"
                  else api.get_aggregator(args.aggregator))
    # --lr-schedule/--sync-policy override the legacy string flags; either
    # way the objects come out of the same registries
    schedule = api.get_schedule(args.lr_schedule or None, ccfg)
    sync_policy = api.get_sync_policy(args.sync_policy or None, ccfg,
                                      delta=args.trigger_delta)
    learner = CoLearner(ccfg, loss_fn, optimizer_name=args.optimizer,
                        codec=codec, aggregator=aggregator,
                        round_engine=args.engine, schedule=schedule,
                        sync_policy=sync_policy)
    params = tr.init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32)
    state = learner.init(params)
    print(f"co-learning {cfg.name}: K={K} params="
          f"{tr.count_params(params):,} rounds={args.rounds} T0={args.t0} "
          f"{learner.schedule.name}+{learner.sync_policy.name} "
          f"engine={args.engine} codec={learner.codec.name} "
          f"aggregator={learner.aggregator.name}", flush=True)

    for i in range(args.rounds):
        t0 = time.time()

        def epoch_batches(round_i, epoch_j):
            bx, by = data.epoch_batches(round_i, epoch_j)
            if args.steps_per_epoch:
                bx, by = bx[:, :args.steps_per_epoch], by[:, :args.steps_per_epoch]
            return (jnp.asarray(bx), jnp.asarray(by))

        state = learner.run_round(state, epoch_batches)
        log = state["log"][-1]
        ev = eval_loss(learner.shared_model(state), cfg, ex, ey)
        sync_s = "" if log.synced else " SKIP(sync)"
        print(f"round {log.round}: T={log.T} lr {log.lr_first:.4f}->"
              f"{log.lr_last:.4f} rel_dw={log.rel_change:.4f} "
              f"local_loss={np.mean(log.local_losses):.4f} eval={ev:.4f} "
              f"comm={log.comm_bytes/2**20:.1f}MiB next_T={state['ctrl'].T}"
              f"{sync_s} ({time.time()-t0:.1f}s)", flush=True)

    if args.checkpoint:
        save_round_state(args.checkpoint, state)
        print(f"saved {args.checkpoint}.params.npz")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
