"""Continuous-operation driver: train on a drifting stream, serve between
rounds.

Closes the train->serve loop: each communication round trains on that
round's ``ShardStream`` snapshot (concept drift as a scenario axis), the
synced shared model is published into a ``ModelBank``, and a ``ServeLoop``
hot-swaps the newest version into its compiled decode step and serves a
prompt batch — all in one process, the CPU-scale shape of a data center
that keeps serving while it co-trains.

Usage:
  PYTHONPATH=src python -m repro.launch.continuous --participants 3 \
      --rounds 6 --drift abrupt --drift-round 3 --sync-policy divtrigger
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core import api
from repro.core.colearn import CoLearner
from repro.data.stream import ShardStream, get_drift
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr
from repro.serving import ModelBank, ServeLoop


def drift_from_flags(args):
    """Map the CLI drift flags onto a DriftSchedule instance."""
    if args.drift == "none":
        return get_drift(None)
    if args.drift == "abrupt":
        return get_drift("abrupt", at_round=args.drift_round,
                         severity=args.drift_severity)
    return get_drift(args.drift, rate=args.drift_rate)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--participants", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--t0", type=int, default=2)
    ap.add_argument("--eta0", type=float, default=0.01)
    ap.add_argument("--epsilon", type=float, default=0.05)
    ap.add_argument("--sync-policy", default="ile",
                    choices=["ile", "fle", "divtrigger"])
    ap.add_argument("--trigger-delta", type=float, default=0.05)
    ap.add_argument("--engine", default="fused", choices=["fused", "python"])
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--n-examples", type=int, default=480)
    ap.add_argument("--steps-per-epoch", type=int, default=0)
    ap.add_argument("--drift", default="none",
                    choices=["none", "covariate", "label_shift", "abrupt"],
                    help="concept-drift schedule for the shard stream "
                         "(repro.data.stream registry)")
    ap.add_argument("--drift-rate", type=float, default=0.1,
                    help="per-round drift rate (covariate | label_shift)")
    ap.add_argument("--drift-round", type=int, default=3,
                    help="task-switch round for --drift abrupt")
    ap.add_argument("--drift-severity", type=float, default=1.0,
                    help="relabeled label-space fraction for --drift abrupt")
    ap.add_argument("--publish-on", default="synced",
                    choices=["synced", "always"],
                    help="bank publication policy: synced = keep serving "
                         "the stale shared model through quiet rounds")
    ap.add_argument("--bank-dir", default="",
                    help="persist published versions here (checkpoint/io)")
    ap.add_argument("--serve-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.max_seq < args.prompt_len + args.new_tokens:
        ap.error(f"--max-seq {args.max_seq} < --prompt-len {args.prompt_len}"
                 f" + --new-tokens {args.new_tokens}: decode would index "
                 "past the KV cache")
    if args.drift_rate != 0.1 and args.drift not in ("covariate",
                                                     "label_shift"):
        ap.error("--drift-rate requires --drift covariate|label_shift")
    if ((args.drift_round != 3 or args.drift_severity != 1.0)
            and args.drift != "abrupt"):
        ap.error("--drift-round/--drift-severity require --drift abrupt")

    cfg = get_smoke_config(args.arch)
    K = args.participants
    drift = drift_from_flags(args)
    x, y = lm_examples(args.seed, args.n_examples, args.seq_len,
                       cfg.vocab_size)
    stream = ShardStream([x, y], K, args.batch_size, args.seed, drift=drift)
    ex, ey = lm_examples(args.seed + 99, 128, args.seq_len, cfg.vocab_size)

    def loss_fn(params, batch):
        bx, by = batch
        return tr.loss_fn(params, cfg, {"tokens": bx, "labels": by})

    ccfg = CoLearnConfig(n_participants=K, T0=args.t0, eta0=args.eta0,
                         epsilon=args.epsilon, max_rounds=args.rounds)
    sync_policy = api.get_sync_policy(args.sync_policy, ccfg,
                                      delta=args.trigger_delta)
    learner = CoLearner(ccfg, loss_fn, round_engine=args.engine,
                        sync_policy=sync_policy, shard_sizes=stream.sizes,
                        batch_mask=stream.batch_mask if stream.ragged
                        else None)
    params = tr.init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32)
    state = learner.init(params)

    bank = ModelBank(mode="shared", publish_on=args.publish_on,
                     dir=args.bank_dir or None)
    bank.publish(learner.shared_model(state), round_i=0)  # v1 = init model
    serve = ServeLoop(cfg, learner.shared_model(state),
                      batch=args.serve_batch, max_seq=args.max_seq)
    serve.poll(bank)
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 7),
                                 (args.serve_batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    print(f"continuous {cfg.name}: K={K} rounds={args.rounds} "
          f"drift={drift.name} sync={learner.sync_policy.name} "
          f"publish_on={args.publish_on} engine={args.engine}", flush=True)

    for _ in range(args.rounds):
        t0 = time.time()

        def epoch_batches(round_i, epoch_j):
            bx, by = stream.epoch_batches(round_i, epoch_j)
            if args.steps_per_epoch:
                bx = bx[:, :args.steps_per_epoch]
                by = by[:, :args.steps_per_epoch]
            return (jnp.asarray(bx), jnp.asarray(by))

        state = learner.run_round(state, epoch_batches,
                                  on_round_end=bank.publish_from)
        swap_t0 = time.time()
        swapped = serve.poll(bank)
        swap_ms = (time.time() - swap_t0) * 1e3
        _, stats = serve.generate(prompts, args.new_tokens)
        log = state["log"][-1]
        # honest eval: the held-out set as THIS round's distribution sees it
        dx, dy = stream.transform_test((ex, ey), state["round"])
        loss, _ = tr.loss_fn(bank.current().params, cfg,
                             {"tokens": jnp.asarray(dx[:64]),
                              "labels": jnp.asarray(dy[:64])})
        print(f"round {log.round}: T={log.T} "
              f"local_loss={np.mean(log.local_losses):.4f} "
              f"serve_loss={float(loss):.4f} v{serve.version} "
              f"stale={bank.staleness(state['round'])} "
              f"{'swap %.1fms' % swap_ms if swapped else 'no-swap'} "
              f"{stats['tokens_per_s']:.0f} tok/s "
              f"compiles={serve.compile_count()}"
              f"{'' if log.synced else ' SKIP(sync)'} "
              f"({time.time()-t0:.1f}s)", flush=True)

    assert serve.compile_count() == 1, "hot swaps must not recompile decode"
    print(f"served {serve.tokens_served} tokens across "
          f"{serve.batches_served} batches while training "
          f"{args.rounds} rounds; final version v{serve.version}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
