"""tracelint — static analysis for the repo's traced-data discipline.

Run as a module (CI job) or from pytest (self-run in
``tests/test_analysis.py``)::

    python -m repro.analysis.tracelint src/repro
    run_paths(["src/repro"]) == []

Rules (see ``docs/traced_data_discipline.md`` for the rationale):

== =========================== =============================================
ID name                        what it flags
== =========================== =============================================
TL001 jit-in-loop              ``jax.jit`` / ``pl.pallas_call`` / engine
                               ``make_fused_*`` builders constructed inside
                               a loop body — one compile cache per
                               iteration, the per-round recompile disaster.
TL002 host-sync-in-traced      ``.item()`` / ``jax.device_get`` /
                               ``np.asarray`` / ``float()``/``int()`` in a
                               function reachable from traced code — a
                               blocking sync (or concretization error) on
                               the round critical path.
TL003 traced-closure-leak      a traced function defined inside a host
                               loop closing over loop-carried data instead
                               of taking it as an argument — the value is
                               baked into the trace, so every iteration
                               retraces.
TL004 missing-donate           a round/epochs/finalize-shaped executable
                               jitted without ``donate_argnums`` — the old
                               params stay alive across the donating call,
                               doubling peak memory.
TL005 registry-conformance     a registered codec/aggregator/engine/
                               schedule/policy/topology/drift/churn object
                               missing part of its protocol surface,
                               including the stateful/live/weighted/events
                               optional hooks (the rule that would have
                               caught the PR 6/7/8 plumbing gaps).
TL006 state-key-consistency    a ``state["…"]`` key the engines thread
                               that ``checkpoint/io.py`` does not persist
                               or ``restart_participant`` / the runners'
                               ``select_live`` plumbing do not handle.
== =========================== =============================================

Suppression: append ``# tracelint: disable=TL002 -- reason`` to the
flagged line (or put it on a comment line directly above). The committed
baseline (``tracelint_baseline.txt``) is empty and must stay empty —
fix the hazard or justify it inline.

TL001–TL004 are pure AST passes over the given paths. TL005/TL006
import ``repro`` and reflect over the live registries / module sources;
they run whenever ``repro`` is importable (disable with
``--no-project-rules`` when linting fixtures).
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from dataclasses import dataclass

# -- findings, suppressions, baseline ----------------------------------------

RULES = {
    "TL001": "jit-in-loop",
    "TL002": "host-sync-in-traced",
    "TL003": "traced-closure-leak",
    "TL004": "missing-donate",
    "TL005": "registry-conformance",
    "TL006": "state-key-consistency",
}

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "tracelint_baseline.txt")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} ({RULES[self.rule]}) {self.message}"

    def key(self) -> str:
        """Baseline key: stable under message rewording, not line drift
        (the baseline is meant to stay empty, not to age gracefully)."""
        return f"{self.rule} {self.path}:{self.line}"


_SUPPRESS_RE = re.compile(r"#\s*tracelint:\s*disable=((?:TL\d{3}[,\s]*)+)")


def _suppressions(source: str) -> dict:
    """line number -> set of rule ids suppressed on that line."""
    out = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m:
            out[i] = set(re.findall(r"TL\d{3}", m.group(1)))
    return out


def _apply_suppressions(findings, sup):
    """A finding is suppressed by a directive on its own line or on the
    comment line directly above it."""
    kept = []
    for f in findings:
        rules = sup.get(f.line, set()) | sup.get(f.line - 1, set())
        if f.rule not in rules:
            kept.append(f)
    return kept


def load_baseline(path: str) -> set:
    if not os.path.exists(path):
        return set()
    with open(path) as fh:
        return {line.strip() for line in fh
                if line.strip() and not line.startswith("#")}


# -- AST helpers -------------------------------------------------------------

def _dotted(node):
    """'jax.lax.scan' for an Attribute chain, 'jit' for a Name, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _tail(dotted):
    return dotted.rsplit(".", 1)[-1] if dotted else None


def _annotate_parents(tree):
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._tl_parent = node


def _ancestors(node):
    node = getattr(node, "_tl_parent", None)
    while node is not None:
        yield node
        node = getattr(node, "_tl_parent", None)


_LOOPS = (ast.For, ast.AsyncFor, ast.While)
_COMPS = (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)
_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

#: executable builders that own a compile cache — building one per loop
#: iteration is the per-round recompile disaster TL001 exists for
JIT_BUILDERS = {"jax.jit", "jit", "pjit", "jax.pmap", "pmap",
                "pl.pallas_call", "pallas_call"}
_JIT_BUILDER_TAIL_RE = re.compile(r"^make_fused_\w+$")

#: calls whose function-valued arguments get traced (roots for TL002/3)
TRACER_ENTRIES = JIT_BUILDERS | {
    "jax.vmap", "vmap", "jax.grad", "jax.value_and_grad", "jax.checkpoint",
    "jax.remat", "shard_map", "jax.lax.scan", "lax.scan", "jax.lax.cond",
    "lax.cond", "jax.lax.switch", "lax.switch", "jax.lax.while_loop",
    "lax.while_loop", "jax.lax.fori_loop", "lax.fori_loop", "jax.lax.map",
    "lax.map",
}

#: host-sync calls flagged by TL002 inside traced-reachable functions
HOST_SYNC_CALLS = {"jax.device_get", "device_get", "np.asarray", "np.array",
                   "numpy.asarray", "numpy.array", "onp.asarray"}
HOST_SYNC_METHODS = {"item", "tolist", "to_py"}
HOST_SYNC_BUILTINS = {"float", "int", "bool"}

#: jax.jit first-arg names that mark a donating-signature executable —
#: the round/epochs/finalize family the engine builds (TL004)
_DONATING_RE = re.compile(r"\b(round_fn|epochs_fn|finalize|fused)\w*")


def _is_jit_builder(call: ast.Call) -> bool:
    d = _dotted(call.func)
    if d in JIT_BUILDERS:
        return True
    t = _tail(d)
    return bool(t and _JIT_BUILDER_TAIL_RE.match(t))


def _assigned_names(node, *, skip=None):
    """All names bound anywhere under ``node`` (assignments, loop targets,
    with-targets, comprehension targets), excluding the ``skip`` subtree."""
    names = set()
    for n in ast.walk(node):
        if skip is not None and n is skip:
            continue
        if _in_subtree(n, skip):
            continue
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store,)):
            names.add(n.id)
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(n.name)
    return names


def _in_subtree(node, root):
    if root is None:
        return False
    while node is not None:
        if node is root:
            return True
        node = getattr(node, "_tl_parent", None)
    return False


def _func_params(fn):
    args = fn.args
    names = [a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return set(names)


def _walk_body(fn):
    """Walk a function's *body* only — default-value expressions and
    decorators evaluate at definition time in the enclosing scope (the
    ``def f(x, _w=w)`` rebind is the sanctioned fix for TL003, not a
    closure)."""
    for stmt in (fn.body if isinstance(fn.body, list) else [fn.body]):
        yield from ast.walk(stmt)


def _free_names(fn):
    """Names loaded in ``fn``'s body that ``fn`` does not bind itself."""
    bound = set(_func_params(fn))
    loaded = set()
    for n in _walk_body(fn):
        if isinstance(n, ast.Name):
            if isinstance(n.ctx, ast.Store):
                bound.add(n.id)
            elif isinstance(n.ctx, ast.Load):
                loaded.add(n.id)
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(n.name)
    return loaded - bound


# -- per-module linter (TL001-TL004) -----------------------------------------

class ModuleLinter:
    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        _annotate_parents(self.tree)
        self.findings = []

    def run(self):
        self._collect_traced()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                self._tl001(node)
                self._tl004(node)
        self._tl002()
        self._tl003()
        return _apply_suppressions(self.findings, _suppressions(self.source))

    def _flag(self, rule, node, message):
        self.findings.append(
            Finding(rule, self.path, getattr(node, "lineno", 1), message))

    # -- TL001: jit built inside a loop body -------------------------------
    def _tl001(self, call):
        if not _is_jit_builder(call):
            return
        for anc in _ancestors(call):
            if isinstance(anc, _FUNCS + (ast.ClassDef,)):
                return  # enclosing def owns the call; loops above are lexical only
            if isinstance(anc, _LOOPS + _COMPS):
                self._flag("TL001", call,
                           f"`{ast.unparse(call.func)}` constructed inside "
                           "a loop body: a fresh compile cache per "
                           "iteration. Build the executable once outside "
                           "and pass per-iteration values as arguments.")
                return

    # -- traced-function discovery (shared by TL002/TL003) ------------------
    def _collect_traced(self):
        self.functions = [n for n in ast.walk(self.tree)
                          if isinstance(n, _FUNCS)]
        by_name = {}
        for fn in self.functions:
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                by_name.setdefault(fn.name, []).append(fn)
        traced = set()

        def mark_name(name):
            for fn in by_name.get(name, ()):
                traced.add(fn)

        # roots: jit-ish decorators, and function-valued args of tracer
        # entries (by name, or a lambda in place)
        for fn in self.functions:
            for dec in getattr(fn, "decorator_list", ()):
                d = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
                if d in JIT_BUILDERS or (
                        isinstance(dec, ast.Call) and _tail(d) == "partial"
                        and dec.args
                        and _dotted(dec.args[0]) in JIT_BUILDERS):
                    traced.add(fn)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if _dotted(node.func) not in TRACER_ENTRIES:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Lambda):
                    traced.add(arg)
                elif isinstance(arg, ast.Name):
                    mark_name(arg.id)

        # close over nesting and intra-module calls (self.foo() / foo())
        changed = True
        while changed:
            changed = False
            for fn in self.functions:
                if fn in traced:
                    continue
                if any(a in traced for a in _ancestors(fn)
                       if isinstance(a, _FUNCS)):
                    traced.add(fn)
                    changed = True
            for fn in list(traced):
                for node in ast.walk(fn):
                    if not isinstance(node, ast.Call):
                        continue
                    callee = None
                    if isinstance(node.func, ast.Name):
                        callee = node.func.id
                    elif (isinstance(node.func, ast.Attribute)
                          and isinstance(node.func.value, ast.Name)
                          and node.func.value.id in ("self", "cls")):
                        callee = node.func.attr
                    for target in by_name.get(callee, ()):
                        if target not in traced:
                            traced.add(target)
                            changed = True
        self.traced = traced

    # -- TL002: host syncs reachable from traced code ------------------------
    def _tl002(self):
        seen = set()
        for fn in self.traced:
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call) or node.lineno in seen:
                    continue
                d = _dotted(node.func)
                hit = None
                if d in HOST_SYNC_CALLS:
                    hit = d
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in HOST_SYNC_METHODS):
                    hit = f".{node.func.attr}()"
                elif (d in HOST_SYNC_BUILTINS and node.args
                      and not isinstance(node.args[0], ast.Constant)):
                    hit = f"{d}()"
                if hit:
                    seen.add(node.lineno)
                    self._flag("TL002", node,
                               f"host sync `{hit}` inside a function "
                               "reachable from traced code: a blocking "
                               "device round-trip (or concretization "
                               "error) on the round critical path. Return "
                               "device values and sync once, outside.")

    # -- TL003: traced fn closing over loop-carried data ---------------------
    def _tl003(self):
        for fn in self.traced:
            # nested-in-traced functions are static unrolling inside one
            # trace — only root traced fns can leak host-loop data
            if any(a in self.traced for a in _ancestors(fn)
                   if isinstance(a, _FUNCS)):
                continue
            free = _free_names(fn)
            if not free:
                continue
            for anc in _ancestors(fn):
                if isinstance(anc, _LOOPS):
                    loop_names = _assigned_names(anc, skip=fn)
                    if isinstance(anc, (ast.For, ast.AsyncFor)):
                        loop_names |= {n.id for n in ast.walk(anc.target)
                                       if isinstance(n, ast.Name)}
                    leaked = sorted(free & loop_names)
                    if leaked:
                        self._flag(
                            "TL003", fn,
                            f"traced function closes over loop-carried "
                            f"{', '.join(leaked)}: the value is baked "
                            "into the trace, so every iteration "
                            "retraces. Pass it as an argument instead.")
                        break

    # -- TL004: donating-signature executables without donate_argnums --------
    def _tl004(self, call):
        if _dotted(call.func) not in ("jax.jit", "jit"):
            return
        if not call.args:
            return
        target = ast.unparse(call.args[0])
        if not _DONATING_RE.search(target):
            return
        kwargs = {kw.arg for kw in call.keywords}
        if not kwargs & {"donate_argnums", "donate_argnames"}:
            self._flag("TL004", call,
                       f"`jax.jit({target}, ...)` looks like a round/"
                       "epochs/finalize executable but passes no "
                       "donate_argnums: the consumed input buffers stay "
                       "alive across the call, doubling peak memory.")


def lint_source(source: str, path: str = "<fixture>"):
    """AST rules (TL001-TL004) over one source string — the test hook."""
    return ModuleLinter(path, source).run()


def lint_file(path: str):
    with open(path) as fh:
        return lint_source(fh.read(), path)


def iter_py_files(paths):
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for root, _dirs, files in os.walk(p):
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


# -- TL005: registry conformance (runtime reflection) ------------------------

def _accepts(fn, kwarg):
    import inspect
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return True
    return kwarg in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


def _locate(cls):
    import inspect
    try:
        path = inspect.getsourcefile(cls) or "<unknown>"
        line = inspect.getsourcelines(cls)[1]
    except (OSError, TypeError):
        path, line = "<unknown>", 1
    return path, line


def check_registries():
    """Every registered object implements its full protocol surface,
    including the optional hooks later PRs rely on (``live=`` liveness
    rows, ``events=`` membership events, ``delta=`` gate overrides,
    ``weighted=``/``stateful=`` fused-mean variants). A registered object
    missing one of these degrades *silently* — the engine falls back to
    the legacy call shape — which is exactly how the PR 6/7/8 plumbing
    gaps shipped."""
    from repro.core import api, membership, topology
    from repro.data import stream

    findings = []

    def require(obj, registry, name, cond, what):
        if not cond:
            path, line = _locate(type(obj))
            findings.append(Finding(
                "TL005", path, line,
                f"{registry}[{name!r}] ({type(obj).__name__}) {what}"))

    def methods(obj, registry, name, *names):
        for m in names:
            require(obj, registry, name, callable(getattr(obj, m, None)),
                    f"missing protocol method `{m}`")

    def kw(obj, registry, name, method, kwarg):
        fn = getattr(obj, method, None)
        require(obj, registry, name, fn is None or _accepts(fn, kwarg),
                f"`{method}` does not accept the `{kwarg}=` hook")

    for name, factory in api.CODECS.items():
        c = factory()
        methods(c, "CODECS", name, "encode", "decode", "roundtrip",
                "wire_bytes", "init_state", "make_fused_mean")
        require(c, "CODECS", name, hasattr(c, "stateful"),
                "missing `stateful` attribute")
        for hook in ("weighted", "stateful"):
            kw(c, "CODECS", name, "make_fused_mean", hook)
        if getattr(c, "stateful", False):
            require(c, "CODECS", name,
                    type(c).roundtrip_ef is not api.WireCodec.roundtrip_ef,
                    "is stateful but does not override `roundtrip_ef` "
                    "(error feedback would silently no-op)")

    for name, factory in api.AGGREGATORS.items():
        a = factory()
        methods(a, "AGGREGATORS", name, "mixing_matrix",
                "make_aggregate_fn", "comm_bytes", "init_round_state")
        for attr in ("stateful", "uses_weights", "static_comm"):
            require(a, "AGGREGATORS", name, hasattr(a, attr),
                    f"missing `{attr}` attribute")
        kw(a, "AGGREGATORS", name, "mixing_matrix", "live")
        kw(a, "AGGREGATORS", name, "comm_bytes", "live")
        kw(a, "AGGREGATORS", name, "make_aggregate_fn", "dynamic")

    for name, factory in api.ENGINES.items():
        methods(factory(), "ENGINES", name, "bind")

    for name, factory in api.SCHEDULES.items():
        s = factory()
        methods(s, "SCHEDULES", name, "lr", "round_params",
                "device_round_params")
        require(s, "SCHEDULES", name,
                callable(getattr(s, "traced_lr", None)),
                "missing the traced `traced_lr` body the fused engine "
                "embeds")

    for name, factory in api.SYNC_POLICIES.items():
        p = factory()
        methods(p, "SYNC_POLICIES", name, "init_state", "update",
                "should_sync", "round_delta", "epochs_budget")
        require(p, "SYNC_POLICIES", name, hasattr(p, "divergence_gated"),
                "missing `divergence_gated` attribute")
        require(p, "SYNC_POLICIES", name,
                callable(getattr(p, "traced_should_sync", None)),
                "missing the traced `traced_should_sync` gate")
        kw(p, "SYNC_POLICIES", name, "update", "events")
        kw(p, "SYNC_POLICIES", name, "should_sync", "delta")
        kw(p, "SYNC_POLICIES", name, "round_delta", "events")

    for name, factory in topology.TOPOLOGIES.items():
        t = factory()
        methods(t, "TOPOLOGIES", name, "adjacency", "mixing_matrix",
                "edge_perms", "spectral_gap", "validate", "period")
        require(t, "TOPOLOGIES", name, hasattr(t, "time_varying"),
                "missing `time_varying` attribute")
        kw(t, "TOPOLOGIES", name, "mixing_matrix", "live")

    for name, cls in stream.DRIFTS.items():
        d = cls()
        methods(d, "DRIFTS", name, "transform")
        require(d, "DRIFTS", name, hasattr(d, "is_static"),
                "missing `is_static` attribute")
        for arg in ("x", "y", "round_i", "seed"):
            kw(d, "DRIFTS", name, "transform", arg)

    for name, factory in membership.CHURN_SCHEDULES.items():
        c = factory()
        methods(c, "CHURN_SCHEDULES", name, "live_mask")
        require(c, "CHURN_SCHEDULES", name, hasattr(c, "is_static"),
                "missing `is_static` attribute")

    return findings


# -- TL006: state-key consistency --------------------------------------------

#: state keys that are legitimately in-memory only: the round log is
#: re-derived (checkpoint meta persists the controller history; the
#: benchmarks serialize their own records)
EPHEMERAL_KEYS = frozenset({"log"})
#: per-participant (K, ...) slots that crash handling must reset and the
#: liveness freeze must carry per-row
PER_SLOT_KEYS = frozenset({"params", "opt", "residual"})


def _state_keys(tree):
    """String keys accessed as state["…"] / state.get("…")."""
    keys = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and node.value.id == "state"
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, str)):
            keys.add(node.slice.value)
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "get"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "state"
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            keys.add(node.args[0].value)
    return keys


def _function_source_keys(tree, fn_name):
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == fn_name:
            return _state_keys(node)
    return None


def _class_state_keys(tree, class_names):
    """Keys accessed on the LEARNER state inside the named classes only —
    other ``state`` locals (e.g. an aggregator's round-state sub-dict)
    are a different namespace."""
    keys = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name in class_names:
            keys |= _state_keys(node)
    return keys


def check_state_keys(threaded, io_keys, restart_keys, runner_keys,
                     io_path="src/repro/checkpoint/io.py",
                     colearn_path="src/repro/core/colearn.py"):
    """Pure core of TL006 (unit-tested on fabricated key sets).

    ``threaded``: keys the engines read/write on ``state``; ``io_keys``:
    keys checkpoint save/restore handles; ``restart_keys``: keys
    ``restart_participant`` resets; ``runner_keys``: keys the runners'
    select-live / finish-round plumbing touches.
    """
    findings = []
    for key in sorted(threaded - io_keys - EPHEMERAL_KEYS):
        findings.append(Finding(
            "TL006", io_path, 1,
            f"engines thread state[{key!r}] but checkpoint save/restore "
            "never handles it: a resumed run silently drops it. Persist "
            "it (or add it to tracelint's EPHEMERAL_KEYS with a reason)."))
    for key in sorted((threaded & PER_SLOT_KEYS) - restart_keys):
        findings.append(Finding(
            "TL006", colearn_path, 1,
            f"per-participant state[{key!r}] is threaded but "
            "`restart_participant` does not reset it: a restarted slot "
            "would resume with stale per-slot memory."))
    for key in sorted((threaded & PER_SLOT_KEYS) - runner_keys):
        findings.append(Finding(
            "TL006", colearn_path, 1,
            f"per-participant state[{key!r}] is threaded but the round "
            "runners' select-live plumbing never touches it: dead slots "
            "would not carry it through a sync."))
    return findings


def check_project_state_keys():
    import inspect

    from repro.checkpoint import io as ckpt_io
    from repro.core import api, colearn

    def tree_of(mod):
        path = inspect.getsourcefile(mod)
        with open(path) as fh:
            t = ast.parse(fh.read(), filename=path)
        return path, t

    colearn_path, colearn_tree = tree_of(colearn)
    api_path, api_tree = tree_of(api)
    io_path, io_tree = tree_of(ckpt_io)

    runner_keys = _class_state_keys(api_tree,
                                    {"_PythonRunner", "_FusedRunner"})
    threaded = _state_keys(colearn_tree) | runner_keys
    io_keys = (_function_source_keys(io_tree, "save_round_state") or set()) \
        | (_function_source_keys(io_tree, "restore_round_state") or set())
    restart_keys = _function_source_keys(
        colearn_tree, "restart_participant") or set()
    return check_state_keys(threaded, io_keys, restart_keys, runner_keys,
                            io_path=io_path, colearn_path=colearn_path)


# -- driver ------------------------------------------------------------------

def run_paths(paths, baseline: str = DEFAULT_BASELINE,
              project_rules: bool = True):
    """All unsuppressed findings not covered by the baseline."""
    findings = []
    for path in iter_py_files(paths):
        findings.extend(lint_file(path))
    if project_rules:
        findings.extend(check_registries())
        findings.extend(check_project_state_keys())
    known = load_baseline(baseline) if baseline else set()
    return [f for f in findings if f.key() not in known]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tracelint",
        description="static analysis for the traced-data discipline")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--no-project-rules", action="store_true",
                    help="skip the import-based rules (TL005/TL006)")
    args = ap.parse_args(argv)
    findings = run_paths(args.paths, baseline=args.baseline,
                         project_rules=not args.no_project_rules)
    for f in findings:
        print(f.render())
    if findings:
        print(f"tracelint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print(f"tracelint: clean ({', '.join(sorted(RULES))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
