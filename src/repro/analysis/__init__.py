"""Static analysis + runtime sanitizers for the traced-data discipline.

``tracelint`` is the AST pass (``python -m repro.analysis.tracelint
src/repro``); ``guards`` holds the runtime side — the ``no_retrace``
compile-count guard and the ``no_transfer`` implicit-transfer guard the
benchmarks, tests and ``ServeLoop`` share. See
``docs/traced_data_discipline.md`` for what each rule enforces and why.
"""
from repro.analysis.guards import (RetraceError, assert_compile_count,
                                   compile_count, no_retrace, no_transfer)

__all__ = ["RetraceError", "assert_compile_count", "compile_count",
           "no_retrace", "no_transfer"]
