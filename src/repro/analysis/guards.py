"""Runtime sanitizers for the traced-data discipline.

The repo's core invariant (ROADMAP "Static analysis") is that per-round
quantities ride into long-lived donated executables as *traced data*:
nothing recompiles per round and no implicit host<->device traffic lands
on the round critical path. ``tracelint`` enforces the static half; this
module is the runtime half:

* :func:`compile_count` / :func:`assert_compile_count` — the ONE place
  the repo reads a jitted callable's compile cache. ``ServeLoop``,
  ``round_latency.py --check-retrace`` and the engine tests all count
  through it, so "what counts as a recompile" cannot drift between the
  three stories.
* :class:`no_retrace` — wraps a jitted callable and raises
  :class:`RetraceError` the moment it holds more compiled variants than
  promised. A retrace is otherwise *silent* (just 10x latency / 2x
  memory); the wrapper turns it into a loud failure at the offending
  call.
* :func:`no_transfer` — pins a code region free of *implicit* transfers
  via ``jax.transfer_guard("disallow")``. Explicit ``jax.device_put`` /
  ``jax.device_get`` (the engine's staging and the one per-round aux
  fetch) stay legal; a numpy array or python scalar sneaking into a
  jitted call, or a ``float()`` on a device value, raises.
"""
from __future__ import annotations

import contextlib

import jax


class RetraceError(AssertionError):
    """A guarded executable compiled more variants than promised."""


def compile_count(fn) -> int:
    """Distinct compiled executables behind ``fn``.

    ``fn`` is a jitted callable (``jax.jit`` result) or a
    :class:`no_retrace` wrapper. Zero until the first call.
    """
    if isinstance(fn, no_retrace):
        return fn.compile_count()
    return fn._cache_size()


def assert_compile_count(fn, expected: int, what: str = "jitted function"):
    """Assert ``fn`` compiled exactly ``expected`` variants.

    The after-the-fact form of :class:`no_retrace` for executables built
    elsewhere (the engine's fused round/epochs/finalize): run the
    scenario, then pin the cache size it must have ended at.
    """
    n = compile_count(fn)
    if n != expected:
        raise RetraceError(
            f"{what}: compile count {n} != expected {expected} — a "
            "per-round quantity leaked into the trace (closure capture, "
            "python branching on data, or shape/dtype drift across calls)")
    return n


class no_retrace:
    """Wrap a jitted callable so every call enforces a compile budget.

    >>> step = no_retrace(jax.jit(f), limit=1, what="decode step")
    >>> step(x)          # compiles once: count 1 <= limit, fine
    >>> step(x.astype(jnp.float64))   # RetraceError at the call site

    ``limit`` is the number of compiled variants the wrapper tolerates
    (1 for the single-signature executables this repo builds).
    ``compile_count()`` exposes the underlying cache size — this is what
    ``ServeLoop.compile_count`` now reports.
    """

    def __init__(self, jitted, *, limit: int = 1,
                 what: str = "jitted function"):
        self._jitted = jitted
        self.limit = int(limit)
        self.what = what

    def __call__(self, *args, **kwargs):
        out = self._jitted(*args, **kwargs)
        self.check()
        return out

    def compile_count(self) -> int:
        return self._jitted._cache_size()

    def check(self, limit: int | None = None) -> int:
        """Raise :class:`RetraceError` if the budget is exceeded."""
        n = self.compile_count()
        lim = self.limit if limit is None else int(limit)
        if n > lim:
            raise RetraceError(
                f"{self.what}: {n} compiled variants exceed the "
                f"no_retrace limit of {lim} — an argument changed "
                "shape/dtype or a per-call quantity was baked into the "
                "trace instead of riding in as data")
        return n


@contextlib.contextmanager
def no_transfer():
    """Disallow implicit host<->device transfers inside the block.

    Explicit staging stays legal: ``jax.device_put`` (and
    ``jnp.asarray`` over a numpy array, which routes through it),
    ``jax.device_get`` / ``np.asarray`` on a device array. What raises:
    numpy arrays or python scalars passed straight into a jitted call,
    ``jnp.stack`` over numpy inputs, ``float()``/``int()``/``.item()``
    on device values. Those are exactly the accidental per-round
    transfers the engine's staging discipline exists to prevent.
    """
    with jax.transfer_guard("disallow"):
        yield
