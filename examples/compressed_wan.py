"""Scenario (beyond-paper): int8-quantized WAN uploads.

The paper notes it does NOT compress parameter exchange; this example shows
the framework's beyond-paper option: participants upload int8 blockwise-
quantized parameters, cutting per-round WAN volume ~2x vs bf16 / ~4x vs
f32 at negligible accuracy cost. Both wire paths are exercised: the
leafwise reference codec and the flat-buffer fast path (one fused
quantize->average->dequantize pass over one contiguous buffer, exact
byte accounting — see ROADMAP "Wire codec").

Run:  PYTHONPATH=src python examples/compressed_wan.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.colearn import CoLearner
from repro.core.compression import compressed_bytes, flat_compressed_bytes
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr

cfg = get_smoke_config("phi4-mini-3.8b")
x, y = lm_examples(seed=0, n=400, seq_len=32, vocab=cfg.vocab_size)
shards = partition_arrays([x, y], K=4, seed=0)

for label, compress in (("exact (paper)", None),
                        ("int8 leafwise", "leafwise"),
                        ("int8 flat-buffer", "fused")):
    data = ParticipantData(shards, batch_size=8)
    learner = CoLearner(
        CoLearnConfig(n_participants=4, T0=1, max_rounds=3, eta0=0.05),
        loss_fn=lambda p, b: tr.loss_fn(p, cfg, {"tokens": b[0], "labels": b[1]}),
        compress=compress)
    state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
    for i in range(3):
        state = learner.run_round(
            state, lambda i_, j_: tuple(map(jnp.asarray,
                                            data.epoch_batches(i_, j_))))
    params = learner.shared_model(state)
    raw = sum(t.size * 4 for t in jax.tree.leaves(params))
    wire = raw
    if compress == "leafwise":
        wire = compressed_bytes(params)
    elif compress == "fused":
        wire = flat_compressed_bytes(state["params"])  # exact, incl. pad
    print(f"{label:22s} final_loss={np.mean(state['log'][-1].local_losses):.4f}"
          f"  wire_bytes/round={2*wire/2**20:.1f}MiB (f32 would be "
          f"{2*raw/2**20:.1f}MiB)")
