"""Scenario (beyond-paper): int8-quantized WAN uploads + round strategies.

The paper notes it does NOT compress parameter exchange; this example shows
the framework's beyond-paper wire codecs (``repro.core.api``): participants
upload int8 blockwise-quantized parameters, cutting per-round WAN volume
~2x vs bf16 / ~4x vs f32 at negligible accuracy cost. Both codec objects
are exercised under full Eq. 2 averaging — LeafwiseInt8 (the per-leaf
reference roundtrip) and FlatFusedInt8 (one fused quantize->average->
dequantize pass over one contiguous buffer, exact byte accounting) — and
the per-round wire bytes now come straight from ``RoundLog.comm_bytes``
(codec-priced upload + f32 download). Two sub-int8 runs push the same
flat wire below one byte per element — ``FlatFusedIntN(bits=4,
error_feedback=True)`` and the 1-bit extreme — where the error-feedback
residual (each round re-injects its own rounding error into the next
upload) is what keeps the aggressive widths converging alongside int8;
compare their bytes AND final losses in the output. A later run swaps
the aggregator for FedAvg-style partial participation: only m=2 of the
K=4 data centers upload each round, and the comm accounting shrinks
accordingly. The final run keeps full averaging but gates it behind a
Kamp-style ``DivergenceTrigger`` sync policy: rounds where the local
models haven't drifted past delta skip the wire entirely and bill ZERO
bytes — the cheapest upload is the one never sent.

Run:  PYTHONPATH=src python examples/compressed_wan.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.api import (DivergenceTrigger, ExactF32, FlatFusedInt8,
                            FlatFusedIntN, FullAverage, LeafwiseInt8,
                            PartialParticipation)
from repro.core.colearn import CoLearner
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr

cfg = get_smoke_config("phi4-mini-3.8b")
x, y = lm_examples(seed=0, n=400, seq_len=32, vocab=cfg.vocab_size)
shards = partition_arrays([x, y], K=4, seed=0)

RUNS = (
    ("exact (paper)", ExactF32(), FullAverage(), None),
    ("int8 leafwise", LeafwiseInt8(), FullAverage(), None),
    ("int8 flat-buffer", FlatFusedInt8(), FullAverage(), None),
    ("int4 flat + EF", FlatFusedIntN(bits=4, error_feedback=True),
     FullAverage(), None),
    ("1-bit flat + EF", FlatFusedIntN(bits=1, error_feedback=True),
     FullAverage(), None),
    ("flat + partial m=2", FlatFusedInt8(), PartialParticipation(m=2), None),
    ("flat + div-trigger", FlatFusedInt8(), FullAverage(),
     DivergenceTrigger(delta=0.01)),
)

for label, codec, aggregator, sync_policy in RUNS:
    data = ParticipantData(shards, batch_size=8)
    learner = CoLearner(
        CoLearnConfig(n_participants=4, T0=1, max_rounds=3, eta0=0.05),
        loss_fn=lambda p, b: tr.loss_fn(p, cfg, {"tokens": b[0], "labels": b[1]}),
        codec=codec, aggregator=aggregator, sync_policy=sync_policy)
    state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
    for _ in range(3):
        state = learner.run_round(
            state, lambda i_, j_: tuple(map(jnp.asarray,
                                            data.epoch_batches(i_, j_))))
    params = learner.shared_model(state)
    raw = sum(t.size * 4 for t in jax.tree.leaves(params))
    log = state["log"][-1]
    synced = sum(1 for l in state["log"] if l.synced)
    total = sum(l.comm_bytes for l in state["log"])
    # per-round cost of a SYNCED round (quiet rounds bill 0 by design)
    per_round = next((l.comm_bytes for l in state["log"] if l.synced), 0)
    print(f"{label:20s} final_loss={np.mean(log.local_losses):.4f}"
          f"  comm/round={per_round/2**20:.1f}MiB per participant, "
          f"3-round total={total/2**20:.1f}MiB over {synced}/3 synced "
          f"rounds (f32 full-avg would be {2*raw/2**20:.1f}MiB/round)")
