"""Quickstart: co-learning (the paper's Algorithm 1) in ~40 lines.

Five "data centers" each hold a disjoint shard of a synthetic LM corpus;
they train locally with the cyclical learning rate (Eq. 3), the server
averages parameters (Eq. 2) and doubles local epochs when the shared model
stabilizes (Eq. 4).

The round strategy is composed explicitly from the five protocols in
``repro.core.api`` — the wire codec (ExactF32: paper-faithful f32 uploads),
the aggregator (FullAverage: Eq. 2), the round engine (PythonEngine: the
reference host loop), the learning-rate schedule (CLR: Eq. 3, restarting
at η^i every round), and the sync policy (ILE: Eq. 4, doubling local
epochs once the shared model stabilizes). Swap any piece independently:
e.g. ``codec=FlatFusedInt8()`` for int8 flat-buffer uploads (see
examples/compressed_wan.py), ``aggregator=PartialParticipation(m=2)`` for
FedAvg-style sampled uploads, ``round_engine=FusedEngine()`` for the
one-executable-per-round fast path, ``schedule=WarmupCLR()`` to ramp η^i
over the first rounds, or ``sync_policy=DivergenceTrigger(delta=...)`` to
communicate only when the local models have diverged (Kamp et al.).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.api import CLR, ILE, ExactF32, FullAverage, PythonEngine
from repro.core.colearn import CoLearner
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr

cfg = get_smoke_config("internlm2-1.8b")           # reduced dense GQA model
x, y = lm_examples(seed=0, n=600, seq_len=32, vocab=cfg.vocab_size)
data = ParticipantData(partition_arrays([x, y], K=5, seed=0), batch_size=8)

learner = CoLearner(
    CoLearnConfig(n_participants=5, T0=1, eta0=0.05, epsilon=0.05,
                  max_rounds=4),
    loss_fn=lambda p, b: tr.loss_fn(p, cfg, {"tokens": b[0], "labels": b[1]}),
    codec=ExactF32(),                   # paper-faithful f32 wire
    aggregator=FullAverage(),           # Eq. 2 over all K participants
    round_engine=PythonEngine(),        # reference per-epoch host loop
    schedule=CLR(eta0=0.05),            # Eq. 3: restart at eta^i each round
    sync_policy=ILE(epsilon=0.05),      # Eq. 4: double T_i on stabilization
)
state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))

for _ in range(4):
    state = learner.run_round(
        state, lambda i_, j_: tuple(map(jnp.asarray, data.epoch_batches(i_, j_))))
    log = state["log"][-1]
    print(f"round {log.round}: T_i={log.T} lr {log.lr_first:.3f}->{log.lr_last:.4f}"
          f" loss={np.mean(log.local_losses):.3f} |Δw̄|/|w̄|={log.rel_change:.4f}"
          f" next_T={state['ctrl'].T} comm={log.comm_bytes/2**20:.1f}MiB")

print("shared model params:", tr.count_params(learner.shared_model(state)))
