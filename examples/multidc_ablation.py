"""Scenario: the paper's Figure-2 ablation, end to end.

Compares CLR+ILE / CLR+FLE / ELR+ILE / ELR+FLE on the CIFAR-like synthetic
image task with a tiny ResNet across 5 simulated data centers, plus the
vanilla (centralized) and ensemble baselines of Table 2.

Run:  PYTHONPATH=src python examples/multidc_ablation.py
"""
import sys

sys.path.insert(0, ".")

from benchmarks.ablation import run as run_ablation
from benchmarks.cifar_like import run as run_cifar

print("== Fig.2 ablation (resnet_tiny, 5 data centers) ==")
rows = run_ablation(models=("resnet_tiny",), rounds=5, n=3000)
best = max(rows, key=lambda r: r["final_acc"])
print(f"best combo: {best['combo']} (paper: clr+ile)")

print("\n== Table 2: vanilla vs ensemble vs co-learning ==")
run_cifar(models=("vgg_tiny", "resnet_tiny"), rounds=5, n=3000)
