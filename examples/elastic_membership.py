"""Elastic membership: a data center crashes mid-run and warm-rejoins.

The paper assumes a static set of K participants; its whole failure story
is one sentence — restart the failed participant's local training from
the shared model. ``repro.core.membership`` turns that into a first-class
layer: a ``ChurnSchedule`` decides WHO is live each round, the liveness
mask rides into the (unchanged, compiled-once) round executables as
traced data, and the aggregators renormalize their mixing over the live
set so a dead slot neither uploads, downloads, nor counts in the mean.

This walkthrough scripts the paper's scenario exactly: data center 1
crashes during round 2 and comes back in round 4. While it is down its
slot is an identity carry (parameters AND optimizer state frozen); on
rejoin ``CoLearner.restart_participant`` warm-starts it from the last
*synced* shared model, and training proceeds — same executables, no
recompilation, every round logged with its live count.

Run:  PYTHONPATH=src python examples/elastic_membership.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.api import FusedEngine
from repro.core.colearn import CoLearner
from repro.core.membership import ScriptedChurn
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr

K, ROUNDS = 4, 6
cfg = get_smoke_config("internlm2-1.8b")           # reduced dense GQA model
x, y = lm_examples(seed=0, n=480, seq_len=32, vocab=cfg.vocab_size)
data = ParticipantData(partition_arrays([x, y], K=K, seed=0), batch_size=8)

# the fault-injection trace: slot 1 dies at round 2, warm-rejoins at 4
churn = ScriptedChurn(events=(("crash", 2, 1), ("rejoin", 4, 1)))

learner = CoLearner(
    CoLearnConfig(n_participants=K, T0=1, eta0=0.05, epsilon=0.05,
                  max_rounds=ROUNDS),
    loss_fn=lambda p, b: tr.loss_fn(p, cfg, {"tokens": b[0], "labels": b[1]}),
    round_engine=FusedEngine(),     # churn rides into the ONE executable
    churn=churn,                    # ...as a traced (K,) liveness row
)
state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))

for i in range(ROUNDS):
    state = learner.run_round(
        state, lambda i_, j_: tuple(map(jnp.asarray, data.epoch_batches(i_, j_))))
    log = state["log"][-1]
    ev = state["membership"].round_events(i)
    ev_s = "".join(f"  <-- slot {k} {kind}s" for _, k, kind in ev)
    print(f"round {log.round}: live={log.live}/{K} "
          f"loss={np.mean(log.local_losses):.3f} "
          f"|Δw̄|/|w̄|={log.rel_change:.4f} "
          f"comm={log.comm_bytes / 2**20:.1f}MiB{ev_s}")

print("membership event log:", state["membership"].events)
print("shared model params:", tr.count_params(learner.shared_model(state)))
