"""Scenario: heterogeneous data across the data centers (ISSUE 5).

The paper trains on equal IID shards; this example exercises the claim it
actually makes — model averaging "on different types of data" — along both
heterogeneity axes:

1. quantity skew — one data center holds 4x the data of the smallest
   (``quantity_skew``). The ragged pipeline pads to the longest shard and
   masks the padding (no shard is clamped, no example dropped), and
   Eq. 2 averaging is example-count weighted (FedAvg, 1602.05629).
2. label skew — each center's class mixture ~ Dirichlet(alpha)
   (``dirichlet_partition``); alpha=0.1 is near single-class shards, the
   regime where decentralized averaging is actually stressed (D²,
   1803.07068).

Run:  PYTHONPATH=src python examples/heterogeneous_shards.py
"""
import sys

sys.path.insert(0, ".")

import numpy as np

from benchmarks.harness import run_colearn
from repro.data.synthetic import image_like
from repro.models.convnets import IMAGE_MODELS

init_fn, apply_fn = IMAGE_MODELS["resnet_tiny"]
train = image_like(seed=0, n=2000)
test = image_like(seed=1000, n=800)

print("== quantity skew (sizes 4:2:1:1, weighted vs uniform Eq. 2) ==")
for weighted in (False, True):
    r = run_colearn(init_fn, apply_fn, train, test, K=4, rounds=3, T0=1,
                    engine="fused", partition="sizes",
                    sizes=[0.5, 0.25, 0.125, 0.125], weighted=weighted)
    print(f"  weighted={weighted}: shards={list(r['shard_sizes'])} "
          f"acc/round={[f'{a:.3f}' for a in r['acc']]}")

print("== label skew (Dirichlet alpha, weighted Eq. 2) ==")
for alpha in (0.1, 1.0):
    r = run_colearn(init_fn, apply_fn, train, test, K=4, rounds=3, T0=1,
                    engine="fused", partition="dirichlet",
                    dirichlet_alpha=alpha, weighted=True)
    print(f"  alpha={alpha}: shards={list(r['shard_sizes'])} "
          f"acc/round={[f'{a:.3f}' for a in r['acc']]}")

print("every example trained: shard sizes above always sum to",
      np.sum(r["shard_sizes"]))
