"""Scenario: batched KV-cache serving of a co-learned model.

Trains a reduced Jamba (hybrid Mamba+attention+MoE) with co-learning for a
couple of rounds, then serves batched greedy decoding from the shared
model — the same serve_step the multi-pod dry-run lowers at production
shapes (decode_32k / long_500k).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.colearn import CoLearner
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr

cfg = get_smoke_config("jamba-v0.1-52b")
x, y = lm_examples(seed=0, n=300, seq_len=24, vocab=cfg.vocab_size)
data = ParticipantData(partition_arrays([x, y], K=3, seed=0), batch_size=6)
learner = CoLearner(
    CoLearnConfig(n_participants=3, T0=1, max_rounds=2, eta0=0.05),
    loss_fn=lambda p, b: tr.loss_fn(p, cfg, {"tokens": b[0], "labels": b[1]}))
state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
for i in range(2):
    state = learner.run_round(
        state, lambda i_, j_: tuple(map(jnp.asarray, data.epoch_batches(i_, j_))))
    print(f"round {i}: loss={np.mean(state['log'][-1].local_losses):.3f}")

params = learner.shared_model(state)

B, prompt_len, new_tokens, max_seq = 4, 8, 12, 32
prompts = jnp.asarray(x[:B, :prompt_len])
cache = tr.init_cache(cfg, B, max_seq, jnp.float32)
step = jax.jit(lambda p, c, t, i: tr.decode_step(p, cfg, c, t, i))

logits = None
for t in range(prompt_len):                      # prefill token-by-token
    logits, cache = step(params, cache, prompts[:, t:t + 1], jnp.int32(t))
tok = jnp.argmax(logits, -1).astype(jnp.int32)
out = [tok]
for i in range(new_tokens - 1):                  # greedy decode
    logits, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out.append(tok)
gen = jnp.concatenate(out, axis=1)
print("prompt[0]:", prompts[0].tolist())
print("generated[0]:", gen[0].tolist())
print("cache kinds:", sorted({k.split(':')[0] for k in cfg.layer_kinds()}))
