"""Graph gossip: decentralized rounds over a sparse topology, plus D².

The paper's server averages all K uploads every round (Eq. 2) — an
O(K) all-to-all. ``repro.core.topology`` replaces the server with a
communication graph: each data center exchanges parameters only with its
graph neighbors, mixing with Metropolis–Hastings doubly-stochastic
weights, so the per-round wire bill is O(degree) while repeated rounds
still drive all replicas to the same consensus (rate set by the graph's
spectral gap). ``D2Gossip`` adds the D² / Exact-Diffusion correction on
top of the same graph — a per-slot memory that cancels the bias sparse
mixing picks up when shards are non-IID.

This walkthrough trains 8 "data centers" on a hypercube (each talks to
log2(K)=3 neighbors), prints the spectral-gap diagnostic for several
registered topologies, and compares the per-round bill against the dense
all-to-all. The time-varying one-peer exponential graph shows topology
as traced data: the graph changes every round, the compiled round
executable does not.

Run:  PYTHONPATH=src python examples/graph_gossip.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.api import D2Gossip, FusedEngine, GraphGossip
from repro.core.colearn import CoLearner
from repro.core.topology import get_topology
from repro.data.partition import partition_arrays
from repro.data.pipeline import ParticipantData
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr

K, ROUNDS = 8, 4

# spectral gap of I - W bounds the consensus rate: bigger gap, faster
# mixing, and (for the sparse graphs) a far smaller per-edge wire bill
print(f"topology diagnostics at K={K}:")
for name in ("ring", "grid2d", "hypercube", "exponential", "complete"):
    t = get_topology(name)
    print(f"  {name:<12} max_degree={t.degree(0, K)} "
          f"spectral_gap={t.spectral_gap(K):.3f}"
          f"{'  (time-varying, period-averaged)' if t.time_varying else ''}")

cfg = get_smoke_config("internlm2-1.8b")           # reduced dense GQA model
x, y = lm_examples(seed=0, n=640, seq_len=32, vocab=cfg.vocab_size)
data = ParticipantData(partition_arrays([x, y], K=K, seed=0), batch_size=8)

learner = CoLearner(
    CoLearnConfig(n_participants=K, T0=1, eta0=0.05, epsilon=0.05,
                  max_rounds=ROUNDS),
    loss_fn=lambda p, b: tr.loss_fn(p, cfg, {"tokens": b[0], "labels": b[1]}),
    aggregator=D2Gossip("hypercube"),   # sparse gossip + D² bias correction
    round_engine=FusedEngine(),         # one executable; W rides as data
)
state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))

dense = GraphGossip("complete")
for i in range(ROUNDS):
    state = learner.run_round(
        state, lambda i_, j_: tuple(map(jnp.asarray, data.epoch_batches(i_, j_))))
    log = state["log"][-1]
    dense_bytes = dense.comm_bytes(learner.codec, state["params"], i)
    print(f"round {log.round}: loss={np.mean(log.local_losses):.3f} "
          f"|Δw̄|/|w̄|={log.rel_change:.4f} "
          f"comm={log.comm_bytes / 2**20:.1f}MiB/node "
          f"(dense all-to-all would be {dense_bytes / 2**20:.1f}MiB)")

# doubly-stochastic mixing preserves the replica mean; D²'s corrections
# sum to zero — the consensus mean is what a deployment would serve
mean = jax.tree.map(lambda t: t.mean(0), state["params"])
spread = max(float(jnp.abs(p - m[None]).max())
             for p, m in zip(jax.tree.leaves(state["params"]),
                             jax.tree.leaves(mean)))
print(f"replica spread around consensus mean: {spread:.4f}")
print("shared model params:", tr.count_params(learner.shared_model(state)))
