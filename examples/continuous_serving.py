"""Continuous operation: train on a drifting stream, serve between rounds.

The paper's data centers keep *producing* data while training runs — and
keep *serving* the model they train. This walkthrough closes that loop at
CPU scale with the three pieces of ``repro.serving``:

1. A ``ShardStream`` stages each round's shards from a drifting corpus
   (here an abrupt task switch at round 3 — labels are cyclically
   remapped, the classic concept-drift recovery scenario). Shapes are a
   round-0 invariant, so the drifting contents ride into the one compiled
   round executable as traced data.
2. A ``ModelBank`` versions the shared model after every synced round
   (``CoLearner.run_round``'s ``on_round_end`` hook). Quiet rounds under
   the divergence-triggered sync policy publish nothing — the bank keeps
   serving the last *synced* model, stale but still the shared one.
3. A ``ServeLoop`` polls the bank between rounds and hot-swaps the newest
   version into its single jitted decode step: same treedef and shapes
   mean the swap is a pointer update — the decode compile count stays 1
   across every swap (asserted at the end).

Run:  PYTHONPATH=src python examples/continuous_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import CoLearnConfig
from repro.core.api import DivergenceTrigger
from repro.core.colearn import CoLearner
from repro.data.stream import AbruptDrift, ShardStream
from repro.data.synthetic import lm_examples
from repro.models import transformer as tr
from repro.serving import ModelBank, ServeLoop

K, ROUNDS = 3, 6
cfg = get_smoke_config("internlm2-1.8b").with_(     # 1-layer reduced model
    n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
    segments=((("gqa:dense",), 1),))

# the stream: same corpus surface as ParticipantData, but round-indexed —
# at round 3 the label space is cyclically remapped (the task switches)
x, y = lm_examples(seed=0, n=240, seq_len=16, vocab=cfg.vocab_size)
stream = ShardStream([x, y], K, batch_size=8, seed=0,
                     drift=AbruptDrift(at_round=3))

learner = CoLearner(
    CoLearnConfig(n_participants=K, T0=2, eta0=0.05, epsilon=0.05,
                  max_rounds=ROUNDS),
    loss_fn=lambda p, b: tr.loss_fn(p, cfg, {"tokens": b[0], "labels": b[1]}),
    round_engine="fused",
    sync_policy=DivergenceTrigger(delta=0.02),   # quiet while locals agree
)
state = learner.init(tr.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))

# publication + serving: v1 is the init model, so serving is live from
# round 0 even if the first rounds stay quiet
bank = ModelBank()
bank.publish(learner.shared_model(state), round_i=0)
serve = ServeLoop(cfg, learner.shared_model(state), batch=4, max_seq=16)
serve.poll(bank)
prompts = jax.random.randint(jax.random.PRNGKey(7), (4, 6), 0,
                             cfg.vocab_size)

for _ in range(ROUNDS):
    state = learner.run_round(
        state,
        lambda i_, j_: tuple(map(jnp.asarray, stream.epoch_batches(i_, j_))),
        on_round_end=bank.publish_from)          # synced rounds publish
    swapped = serve.poll(bank)                   # quiet rounds: no swap
    _, stats = serve.generate(prompts, new_tokens=8)
    log = state["log"][-1]
    print(f"round {log.round}: {'sync' if log.synced else 'quiet'} "
          f"loss={np.mean(log.local_losses):.3f} "
          f"serving v{serve.version} "
          f"(stale {bank.staleness(state['round'])} rounds) "
          f"{'swapped' if swapped else 'held'} "
          f"{stats['tokens_per_s']:.0f} tok/s "
          f"compiles={stats['compile_count']}")

assert serve.compile_count() == 1, "a hot swap must never recompile decode"
print(f"served {serve.tokens_served} tokens across {serve.batches_served} "
      f"batches while training {ROUNDS} rounds; final version "
      f"v{serve.version} of {bank.version}")
